//! Behavioral SEU fault-injection campaign (§2.4, Table 5's resilience
//! story made dynamic): inject bit-flips into live NIC protocol state at
//! MTBF-derived rates while collectives run; reliable designs stall QPs,
//! OptiNIC's self-healing state degrades gracefully.
//!
//!   cargo run --release --example fault_injection -- --rounds 40

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::hw;
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::Table;

fn main() {
    let args = optinic::util::cli::Args::from_env(false, &[]).unwrap();
    let rounds = args.opt_usize("rounds", 40);
    let accel = args.opt_f64("accel", 2e7);

    let mut table = Table::new(
        "fault injection: AllReduce rounds under accelerated SEU rates",
        &[
            "transport",
            "MTBF model (h)",
            "faults injected",
            "rounds ok",
            "rounds failed",
            "stalled QPs",
        ],
    );
    for transport in [
        TransportKind::Roce,
        TransportKind::Irn,
        TransportKind::Srnic,
        TransportKind::Optinic,
    ] {
        let report = hw::synthesize(transport);
        let mut fab = FabricCfg::cloudlab(4);
        fab.corrupt_prob = 0.0;
        let mut cluster =
            Cluster::new(ClusterCfg::new(fab, transport).with_seed(3).with_bg_load(0.0));
        // schedule Poisson fault arrivals over a generous horizon
        let horizon = (rounds as u64) * 50 * optinic::sim::MS;
        hw::fault::schedule_faults(&mut cluster, transport, horizon, accel, 3);

        let elems = 64 * 1024;
        let ws = Workspace::new(&mut cluster, elems, 1);
        let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems]).collect();
        let mut driver = Driver::new(1);
        let mut ok = 0;
        let mut failed = 0;
        for _ in 0..rounds {
            ws.load_inputs(&mut cluster, &inputs);
            let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
            spec.exchange_stats = true;
            if !matches!(transport, TransportKind::Optinic | TransportKind::OptinicHw) {
                spec = spec.reliable();
            }
            // bound each round so a stalled QP can't hang the campaign
            cluster.cfg.max_sim_time = cluster.time + 200 * optinic::sim::MS;
            let res = driver.run(&mut cluster, &ws, &spec);
            if res.completed && !res.per_rank.iter().any(|r| r.failed) {
                ok += 1;
            } else {
                failed += 1;
            }
            if cluster.total_stalled_qps() > 0 {
                // a permanently stalled QP poisons all further rounds:
                // count the remainder as failed, as an operator would see it
                failed += rounds - ok - failed;
                break;
            }
        }
        let out = hw::fault::outcome(&cluster, failed == 0);
        table.row(&[
            transport.name().to_string(),
            format!("{:.1}", report.mtbf_hours),
            out.faults_injected.to_string(),
            ok.to_string(),
            failed.to_string(),
            out.stalled_qps.to_string(),
        ]);
    }
    table.print();
    println!("\nReliable designs: a single upset in retry/sequence state can stall a QP");
    println!("indefinitely. OptiNIC's 52 B of self-healing context degrades to at most");
    println!("one partial completion — collectives keep finishing.");
}
