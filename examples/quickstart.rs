//! Quickstart: one lossy AllReduce over OptiNIC vs RoCE on a simulated
//! 8-node 25 GbE cluster with background traffic, plus the transport
//! design-space matrix (paper Table 1).
//!
//! Run: `cargo run --release --example quickstart`

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::Table;

fn main() {
    // ---- Table 1: the design space ------------------------------------------
    let mut t1 = Table::new(
        "Table 1: RDMA transport design space",
        &["transport", "reliability", "reordering", "CC", "PFC", "key focus"],
    );
    let fab = FabricCfg::cloudlab(2);
    let cfg = optinic::transport::TransportCfg::from_fabric(&fab);
    for kind in TransportKind::ALL_WITH_VARIANTS {
        let t = kind.build(0, &cfg);
        let f = t.features();
        t1.row(&[
            kind.name().to_string(),
            f.reliability.to_string(),
            f.reordering.to_string(),
            f.congestion_control.to_string(),
            if f.pfc_required { "Required" } else { "Not Required" }.to_string(),
            f.key_focus.to_string(),
        ]);
    }
    t1.print();

    // ---- one collective, two transports --------------------------------------
    let n = 8;
    let elems = 1024 * 1024; // 4 MB tensor
    let mut table = Table::new(
        "4 MB AllReduce on 8 nodes, 25 GbE, 20% background traffic",
        &["transport", "iter", "CCT", "data loss %", "partial steps"],
    );
    for transport in [TransportKind::Roce, TransportKind::Optinic] {
        let mut cluster = Cluster::new(
            ClusterCfg::new(FabricCfg::cloudlab(n), transport)
                .with_seed(11)
                .with_bg_load(0.2),
        );
        let ws = Workspace::new(&mut cluster, elems, 1);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|r| (0..elems).map(|i| ((r + i) % 17) as f32).collect())
            .collect();
        let mut driver = Driver::new(1);
        for iter in 0..3 {
            ws.load_inputs(&mut cluster, &inputs);
            let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
            spec.exchange_stats = true;
            if transport == TransportKind::Roce {
                spec = spec.reliable();
            }
            let res = driver.run(&mut cluster, &ws, &spec);
            table.row(&[
                transport.name().to_string(),
                iter.to_string(),
                optinic::sim::fmt_time(res.cct_ns),
                format!("{:.3}", res.loss_fraction * 100.0),
                res.per_rank
                    .iter()
                    .map(|r| r.partial_steps)
                    .sum::<usize>()
                    .to_string(),
            ]);
        }
        // verify the reduction arrived (approximately, for OptiNIC)
        let out = ws.read_output(&cluster, 0, CollectiveKind::AllReduceRing);
        let want: f32 = (0..n).map(|r| (r % 17) as f32).sum();
        let got = out[0];
        println!(
            "{}: reduced[0] = {got} (exact {want}) — {}",
            transport.name(),
            if (got - want).abs() < 1e-3 {
                "exact"
            } else {
                "approximate (bounded loss)"
            }
        );
    }
    table.print();
    println!("\nOptiNIC completes within its adaptive timeout budget and never");
    println!("stalls on stragglers; RoCE retransmits until every byte lands.");
}
