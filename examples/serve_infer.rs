//! Inference-serving driver (the Fig 4 scenario): batched decode serving
//! with tensor-parallel collectives per step, comparing transports on
//! throughput, TTFT (mean + p99), and end-to-end accuracy through the
//! lossy logits path.
//!
//!   cargo run --release --example serve_infer -- --model tiny --requests 64

use optinic::coordinator::{EnvKind, ServeCfg, Server};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, Table};
use optinic::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false, &[]).map_err(anyhow::Error::msg)?;
    let model = args.opt_or("model", "tiny");
    let requests = args.opt_usize("requests", 48);
    let env = EnvKind::parse(&args.opt_or("env", "hyperstack-8")).expect("bad env");

    let mut table = Table::new(
        &format!("serving {model} on {} ({requests} requests)", env.name()),
        &[
            "transport",
            "tok/s",
            "TTFT mean",
            "TTFT p99",
            "acc (lossy)",
            "acc (clean)",
            "data loss %",
        ],
    );
    for transport in [TransportKind::Roce, TransportKind::Optinic] {
        let mut engine = Engine::load_default()?;
        let mut cfg = ServeCfg::new(&model, env, transport);
        cfg.num_requests = requests;
        cfg.arrival_rps = args.opt_f64("rps", 300.0);
        cfg.bg_load = args.opt_f64("bg-load", 0.2);
        let mut res = Server::new(cfg, &mut engine)?.run()?;
        table.row(&[
            transport.name().to_string(),
            format!("{:.1}", res.throughput_tps()),
            fmt_ns(res.ttft_ns.mean()),
            fmt_ns(res.ttft_ns.p99()),
            format!("{:.3}", res.lossy_accuracy),
            format!("{:.3}", res.clean_accuracy),
            format!("{:.3}", res.data_loss_fraction * 100.0),
        ]);
    }
    table.print();
    println!("\nFig 4 shape: accuracy unchanged, OptiNIC throughput higher, p99 TTFT sharply lower.");
    Ok(())
}
