//! End-to-end training driver — the full three-layer stack on a real
//! (small) workload: a transformer trained on a synthetic Zipf–Markov
//! corpus, with every gradient flowing through the simulated lossy fabric
//! under the chosen transport, recovered via the Hadamard+stride codec,
//! and applied through the AOT'd optimizer HLO.
//!
//!   cargo run --release --example train_e2e -- \
//!       --model medium --steps 200 --transport optinic --env hyperstack-8
//!
//! Model tiers (see python/compile/model.py): tiny (~0.1M), small (~0.7M),
//! medium (~3.7M), large (~60M), xl (~110M params — the 100M-scale config;
//! rebuild artifacts with `--models xl` first and budget CPU hours).
//! Writes a loss-curve record to bench_results/train_e2e.json.

use optinic::coordinator::{CommPattern, EnvKind, TrainCfg, Trainer};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{save_results, Table};
use optinic::util::cli::Args;
use optinic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false, &[]).map_err(anyhow::Error::msg)?;
    let model = args.opt_or("model", "small");
    let steps = args.opt_usize("steps", 200);
    let transport = TransportKind::parse(&args.opt_or("transport", "optinic"))
        .expect("bad transport");
    let env = EnvKind::parse(&args.opt_or("env", "hyperstack-8")).expect("bad env");

    let mut engine = Engine::load_default()?;
    let info = engine.manifest.model(&model)?.clone();
    println!(
        "== end-to-end training: {model} ({} params), {} steps, {} on {} ==",
        info.param_count,
        steps,
        transport.name(),
        env.name()
    );

    let mut cfg = TrainCfg::new(&model, env, transport);
    cfg.steps = steps;
    cfg.eval_every = (steps / 10).max(1);
    cfg.pattern = CommPattern::Zero3;
    cfg.bg_load = args.opt_f64("bg-load", 0.2);
    cfg.lr = args.opt_f64("lr", 0.05) as f32;
    let t0 = std::time::Instant::now();
    let result = Trainer::new(cfg, &mut engine)?.run()?;
    let wall = t0.elapsed();

    let mut t = Table::new(
        "loss curve (every ~10%)",
        &["step", "train loss", "sim time", "comm share", "eval acc"],
    );
    let stride = (result.records.len() / 12).max(1);
    for r in result.records.iter().step_by(stride) {
        t.row(&[
            r.step.to_string(),
            format!("{:.4}", r.train_loss),
            optinic::sim::fmt_time(r.sim_time_ns),
            format!(
                "{:.0}%",
                r.comm_ns as f64 / (r.comm_ns + r.compute_ns).max(1) as f64 * 100.0
            ),
            r.eval_accuracy
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    println!(
        "\nfinal eval accuracy {:.3} | simulated wall-clock {} | avg data loss {:.3}% | host wall {:?}",
        result.final_accuracy,
        optinic::sim::fmt_time(result.total_sim_ns),
        result.total_loss_fraction * 100.0,
        wall
    );
    for target in [0.3f32, 0.5, 0.6] {
        if let Some(tta) = result.tta_ns(target) {
            println!("TTA({target:.1}) = {}", optinic::sim::fmt_time(tta));
        }
    }

    // machine-readable record for EXPERIMENTS.md
    let mut o = Json::obj();
    o.set("model", model.as_str())
        .set("transport", transport.name())
        .set("steps", steps)
        .set("final_accuracy", result.final_accuracy as f64)
        .set("total_sim_ns", result.total_sim_ns)
        .set("loss_fraction", result.total_loss_fraction)
        .set(
            "loss_curve",
            Json::Arr(
                result
                    .records
                    .iter()
                    .map(|r| {
                        let mut e = Json::obj();
                        e.set("step", r.step)
                            .set("loss", r.train_loss as f64)
                            .set("t_ns", r.sim_time_ns);
                        e
                    })
                    .collect(),
            ),
        );
    save_results("train_e2e", o);
    println!("wrote bench_results/train_e2e.json");
    Ok(())
}
