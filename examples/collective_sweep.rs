//! Collective microbenchmark sweep (Fig 5 + Fig 6 driver): every transport,
//! every collective, across message sizes, with mean and p99 CCT.
//!
//!   cargo run --release --example collective_sweep -- --mb 20,40 --iters 8

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, Table};
use optinic::util::cli::Args;
use optinic::util::stats::Samples;

fn main() {
    let args = Args::from_env(false, &[]).unwrap();
    let mbs: Vec<usize> = args
        .opt_or("mb", "20,40")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let iters = args.opt_usize("iters", 6);
    let nodes = args.opt_usize("nodes", 8);
    // every configuration, including the OptiNIC (HW) datapath variant
    let transports = TransportKind::ALL_WITH_VARIANTS;
    for kind in [
        CollectiveKind::AllReduceRing,
        CollectiveKind::AllGather,
        CollectiveKind::ReduceScatter,
    ] {
        let mut table = Table::new(
            &format!("{} — {} nodes, 25 GbE, 20% bg", kind.name(), nodes),
            &["transport", "MB", "mean CCT", "p99 CCT", "loss %"],
        );
        for transport in transports {
            for &mb in &mbs {
                let elems = mb * 1024 * 1024 / 4;
                let mut cluster = Cluster::new(
                    ClusterCfg::new(FabricCfg::cloudlab(nodes), transport)
                        .with_seed(11)
                        .with_bg_load(0.2),
                );
                let ws = Workspace::new(&mut cluster, elems, 1);
                let inputs: Vec<Vec<f32>> =
                    (0..nodes).map(|_| vec![1.0f32; elems]).collect();
                let mut driver = Driver::new(1);
                let mut s = Samples::new();
                let mut loss = 0.0;
                for _ in 0..iters {
                    ws.load_inputs(&mut cluster, &inputs);
                    let mut spec = CollectiveSpec::new(kind, elems);
                    spec.exchange_stats = true;
                    if !matches!(
                        transport,
                        TransportKind::Optinic | TransportKind::OptinicHw
                    ) {
                        spec = spec.reliable();
                    }
                    let res = driver.run(&mut cluster, &ws, &spec);
                    s.push(res.cct_ns as f64);
                    loss += res.loss_fraction;
                }
                table.row(&[
                    transport.name().to_string(),
                    mb.to_string(),
                    fmt_ns(s.mean()),
                    fmt_ns(s.p99()),
                    format!("{:.3}", loss / iters as f64 * 100.0),
                ]);
            }
        }
        table.print();
    }
}
