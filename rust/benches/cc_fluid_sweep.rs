//! CC-coupled fluid sweep — the PR10 rate-authority scoreboard.
//!
//! Drives the SAME congestion-control seam (`cc::RateAuthority`) through
//! both engine families on 3-tier fat-trees and scores their agreement:
//! packet-fidelity cells run per-fragment `admit()` gating, fluid/hybrid
//! cells run the CC-coupled solver (virtual-queue marks, synthesized RTT
//! and INT, epoch-paced credit grants; docs/SCALE.md §CC-coupled rate
//! law).
//!
//! * quick (CI bench-smoke): 128-rank {DCQCN, Swift} packet-vs-hybrid
//!   agreement grid plus the headline 1024-rank hierarchical all-reduce
//!   with DCQCN coupled through the hybrid fast path.
//! * full: widens the agreement grid to every `CcKind`.
//!
//! Acceptance: per forced CC kind, the hybrid p99 tracks the packet
//! reference within the documented 15% tolerance, and the 1024-rank
//! CC-coupled cell completes with the coupled plane actually running
//! (`cc_epochs > 0`). Results land in `bench_results/BENCH_PR10.json`.
//!
//! The sweep's worker count is derived through
//! `jobs_bounded_by_cell_bytes(est_cluster_bytes)`, which — unlike the
//! pre-PR10 planner — charges the fluid engine's flow/link tables and
//! the CC plane's side columns, so large coupled grids cannot
//! oversubscribe memory by spawning a worker per core.

use optinic::cc::CcKind;
use optinic::collectives::CollectiveKind;
use optinic::net::{FabricCfg, FidelityMode};
use optinic::sim::{run_scale_cell, ScaleCell};
use optinic::util::bench::{fmt_ns, jf, quick_mode, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{explicit_cores, jobs_bounded_by_cell_bytes, SweepGrid};

/// One bench cell: a fat-tree shape + a forced CC kind + an engine.
struct BCell {
    ranks: usize,
    fidelity: FidelityMode,
    cc: CcKind,
    hier: bool,
    elems: usize,
    iters: usize,
    /// Worker threads for the cell's iteration-level partitioned runner
    /// (wall-clock only; results byte-identical for any value).
    cores: Option<usize>,
}

/// Fat-tree shapes per rank count, as in `scale_sweep`:
/// 128 = 4 pods × 4 leaves × 8 hosts; 1024 = 8 × 8 × 16.
fn shape(ranks: usize) -> (usize, usize, usize, usize) {
    match ranks {
        128 => (4, 4, 4, 8),
        1024 => (8, 8, 8, 16),
        other => panic!("no fat-tree shape for {other} ranks"),
    }
}

/// The `ScaleCell` a bench cell resolves to — shared by the memory
/// planner (`est_cluster_bytes`) and the runner so the jobs bound is
/// computed on exactly what runs.
fn scale_cell(c: &BCell) -> ScaleCell {
    let (pods, leaves, spines, core) = shape(c.ranks);
    let fab = FabricCfg::cloudlab(c.ranks).with_fat_tree(pods, leaves, spines, core);
    let mut cell = ScaleCell::new(fab, CollectiveKind::AllReduceRing, c.elems);
    cell.fidelity = c.fidelity;
    cell.hier = c.hier;
    cell.iters = c.iters;
    cell.seed = 11;
    if let Some(n) = c.cores {
        cell = cell.with_cores(n);
    }
    cell.with_cc(c.cc)
}

fn run_cell(c: &BCell) -> Json {
    let res = run_scale_cell(&scale_cell(c));
    let mut o = Json::obj();
    o.set("ranks", c.ranks)
        .set("fidelity", c.fidelity.name())
        .set("cc", c.cc.canonical_name())
        .set("hier", c.hier)
        .set("mb", c.elems * 4 / (1024 * 1024))
        .set("completed", res.completed)
        .set("p50_ns", res.p50_ns)
        .set("p99_ns", res.p99_ns)
        .set("max_cct_ns", res.max_cct_ns())
        .set("flows", res.flows)
        .set("fluid_flows", res.fluid_started)
        .set("packet_flows", res.packet_started)
        .set("pkts_walked", res.pkts_walked)
        .set("resolves", res.resolves)
        .set("cc_epochs", res.cc_epochs)
        .set("cc_marks", res.cc_marks);
    o
}

fn jb(r: &Json, key: &str) -> bool {
    r.get(key).and_then(Json::as_bool).unwrap_or(false)
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 2 } else { 3 };
    // 128-rank ring: chunk = elems/128 = 256 KiB — at the hybrid bulk
    // threshold, so hybrid cells run the CC-coupled fluid solver while
    // packet cells are the admit()-gated reference
    let elems_128 = 128 * 64 * 1024;
    // 1024-rank hierarchical: 4 MB member flows (fluid) + 64 KiB leader
    // chunks (packet) — both engine families under one forced CC
    let elems_1024 = 1 << 20;
    let cores = explicit_cores();

    let kinds: &[CcKind] = if quick {
        &[CcKind::Dcqcn, CcKind::Swift]
    } else {
        &CcKind::ALL
    };
    let mut cells: Vec<BCell> = Vec::new();
    // engine-agreement grid: per CC kind, packet reference vs hybrid
    for &cc in kinds {
        for fidelity in [FidelityMode::Packet, FidelityMode::Hybrid] {
            cells.push(BCell {
                ranks: 128,
                fidelity,
                cc,
                hier: false,
                elems: elems_128,
                iters,
                cores: None,
            });
        }
    }
    // headline: 1024-rank hierarchical all-reduce, DCQCN coupled through
    // the hybrid fast path
    cells.push(BCell {
        ranks: 1024,
        fidelity: FidelityMode::Hybrid,
        cc: CcKind::Dcqcn,
        hier: true,
        elems: elems_1024,
        iters: 1,
        cores,
    });

    // satellite fix (PR10): bound sweep workers by the LARGEST cell's
    // estimated resident set — fluid tables and CC columns included
    let worst = cells
        .iter()
        .map(|c| scale_cell(c).est_cluster_bytes())
        .max()
        .unwrap_or(1);
    let jobs = jobs_bounded_by_cell_bytes(worst);

    let grid = SweepGrid::new("cc_fluid_sweep", cells).with_jobs(jobs);
    let report = grid.run(|_, cell| run_cell(cell));

    let mut table = Table::new(
        "CC-coupled fluid sweep: tail CCT by ranks x cc x fidelity",
        &[
            "ranks", "collective", "cc", "fidelity", "p50 CCT", "p99 CCT",
            "flows fluid/pkt", "cc epochs", "done",
        ],
    );
    for (cell, r) in grid.cells.iter().zip(&report.results) {
        table.row(&[
            cell.ranks.to_string(),
            if cell.hier { "AR(hier)".into() } else { "AR(ring)".to_string() },
            cell.cc.canonical_name().to_string(),
            cell.fidelity.name().to_string(),
            fmt_ns(jf(r, "p50_ns")),
            fmt_ns(jf(r, "p99_ns")),
            format!("{}/{}", jf(r, "fluid_flows") as u64, jf(r, "packet_flows") as u64),
            (jf(r, "cc_epochs") as u64).to_string(),
            if jb(r, "completed") { "yes".into() } else { "STALL".to_string() },
        ]);
    }
    table.print();

    // acceptance 1: per forced CC kind, hybrid p99 within the documented
    // 15% of the admit()-gated packet reference at 128 ranks
    let find = |cc: CcKind, fid: FidelityMode| -> f64 {
        grid.cells
            .iter()
            .zip(&report.results)
            .find(|(c, _)| c.ranks == 128 && c.cc == cc && c.fidelity == fid)
            .map(|(_, r)| jf(r, "p99_ns"))
            .unwrap_or(0.0)
    };
    let mut agree = true;
    let mut worst_ratio = 1.0f64;
    for &cc in kinds {
        let (pkt, hyb) = (find(cc, FidelityMode::Packet), find(cc, FidelityMode::Hybrid));
        if pkt > 0.0 && hyb > 0.0 {
            let ratio = hyb / pkt;
            if (ratio - 1.0).abs() > worst_ratio.max(1.0 / worst_ratio) - 1.0 {
                worst_ratio = ratio;
            }
            agree &= (0.85..=1.15).contains(&ratio);
        } else {
            agree = false;
        }
    }
    // acceptance 2: the 1024-rank CC-coupled cell completes, is genuinely
    // hybrid, and the coupled plane actually ran
    let headline = grid
        .cells
        .iter()
        .zip(&report.results)
        .filter(|(c, _)| c.ranks == 1024)
        .all(|(_, r)| {
            jb(r, "completed")
                && jf(r, "fluid_flows") > 0.0
                && jf(r, "packet_flows") > 0.0
                && jf(r, "cc_epochs") > 0.0
        });

    println!(
        "\ncc_fluid_sweep: {} cells, wall {} on {} jobs | 1024-rank CC-coupled completes: {} | hybrid-vs-packet p99 within 15% for every CC: {} (worst {:.3}x)",
        report.results.len(),
        fmt_ns(report.wall_ns),
        report.jobs,
        if headline { "YES" } else { "NO" },
        if agree { "YES" } else { "NO" },
        worst_ratio,
    );

    let mut out = Json::obj();
    out.set("bench", "cc_fluid_sweep (PR10)");
    out.set("quick_mode", quick);
    out.set(
        "workload",
        format!(
            "fat-tree all-reduce, forced CC x fidelity, {} iters",
            iters
        ),
    );
    for (cell, r) in grid.cells.iter().zip(&report.results) {
        out.set(
            &format!(
                "{}/{}/{}/{}",
                cell.ranks,
                if cell.hier { "hier" } else { "ring" },
                cell.cc.canonical_name(),
                cell.fidelity.name(),
            ),
            r.clone(),
        );
    }
    out.set("cells", report.results.len())
        .set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs)
        .set("cores", cores.unwrap_or(1))
        .set("worst_cell_est_bytes", worst)
        .set("headline_1024_cc_coupled_completes", headline)
        .set("cc_agreement_within_tolerance", agree)
        .set("worst_p99_ratio", worst_ratio);
    save_results("BENCH_PR10", out);
}
