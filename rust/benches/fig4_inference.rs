//! Fig 4 reproduction: inference accuracy (a), throughput (b), and TTFT
//! tail (c) across transports and environments.

use optinic::coordinator::{EnvKind, ServeCfg, Server};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, save_results, Table};
use optinic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let envs = [EnvKind::CloudLab8, EnvKind::Hyperstack4, EnvKind::Hyperstack8];
    let model = "tiny";
    let requests = 32;

    let mut table = Table::new(
        "Fig 4: inference serving across transports",
        &[
            "environment",
            "transport",
            "acc (lossy)",
            "acc (clean)",
            "tok/s",
            "TTFT mean",
            "TTFT p99",
        ],
    );
    let mut out = Json::obj();
    for env in envs {
        let mut rows = vec![];
        for transport in [TransportKind::Roce, TransportKind::Optinic] {
            let mut engine = Engine::load_default()?;
            let mut cfg = ServeCfg::new(model, env, transport);
            cfg.num_requests = requests;
            cfg.bg_load = 0.2;
            let mut res = Server::new(cfg, &mut engine)?.run()?;
            table.row(&[
                env.name().to_string(),
                transport.name().to_string(),
                format!("{:.3}", res.lossy_accuracy),
                format!("{:.3}", res.clean_accuracy),
                format!("{:.0}", res.throughput_tps()),
                fmt_ns(res.ttft_ns.mean()),
                fmt_ns(res.ttft_ns.p99()),
            ]);
            rows.push((
                transport,
                res.throughput_tps(),
                res.ttft_ns.p99(),
                res.lossy_accuracy,
            ));
        }
        let (_, tput_r, p99_r, _) = rows[0];
        let (_, tput_o, p99_o, _) = rows[1];
        let mut e = Json::obj();
        e.set("throughput_gain", tput_o / tput_r)
            .set("p99_ttft_reduction", p99_r / p99_o);
        out.set(env.name(), e);
        println!(
            "{}: throughput {:+.0}% | p99 TTFT {:.2}x lower (paper: +28–60%, 2–3.5x)",
            env.name(),
            (tput_o / tput_r - 1.0) * 100.0,
            p99_r / p99_o
        );
    }
    table.print();
    save_results("fig4_inference", out);
    Ok(())
}
