//! Fig 4 reproduction: inference accuracy (a), throughput (b), and TTFT
//! tail (c) across transports and environments.
//!
//! The environment × transport grid runs through the multicore sweep
//! runner; each cell owns its Engine + Server.

use optinic::coordinator::{EnvKind, ServeCfg, Server};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, jf, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

fn main() -> anyhow::Result<()> {
    let envs = [EnvKind::CloudLab8, EnvKind::Hyperstack4, EnvKind::Hyperstack8];
    let transports = [TransportKind::Roce, TransportKind::Optinic];
    let model = "tiny";
    let requests = 32;

    // grid order: environment ▸ transport
    let mut cells = Vec::new();
    for env in envs {
        for transport in transports {
            cells.push((env, transport));
        }
    }
    let grid = SweepGrid::new("fig4", cells).with_jobs(jobs_from_args());
    let report = grid.try_run(|_, &(env, transport)| -> anyhow::Result<Json> {
        let mut engine = Engine::load_default()?;
        let mut cfg = ServeCfg::new(model, env, transport);
        cfg.num_requests = requests;
        cfg.bg_load = 0.2;
        let mut res = Server::new(cfg, &mut engine)?.run()?;
        let mut e = Json::obj();
        e.set("lossy", res.lossy_accuracy as f64)
            .set("clean", res.clean_accuracy as f64)
            .set("tput_tps", res.throughput_tps())
            .set("ttft_mean_ns", res.ttft_ns.mean())
            .set("ttft_p99_ns", res.ttft_ns.p99());
        Ok(e)
    })?;

    let mut table = Table::new(
        "Fig 4: inference serving across transports",
        &[
            "environment",
            "transport",
            "acc (lossy)",
            "acc (clean)",
            "tok/s",
            "TTFT mean",
            "TTFT p99",
        ],
    );
    let mut out = Json::obj();
    for (i, env) in envs.iter().enumerate() {
        let pair = &report.results[2 * i..2 * i + 2];
        for (r, transport) in pair.iter().zip(transports) {
            table.row(&[
                env.name().to_string(),
                transport.name().to_string(),
                format!("{:.3}", jf(r, "lossy")),
                format!("{:.3}", jf(r, "clean")),
                format!("{:.0}", jf(r, "tput_tps")),
                fmt_ns(jf(r, "ttft_mean_ns")),
                fmt_ns(jf(r, "ttft_p99_ns")),
            ]);
        }
        let (tput_r, p99_r) = (jf(&pair[0], "tput_tps"), jf(&pair[0], "ttft_p99_ns"));
        let (tput_o, p99_o) = (jf(&pair[1], "tput_tps"), jf(&pair[1], "ttft_p99_ns"));
        let mut e = Json::obj();
        e.set("throughput_gain", tput_o / tput_r)
            .set("p99_ttft_reduction", p99_r / p99_o);
        out.set(env.name(), e);
        println!(
            "{}: throughput {:+.0}% | p99 TTFT {:.2}x lower (paper: +28–60%, 2–3.5x)",
            env.name(),
            (tput_o / tput_r - 1.0) * 100.0,
            p99_r / p99_o
        );
    }
    table.print();
    out.set("jobs", report.jobs);
    save_results("fig4_inference", out);
    Ok(())
}
