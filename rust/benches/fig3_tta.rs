//! Fig 3 reproduction: end-to-end convergence / time-to-accuracy of RoCE vs
//! OptiNIC across model tiers and cluster environments (ZeRO-3 pattern).
//!
//! The paper reports 1.6× average TTA improvement, up to 2× on 8-node
//! Hyperstack. We report the simulated-time ratio to the same accuracy.
//!
//! The panel × transport grid runs through the multicore sweep runner;
//! each cell owns its Engine + Trainer.

use optinic::coordinator::{CommPattern, EnvKind, TrainCfg, Trainer};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{jf, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

fn main() -> anyhow::Result<()> {
    // default panels/steps are trimmed for bench-suite wall-time; the
    // fuller 6-panel × 24-step sweep recorded in EXPERIMENTS.md is
    // reproduced with `--set` overrides via the launcher or by editing
    // these constants.
    let panels = [
        ("tiny", EnvKind::CloudLab8),
        ("tiny", EnvKind::Hyperstack8),
        ("small", EnvKind::Hyperstack8),
        ("medium", EnvKind::Hyperstack8),
    ];
    let steps = 12;

    // grid order: panel ▸ (RoCE, OptiNIC) — cells are (model, env, transport)
    let mut cells = Vec::new();
    for (model, env) in panels {
        for transport in [TransportKind::Roce, TransportKind::Optinic] {
            cells.push((model, env, transport));
        }
    }
    let grid = SweepGrid::new("fig3", cells).with_jobs(jobs_from_args());
    let report = grid.try_run(|_, &(model, env, transport)| -> anyhow::Result<Json> {
        let mut engine = Engine::load_default()?;
        let mut cfg = TrainCfg::new(model, env, transport);
        cfg.steps = steps;
        cfg.eval_every = steps;
        cfg.pattern = CommPattern::Zero3;
        cfg.bg_load = 0.2;
        let r = Trainer::new(cfg, &mut engine)?.run()?;
        let mut e = Json::obj();
        e.set("sim_ns", r.total_sim_ns)
            .set("acc", r.final_accuracy as f64);
        Ok(e)
    })?;

    let mut table = Table::new(
        "Fig 3: convergence time (ZeRO-3 pattern, 20% bg traffic)",
        &[
            "model",
            "environment",
            "RoCE time",
            "OptiNIC time",
            "speedup",
            "acc RoCE",
            "acc OptiNIC",
        ],
    );
    let mut out = Json::obj();
    let mut speedups = vec![];
    for (i, (model, env)) in panels.iter().enumerate() {
        let (roce, opt) = (&report.results[2 * i], &report.results[2 * i + 1]);
        let (t_roce, t_opt) = (jf(roce, "sim_ns"), jf(opt, "sim_ns"));
        let speedup = t_roce / t_opt.max(1.0);
        speedups.push(speedup);
        table.row(&[
            model.to_string(),
            env.name().to_string(),
            optinic::sim::fmt_time(t_roce as u64),
            optinic::sim::fmt_time(t_opt as u64),
            format!("{speedup:.2}x"),
            format!("{:.3}", jf(roce, "acc")),
            format!("{:.3}", jf(opt, "acc")),
        ]);
        let mut e = Json::obj();
        e.set("roce_ns", t_roce)
            .set("optinic_ns", t_opt)
            .set("speedup", speedup)
            .set("acc_roce", jf(roce, "acc"))
            .set("acc_optinic", jf(opt, "acc"));
        out.set(&format!("{model}/{}", env.name()), e);
    }
    table.print();
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    println!("\naverage TTA speedup {avg:.2}x (paper: 1.6x); best {max:.2}x (paper: up to 2x)");
    out.set("avg_speedup", avg)
        .set("max_speedup", max)
        .set("jobs", report.jobs);
    save_results("fig3_tta", out);
    Ok(())
}
