//! Fig 3 reproduction: end-to-end convergence / time-to-accuracy of RoCE vs
//! OptiNIC across model tiers and cluster environments (ZeRO-3 pattern).
//!
//! The paper reports 1.6× average TTA improvement, up to 2× on 8-node
//! Hyperstack. We report the simulated-time ratio to the same accuracy.

use optinic::coordinator::{CommPattern, EnvKind, TrainCfg, Trainer};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{save_results, Table};
use optinic::util::json::Json;

fn main() -> anyhow::Result<()> {
    // default panels/steps are trimmed for bench-suite wall-time; the
    // fuller 6-panel × 24-step sweep recorded in EXPERIMENTS.md is
    // reproduced with `--set` overrides via the launcher or by editing
    // these constants.
    let panels = [
        ("tiny", EnvKind::CloudLab8),
        ("tiny", EnvKind::Hyperstack8),
        ("small", EnvKind::Hyperstack8),
        ("medium", EnvKind::Hyperstack8),
    ];
    let steps = 12;

    let mut table = Table::new(
        "Fig 3: convergence time (ZeRO-3 pattern, 20% bg traffic)",
        &[
            "model",
            "environment",
            "RoCE time",
            "OptiNIC time",
            "speedup",
            "acc RoCE",
            "acc OptiNIC",
        ],
    );
    let mut out = Json::obj();
    let mut speedups = vec![];
    for (model, env) in panels {
        let run = |transport| -> anyhow::Result<_> {
            let mut engine = Engine::load_default()?;
            let mut cfg = TrainCfg::new(model, env, transport);
            cfg.steps = steps;
            cfg.eval_every = steps;
            cfg.pattern = CommPattern::Zero3;
            cfg.bg_load = 0.2;
            let r = Trainer::new(cfg, &mut engine)?.run()?;
            Ok((r.total_sim_ns, r.final_accuracy))
        };
        let (t_roce, a_roce) = run(TransportKind::Roce)?;
        let (t_opt, a_opt) = run(TransportKind::Optinic)?;
        let speedup = t_roce as f64 / t_opt.max(1) as f64;
        speedups.push(speedup);
        table.row(&[
            model.to_string(),
            env.name().to_string(),
            optinic::sim::fmt_time(t_roce),
            optinic::sim::fmt_time(t_opt),
            format!("{speedup:.2}x"),
            format!("{a_roce:.3}"),
            format!("{a_opt:.3}"),
        ]);
        let mut e = Json::obj();
        e.set("roce_ns", t_roce)
            .set("optinic_ns", t_opt)
            .set("speedup", speedup)
            .set("acc_roce", a_roce as f64)
            .set("acc_optinic", a_opt as f64);
        out.set(&format!("{model}/{}", env.name()), e);
    }
    table.print();
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    println!("\naverage TTA speedup {avg:.2}x (paper: 1.6x); best {max:.2}x (paper: up to 2x)");
    out.set("avg_speedup", avg).set("max_speedup", max);
    save_results("fig3_tta", out);
    Ok(())
}
