//! CC × transport × collective grid (CC v2 acceptance bench).
//!
//! OptiNIC's decoupling claim (§3.1.3) says tail behavior is a property of
//! the *transport architecture*, not the CC algorithm riding on it. This
//! sweep forces every `CcKind` onto every transport variant and records
//! mean + tail (p99) collective completion time per cell, so the claim is
//! checked by a grid rather than asserted: over the best-effort engine the
//! tail stays flat across CC schemes, while the reliable engines keep
//! their loss-driven tails no matter which algorithm paces them.
//!
//! The grid is declared as data and executed by the deterministic
//! multicore sweep runner (`--jobs N` / `OPTINIC_JOBS`) — with ~100
//! independent cells this is the widest grid in the repo and the main
//! beneficiary of the PR4 harness. Results land in
//! `bench_results/BENCH_PR3.json` (uploaded by the CI `bench-smoke` job
//! alongside BENCH_PR2/PR4). `--quick` (or PERF_QUICK=1) shrinks the
//! grid for CI.

use optinic::cc::CcKind;
use optinic::collectives::CollectiveKind;
use optinic::net::FabricCfg;
use optinic::transport::TransportKind;
use optinic::util::bench::{
    fmt_ns, jf, quick_mode, run_collective_cell, save_results, CollectiveCell, InputSet, Table,
};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

fn main() {
    let quick = quick_mode();
    // quick: 4 nodes × 256 KB × 2 iters × 1 collective (CI smoke);
    // full: 8 nodes × 4 MB × 3 iters × 2 collectives
    let (nodes, elems, iters, collectives): (usize, usize, usize, &[CollectiveKind]) = if quick {
        (4, 64 * 1024, 2, &[CollectiveKind::AllReduceRing])
    } else {
        (
            8,
            1024 * 1024,
            3,
            &[CollectiveKind::AllReduceRing, CollectiveKind::AllGather],
        )
    };
    let mut out = Json::obj();
    out.set("bench", "cc_sweep (PR3)");
    out.set("quick_mode", quick);
    let workload = format!(
        "{} nodes x {} KB x {} iters, bg 0.2, corrupt 5e-5, topo x CC x transport grid",
        nodes,
        elems * 4 / 1024,
        iters
    );
    out.set("workload", workload);
    let topos = [false, true]; // single-switch, then leaf–spine (PR5)

    // grid order = emission order: topo ▸ collective ▸ transport ▸ CC
    let mut cells = Vec::new();
    for &leaf_spine in &topos {
        for &kind in collectives {
            for transport in TransportKind::ALL_WITH_VARIANTS {
                for cc in CcKind::ALL {
                    let mut fab = FabricCfg::cloudlab(nodes);
                    if leaf_spine {
                        fab = fab.with_leaf_spine(2, 2);
                    }
                    fab.corrupt_prob = 5e-5;
                    let mut cell = CollectiveCell::new(fab, transport, kind, elems);
                    cell.seed = 23;
                    cell.bg_load = 0.2;
                    cell.iters = iters;
                    cell.cc = Some(cc);
                    cell.exchange_stats = matches!(
                        transport,
                        TransportKind::Optinic | TransportKind::OptinicHw
                    );
                    cell.reliable = !cell.exchange_stats;
                    // cap each cell so a pathological pairing cannot hang
                    // the grid; an incomplete run is recorded, not hidden
                    cell.iter_cap_ns = 20 * optinic::sim::SEC;
                    cells.push(cell);
                }
            }
        }
    }
    let inputs = InputSet::ones(elems);
    let grid = SweepGrid::new("cc_sweep", cells).with_jobs(jobs_from_args());
    let report = grid.run(|_, cell| run_collective_cell(cell, &inputs));

    let per_kind = TransportKind::ALL_WITH_VARIANTS.len() * CcKind::ALL.len();
    let per_topo = collectives.len() * per_kind;
    for (t, &leaf_spine) in topos.iter().enumerate() {
        let topo_name = if leaf_spine { "leaf-spine" } else { "single" };
        for (k, kind) in collectives.iter().enumerate() {
            let mut table = Table::new(
                &format!(
                    "CC x transport grid: {} CCT, {} KB, {} nodes, {topo_name}",
                    kind.name(),
                    elems * 4 / 1024,
                    nodes
                ),
                &["transport", "cc", "mean CCT", "p99 CCT", "tail/mean", "ok"],
            );
            let base = t * per_topo + k * per_kind;
            for (cell, r) in grid.cells[base..base + per_kind]
                .iter()
                .zip(&report.results[base..base + per_kind])
            {
                let cc = cell.cc.unwrap();
                let (mean, p99) = (jf(r, "mean_ns"), jf(r, "p99_ns"));
                let ok = r.get("completed").and_then(Json::as_bool).unwrap_or(false);
                table.row(&[
                    cell.transport.name().to_string(),
                    cc.name().to_string(),
                    fmt_ns(mean),
                    fmt_ns(p99),
                    format!("{:.2}", p99 / mean.max(1.0)),
                    if ok { "y".into() } else { "TIMEOUT".into() },
                ]);
                let mut e = Json::obj();
                e.set("mean_ns", mean).set("p99_ns", p99).set("completed", ok);
                out.set(
                    &format!(
                        "{topo_name}/{}/{}/{}",
                        kind.name(),
                        cell.transport.canonical_name(),
                        cc.canonical_name()
                    ),
                    e,
                );
            }
            table.print();
        }
    }
    println!(
        "\ncc_sweep: {} cells ({} topos x {} collectives x {} transports x {} CCs), wall {} on {} jobs",
        report.results.len(),
        topos.len(),
        collectives.len(),
        TransportKind::ALL_WITH_VARIANTS.len(),
        CcKind::ALL.len(),
        fmt_ns(report.wall_ns),
        report.jobs
    );
    out.set("cells", report.results.len())
        .set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs);
    // the perf/acceptance artifact for this PR (bench-smoke CI job)
    save_results("BENCH_PR3", out);
}
