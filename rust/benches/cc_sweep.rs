//! CC × transport × collective grid (CC v2 acceptance bench).
//!
//! OptiNIC's decoupling claim (§3.1.3) says tail behavior is a property of
//! the *transport architecture*, not the CC algorithm riding on it. This
//! sweep forces every `CcKind` onto every transport variant and records
//! mean + tail (p99) collective completion time per cell, so the claim is
//! checked by a grid rather than asserted: over the best-effort engine the
//! tail stays flat across CC schemes, while the reliable engines keep
//! their loss-driven tails no matter which algorithm paces them.
//!
//! Results land in `bench_results/BENCH_PR3.json` (uploaded by the CI
//! `bench-smoke` job alongside BENCH_PR2). `--quick` (or PERF_QUICK=1)
//! shrinks the grid for CI.

use optinic::cc::CcKind;
use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, save_results, Table};
use optinic::util::json::Json;
use optinic::util::stats::Samples;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("PERF_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    // quick: 4 nodes × 256 KB × 2 iters × 1 collective (CI smoke);
    // full: 8 nodes × 4 MB × 3 iters × 2 collectives
    let (nodes, elems, iters, collectives): (usize, usize, usize, &[CollectiveKind]) = if quick {
        (4, 64 * 1024, 2, &[CollectiveKind::AllReduceRing])
    } else {
        (
            8,
            1024 * 1024,
            3,
            &[CollectiveKind::AllReduceRing, CollectiveKind::AllGather],
        )
    };
    let mut out = Json::obj();
    out.set("bench", "cc_sweep (PR3)");
    out.set("quick_mode", quick);
    let workload = format!(
        "{} nodes x {} KB x {} iters, bg 0.2, corrupt 5e-5, full CC x transport grid",
        nodes,
        elems * 4 / 1024,
        iters
    );
    out.set("workload", workload);
    let t0 = std::time::Instant::now();
    let mut cells = 0usize;
    for &kind in collectives {
        let mut table = Table::new(
            &format!(
                "CC x transport grid: {} CCT, {} KB, {} nodes",
                kind.name(),
                elems * 4 / 1024,
                nodes
            ),
            &["transport", "cc", "mean CCT", "p99 CCT", "tail/mean", "ok"],
        );
        for transport in TransportKind::ALL_WITH_VARIANTS {
            for cc in CcKind::ALL {
                let mut fab = FabricCfg::cloudlab(nodes);
                fab.corrupt_prob = 5e-5;
                let mut cluster = Cluster::new(
                    ClusterCfg::new(fab, transport)
                        .with_seed(23)
                        .with_bg_load(0.2)
                        .with_cc(cc),
                );
                let ws = Workspace::new(&mut cluster, elems, 1);
                let inputs: Vec<Vec<f32>> = (0..nodes).map(|_| vec![1.0f32; elems]).collect();
                let mut driver = Driver::new(1);
                let mut s = Samples::new();
                let mut all_ok = true;
                for _ in 0..iters {
                    ws.load_inputs(&mut cluster, &inputs);
                    let mut spec = CollectiveSpec::new(kind, elems);
                    if matches!(
                        transport,
                        TransportKind::Optinic | TransportKind::OptinicHw
                    ) {
                        spec.exchange_stats = true;
                    } else {
                        spec = spec.reliable();
                    }
                    // cap each cell so a pathological pairing cannot hang
                    // the grid; an incomplete run is recorded, not hidden
                    cluster.cfg.max_sim_time = cluster.time + 20 * optinic::sim::SEC;
                    let res = driver.run(&mut cluster, &ws, &spec);
                    all_ok &= res.completed;
                    s.push(res.cct_ns as f64);
                }
                cells += 1;
                table.row(&[
                    transport.name().to_string(),
                    cc.name().to_string(),
                    fmt_ns(s.mean()),
                    fmt_ns(s.p99()),
                    format!("{:.2}", s.p99() / s.mean().max(1.0)),
                    if all_ok { "y".into() } else { "TIMEOUT".into() },
                ]);
                let mut e = Json::obj();
                e.set("mean_ns", s.mean())
                    .set("p99_ns", s.p99())
                    .set("completed", all_ok);
                out.set(
                    &format!(
                        "{}/{}/{}",
                        kind.name(),
                        transport.canonical_name(),
                        cc.canonical_name()
                    ),
                    e,
                );
            }
        }
        table.print();
    }
    let wall = t0.elapsed().as_nanos() as f64;
    println!(
        "\ncc_sweep: {} cells ({} collectives x {} transports x {} CCs), wall {}",
        cells,
        collectives.len(),
        TransportKind::ALL_WITH_VARIANTS.len(),
        CcKind::ALL.len(),
        fmt_ns(wall)
    );
    out.set("cells", cells).set("sweep_wall_ns", wall);
    // the perf/acceptance artifact for this PR (bench-smoke CI job)
    save_results("BENCH_PR3", out);
}
