//! Open-loop serving grid: transport × arrival process × topology (PR 6).
//!
//! The serving subsystem's acceptance bench. Each cell stands up a
//! disaggregated prefill/decode deployment ([`optinic::serving`]), drives
//! it with open-loop multi-tenant arrivals, and reports per-tenant tail
//! latency (p50/p99/p99.9 TTFT and TPOT), queueing delay, and SLO
//! attainment — plus the KV-cache bytes migrated between the pools over
//! the simulated fabric. The question the grid answers: how much SLO
//! attainment does OptiNIC's bounded completion buy over the reliable
//! family when arrivals are bursty and the fabric is shared?
//!
//! Cells are independent and run through the deterministic multicore
//! sweep runner (`--jobs N` / `OPTINIC_JOBS`); the merged output is
//! byte-identical for any worker count (pinned by
//! `tests/determinism.rs`). `--quick` (or PERF_QUICK=1) shrinks the grid
//! for the CI bench-smoke job. Results land in
//! `bench_results/BENCH_PR6.json` alongside BENCH_PR2–PR5.

use optinic::serving::{run_serving_cell, ArrivalKind, ServingCell};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, quick_mode, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

fn main() {
    let quick = quick_mode();
    // quick: the 2×2×2 acceptance core with a small request budget;
    // full: every transport variant and a deeper queue per tenant
    let (transports, per_tenant): (&[TransportKind], usize) = if quick {
        (&[TransportKind::Optinic, TransportKind::Roce], 10)
    } else {
        (&TransportKind::ALL_WITH_VARIANTS, 24)
    };
    let arrivals = [ArrivalKind::Poisson, ArrivalKind::diurnal_default()];
    let topos = [false, true]; // single-switch, then leaf–spine

    let mut out = Json::obj();
    out.set("bench", "serve_sweep (PR6)");
    out.set("quick_mode", quick);
    out.set(
        "workload",
        format!(
            "2 tenants x {per_tenant} reqs, 400 qps aggregate, bg 0.2, \
             transport x arrival x topo grid"
        ),
    );

    // grid order = emission order: topo ▸ arrival ▸ transport
    let mut cells = Vec::new();
    for &leaf_spine in &topos {
        for &arrival in &arrivals {
            for &transport in transports {
                let mut cell = ServingCell::new(transport, arrival, leaf_spine);
                cell.requests_per_tenant = per_tenant;
                cells.push(cell);
            }
        }
    }
    let grid = SweepGrid::new("serve_sweep", cells).with_jobs(jobs_from_args());
    let report = grid.run(|_, cell| run_serving_cell(cell));

    for (t, &leaf_spine) in topos.iter().enumerate() {
        let topo_name = if leaf_spine { "leaf-spine" } else { "single-switch" };
        let mut table = Table::new(
            &format!("serving grid: {topo_name}, 400 qps aggregate, 2 tenants"),
            &[
                "transport", "arrival", "tenant", "TTFT p50", "TTFT p99", "TTFT p99.9",
                "TPOT p99", "SLO", "KV MB", "done",
            ],
        );
        let per_topo = arrivals.len() * transports.len();
        for (cell, r) in grid.cells[t * per_topo..(t + 1) * per_topo]
            .iter()
            .zip(&report.results[t * per_topo..(t + 1) * per_topo])
        {
            let slo = r.get("slo").expect("cell row has slo block");
            let kv_mb = slo.get("kv_bytes_moved").and_then(Json::as_f64).unwrap_or(0.0) / 1e6;
            let offered = slo.get("requests_offered").and_then(Json::as_i64).unwrap_or(0);
            let done = slo.get("requests_completed").and_then(Json::as_i64).unwrap_or(0);
            if let Some(Json::Arr(rows)) = slo.get("tenants") {
                for row in rows {
                    let g = |k: &str| row.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                    table.row(&[
                        cell.transport.name().to_string(),
                        cell.arrival.name().to_string(),
                        row.get("tenant")
                            .and_then(Json::as_str)
                            .unwrap_or("?")
                            .to_string(),
                        fmt_ns(g("ttft_p50_ns")),
                        fmt_ns(g("ttft_p99_ns")),
                        fmt_ns(g("ttft_p999_ns")),
                        fmt_ns(g("tpot_p99_ns")),
                        format!("{:.0}%", g("slo_attainment") * 100.0),
                        format!("{kv_mb:.2}"),
                        format!("{done}/{offered}"),
                    ]);
                }
            }
            out.set(
                &format!(
                    "{topo_name}/{}/{}",
                    cell.arrival.name(),
                    cell.transport.canonical_name()
                ),
                r.clone(),
            );
        }
        table.print();
    }
    println!(
        "\nserve_sweep: {} cells ({} topos x {} arrivals x {} transports), wall {} on {} jobs",
        report.results.len(),
        topos.len(),
        arrivals.len(),
        transports.len(),
        fmt_ns(report.wall_ns),
        report.jobs
    );
    out.set("cells", report.results.len())
        .set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs);
    // the perf/acceptance artifact for this PR (bench-smoke CI job)
    save_results("BENCH_PR6", out);
}
