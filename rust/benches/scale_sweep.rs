//! Cluster-scale sweep — the PR8 fat-tree / hybrid-fidelity scoreboard.
//!
//! Drives collective schedules through the hybrid packet/flow engine
//! (`optinic::sim::scale` over `optinic::net::flowsim`) on 3-tier
//! fat-trees, ranks × fidelity × transport:
//!
//! * quick (CI bench-smoke): 128-rank OptiNIC-vs-RoCE ring at packet and
//!   hybrid fidelity — the engine-agreement check — plus the headline
//!   1024-rank and 4096-rank hierarchical all-reduces through the hybrid
//!   fast path (`--cores N` threads the big cells' iteration-level
//!   partitioned runner; see docs/PERF.md §Partitioned engine).
//! * full: adds all-fluid cells and more iterations, up to 4096 ranks.
//!
//! Headline acceptance (docs/SCALE.md §Validation): the 1024-rank
//! fat-tree all-reduce completes through the hybrid fast path (fluid
//! bulk AND packet tail flows both in play), and hybrid tail CCT agrees
//! with the in-engine packet reference within the documented 15%
//! tolerance at 128 ranks. Results land in `bench_results/BENCH_PR8.json`.

use optinic::collectives::CollectiveKind;
use optinic::net::{FabricCfg, FidelityMode};
use optinic::sim::{run_scale_cell, ScaleCell};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, jf, quick_mode, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{explicit_cores, jobs_from_args, SweepGrid};

/// One bench cell: a fat-tree shape + engine configuration.
struct BCell {
    ranks: usize,
    fidelity: FidelityMode,
    transport: TransportKind,
    hier: bool,
    elems: usize,
    iters: usize,
    /// Worker threads for the scale cell's iteration-level partitioned
    /// runner (`ScaleCell::with_cores`) — wall-clock only, results are
    /// byte-identical for any value.
    cores: Option<usize>,
}

/// Fat-tree shapes per rank count: (pods, leaves/pod, spines/pod, core).
/// 128 = 4 pods × 4 leaves × 8 hosts; 1024 = 8 × 8 × 16;
/// 4096 = 8 pods × 16 leaves × 32 hosts.
fn shape(ranks: usize) -> (usize, usize, usize, usize) {
    match ranks {
        128 => (4, 4, 4, 8),
        1024 => (8, 8, 8, 16),
        4096 => (8, 16, 16, 32),
        other => panic!("no fat-tree shape for {other} ranks"),
    }
}

fn run_cell(c: &BCell) -> Json {
    let (pods, leaves, spines, core) = shape(c.ranks);
    let fab = FabricCfg::cloudlab(c.ranks).with_fat_tree(pods, leaves, spines, core);
    let mut cell = ScaleCell::new(fab, CollectiveKind::AllReduceRing, c.elems);
    cell.fidelity = c.fidelity;
    cell.hier = c.hier;
    cell.iters = c.iters;
    cell.seed = 11;
    cell.spray = matches!(
        c.transport,
        TransportKind::Optinic | TransportKind::OptinicHw
    );
    if let Some(n) = c.cores {
        cell = cell.with_cores(n);
    }
    let res = run_scale_cell(&cell);
    let mut o = Json::obj();
    o.set("ranks", c.ranks)
        .set("fidelity", c.fidelity.name())
        .set("transport", c.transport.name())
        .set("hier", c.hier)
        .set("mb", c.elems * 4 / (1024 * 1024))
        .set("completed", res.completed)
        .set("p50_ns", res.p50_ns)
        .set("p99_ns", res.p99_ns)
        .set("max_cct_ns", res.max_cct_ns())
        .set("flows", res.flows)
        .set("fluid_flows", res.fluid_started)
        .set("packet_flows", res.packet_started)
        .set("pkts_walked", res.pkts_walked)
        .set("resolves", res.resolves);
    o
}

fn jb(r: &Json, key: &str) -> bool {
    r.get(key).and_then(Json::as_bool).unwrap_or(false)
}

fn main() {
    let quick = quick_mode();
    let iters = if quick { 2 } else { 3 };
    // 128-rank ring: chunk = elems/128 = 256 KiB — right at the hybrid
    // bulk threshold, so hybrid runs the fluid fast path while packet
    // mode is the 64-MTU-per-flow reference the tolerance is judged on
    let elems_128 = 128 * 64 * 1024;
    // 1024-rank hierarchical: members move whole 4 MB buffers (fluid),
    // leaders ring 64 KiB chunks (packet) — genuinely hybrid
    let elems_1024 = 1 << 20;

    // 4096-rank hierarchical (PR9 acceptance): same per-member geometry
    // as 1024 ranks, four pods' worth more leaders in the top ring
    let elems_4096 = 1 << 20;
    // `--cores N` threads the big hierarchical cells' iteration-level
    // partitioned runner (wall-clock only; results byte-identical)
    let cores = explicit_cores();

    let transports = [TransportKind::Roce, TransportKind::Optinic];
    let mut cells: Vec<BCell> = Vec::new();
    // engine-agreement grid at 128 ranks: packet reference vs hybrid
    for &transport in &transports {
        for fidelity in [FidelityMode::Packet, FidelityMode::Hybrid] {
            cells.push(BCell {
                ranks: 128,
                fidelity,
                transport,
                hier: false,
                elems: elems_128,
                iters,
                cores: None,
            });
        }
    }
    // headline: 1024-rank hierarchical all-reduce on the hybrid fast path
    for &transport in &transports {
        cells.push(BCell {
            ranks: 1024,
            fidelity: FidelityMode::Hybrid,
            transport,
            hier: true,
            elems: elems_1024,
            iters: if quick { 1 } else { 2 },
            cores,
        });
    }
    // PR9 headline: 4096-rank hierarchical all-reduce completes on the
    // hybrid fast path (quick keeps one OptiNIC cell so CI still checks
    // the completes-gate; full runs both transports)
    for &transport in &transports {
        if quick && transport != TransportKind::Optinic {
            continue;
        }
        cells.push(BCell {
            ranks: 4096,
            fidelity: FidelityMode::Hybrid,
            transport,
            hier: true,
            elems: elems_4096,
            iters: if quick { 1 } else { 2 },
            cores,
        });
    }
    if !quick {
        // all-fluid contrast cells (fastest engine, loosest tails)
        for &transport in &transports {
            for &(ranks, hier, elems) in
                &[(128usize, false, elems_128), (1024, true, elems_1024)]
            {
                cells.push(BCell {
                    ranks,
                    fidelity: FidelityMode::Flow,
                    transport,
                    hier,
                    elems,
                    iters,
                    cores: None,
                });
            }
        }
    }

    let grid = SweepGrid::new("scale_sweep", cells).with_jobs(jobs_from_args());
    let report = grid.run(|_, cell| run_cell(cell));

    let mut table = Table::new(
        "Fat-tree scale sweep: tail CCT by ranks x fidelity x transport",
        &[
            "ranks", "collective", "fidelity", "transport", "p50 CCT", "p99 CCT",
            "flows fluid/pkt", "done",
        ],
    );
    for (cell, r) in grid.cells.iter().zip(&report.results) {
        table.row(&[
            cell.ranks.to_string(),
            if cell.hier { "AR(hier)".into() } else { "AR(ring)".to_string() },
            cell.fidelity.name().to_string(),
            cell.transport.name().to_string(),
            fmt_ns(jf(r, "p50_ns")),
            fmt_ns(jf(r, "p99_ns")),
            format!("{}/{}", jf(r, "fluid_flows") as u64, jf(r, "packet_flows") as u64),
            if jb(r, "completed") { "yes".into() } else { "STALL".to_string() },
        ]);
    }
    table.print();

    // acceptance 1: the 1024-rank hybrid cell completes AND is genuinely
    // hybrid (fluid bulk and packet tail flows both exercised)
    let hier_completes = |ranks: usize| {
        grid.cells
            .iter()
            .zip(&report.results)
            .filter(|(c, _)| c.ranks == ranks && c.fidelity == FidelityMode::Hybrid)
            .all(|(_, r)| {
                jb(r, "completed")
                    && jf(r, "fluid_flows") > 0.0
                    && jf(r, "packet_flows") > 0.0
            })
    };
    let headline = hier_completes(1024);
    // PR9 acceptance: the 4096-rank hierarchical all-reduce completes
    // through the same hybrid fast path
    let headline_4096 = hier_completes(4096);
    // acceptance 2: hybrid p99 within the documented 15% of the packet
    // reference per transport at 128 ranks (docs/SCALE.md §Validation)
    let find = |transport: TransportKind, fid: FidelityMode| -> f64 {
        grid.cells
            .iter()
            .zip(&report.results)
            .find(|(c, _)| c.ranks == 128 && c.transport == transport && c.fidelity == fid)
            .map(|(_, r)| jf(r, "p99_ns"))
            .unwrap_or(0.0)
    };
    let mut agree = true;
    let mut worst_ratio = 1.0f64;
    for &t in &transports {
        let (pkt, hyb) = (find(t, FidelityMode::Packet), find(t, FidelityMode::Hybrid));
        if pkt > 0.0 && hyb > 0.0 {
            let ratio = hyb / pkt;
            if (ratio - 1.0).abs() > worst_ratio.max(1.0 / worst_ratio) - 1.0 {
                worst_ratio = ratio;
            }
            agree &= (0.85..=1.15).contains(&ratio);
        } else {
            agree = false;
        }
    }

    println!(
        "\nscale_sweep: {} cells, wall {} on {} jobs | 1024-rank hybrid completes: {} | 4096-rank hybrid completes: {} | hybrid-vs-packet p99 within 15%: {} (worst {:.3}x)",
        report.results.len(),
        fmt_ns(report.wall_ns),
        report.jobs,
        if headline { "YES" } else { "NO" },
        if headline_4096 { "YES" } else { "NO" },
        if agree { "YES" } else { "NO" },
        worst_ratio,
    );

    let mut out = Json::obj();
    out.set("bench", "scale_sweep (PR8)");
    out.set("quick_mode", quick);
    out.set(
        "workload",
        format!(
            "fat-tree all-reduce, ranks x fidelity x transport, {} iters",
            iters
        ),
    );
    for (cell, r) in grid.cells.iter().zip(&report.results) {
        out.set(
            &format!(
                "{}/{}/{}/{}",
                cell.ranks,
                if cell.hier { "hier" } else { "ring" },
                cell.fidelity.name(),
                cell.transport.canonical_name(),
            ),
            r.clone(),
        );
    }
    out.set("cells", report.results.len())
        .set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs)
        .set("cores", cores.unwrap_or(1))
        .set("headline_1024_hybrid_completes", headline)
        .set("headline_4096_hybrid_completes", headline_4096)
        .set("hybrid_matches_packet_within_tolerance", agree)
        .set("worst_p99_ratio", worst_ratio);
    save_results("BENCH_PR8", out);
}
