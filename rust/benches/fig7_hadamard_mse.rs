//! Fig 7 reproduction: error of the recovery configurations under packet
//! drops — Raw, full-message Hadamard, block Hadamard, block+stride — and
//! the stride sweep.
//!
//! Reproduction note (recorded in EXPERIMENTS.md): for an orthonormal
//! transform, the *expected* MSE under uniform random packet drops is
//! invariant (Parseval), so the paper's separation must live in the error
//! *distribution*. Real gradients have spatially clustered energy
//! (embedding rows, attention heads); a Raw drop can wipe a high-energy
//! span whole, while the Hadamard equalizes per-packet energy. We therefore
//! generate gradient-like tensors (background noise + contiguous
//! high-energy regions) and report tail MSE (p95 across drop patterns) and
//! worst single-element error — the quantities §3.2's "disproportionately
//! affects model quality" is about. The orderings match Fig 7: Raw worst,
//! HD:Blk catastrophic for hit blocks, HD:Blk+Str ≈ HD:Msg near-ideal.

use optinic::recovery::{decode, drop_packets, encode, mse, Codec};
use optinic::util::bench::{jf, save_results, Table};
use optinic::util::json::Json;
use optinic::util::prng::Pcg64;
use optinic::util::stats::Samples;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

/// Gradient-like tensor: low background noise with a few contiguous
/// high-energy regions (the embedding-row / head-gradient structure).
fn gradient_like(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::seeded(seed);
    let mut x: Vec<f32> = (0..n).map(|_| 0.02 * rng.normal() as f32).collect();
    // 4 hot regions, each 2% of the tensor, holding most of the energy
    for _ in 0..4 {
        let start = rng.index(n - n / 50);
        for v in &mut x[start..start + n / 50] {
            *v = rng.normal() as f32;
        }
    }
    x
}

struct Scores {
    mean_mse: f64,
    p95_mse: f64,
    worst_elem: f64,
}

fn run(x: &[f32], codec: Codec, pkt_elems: usize, rate: f64, trials: u64) -> Scores {
    let mut mses = Samples::new();
    let mut worst = 0.0f64;
    for t in 0..trials {
        let mut wire = encode(x, codec);
        let mut rng = Pcg64::new(9_000 + t, 7);
        drop_packets(&mut wire, pkt_elems, rate, &mut rng);
        let back = decode(&wire, codec, x.len());
        mses.push(mse(x, &back));
        let w = x
            .iter()
            .zip(back.iter())
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        worst = worst.max(w);
    }
    Scores {
        mean_mse: mses.mean(),
        p95_mse: mses.percentile(95.0),
        worst_elem: worst,
    }
}

fn main() {
    let p = 256;
    let n = 256 * p;
    let x = gradient_like(n, 5);
    let trials = 40;
    let jobs = jobs_from_args();

    // ---- (a): configurations under 2% and 5% drops -----------------------------
    // rate × codec grid through the sweep runner; cells are pure
    // functions of (x, spec) — the drop-pattern RNG is seeded per trial,
    // so merged results are byte-identical for any --jobs
    let configs = [
        Codec::Raw,
        Codec::HadamardMsg,
        Codec::HadamardBlock { p },
        Codec::HadamardBlockStride { p, stride: p },
    ];
    let rates = [0.02, 0.05];
    let mut cells = Vec::new();
    for rate in rates {
        for codec in configs {
            cells.push((rate, codec));
        }
    }
    let grid_a = SweepGrid::new("fig7a", cells).with_jobs(jobs);
    let rep_a = grid_a.run(|_, &(rate, codec)| {
        let s = run(&x, codec, p, rate, trials);
        let mut e = Json::obj();
        e.set("mean_mse", s.mean_mse)
            .set("p95_mse", s.p95_mse)
            .set("worst_elem", s.worst_elem);
        e
    });

    let mut out = Json::obj();
    for (i, rate) in rates.iter().enumerate() {
        let mut ta = Table::new(
            &format!("Fig 7a: recovery error at {:.0}% drops (gradient-like tensor)", rate * 100.0),
            &["config", "mean MSE", "p95 MSE", "worst |elem err|"],
        );
        let base = i * configs.len();
        for ((_, codec), r) in grid_a.cells[base..base + configs.len()]
            .iter()
            .zip(&rep_a.results[base..base + configs.len()])
        {
            ta.row(&[
                codec.name(),
                format!("{:.3e}", jf(r, "mean_mse")),
                format!("{:.3e}", jf(r, "p95_mse")),
                format!("{:.3}", jf(r, "worst_elem")),
            ]);
            out.set(&format!("{}@{rate}", codec.name()), r.clone());
        }
        ta.print();
    }

    // ---- (b): stride sweep -------------------------------------------------------
    let mut strides = Vec::new();
    let mut s = 1;
    while s <= p {
        strides.push(s);
        s *= 4;
    }
    let grid_b = SweepGrid::new("fig7b", strides).with_jobs(jobs);
    let rep_b = grid_b.run(|_, &stride| {
        let sc = run(&x, Codec::HadamardBlockStride { p, stride }, p, 0.05, trials);
        let mut e = Json::obj();
        e.set("p95_mse", sc.p95_mse).set("worst_elem", sc.worst_elem);
        e
    });
    let mut tb = Table::new(
        "Fig 7b: error vs stride (block Hadamard, 5% drop)",
        &["stride S", "p95 MSE", "worst |elem err|"],
    );
    let mut strides_out = Json::obj();
    for (stride, r) in grid_b.cells.iter().zip(&rep_b.results) {
        tb.row(&[
            stride.to_string(),
            format!("{:.3e}", jf(r, "p95_mse")),
            format!("{:.3}", jf(r, "worst_elem")),
        ]);
        strides_out.set(&stride.to_string(), jf(r, "p95_mse"));
    }
    tb.print();
    out.set("stride_sweep_p95", strides_out);
    out.set("jobs", rep_a.jobs);
    println!("\npaper shape: Raw/HD:Blk concentrate damage (huge worst-element error);");
    println!("striding disperses it; maximal stride ≈ full-message transform.");
    save_results("fig7_hadamard_mse", out);
}
