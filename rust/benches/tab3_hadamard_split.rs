//! Table 3 reproduction: Hadamard transform runtime across split counts for
//! a 128 MB message. Paper (GPU): 1 split = 22.1 ms, 64 splits = 8.4 ms —
//! 2.5× faster, motivating block-wise processing.
//!
//! We time both the native hot-path FWHT and (for registered shapes) the
//! L1 Pallas kernel through PJRT. The *trend* — runtime dropping as splits
//! increase — is the reproduced result; absolute times are CPU-scale.
//!
//! Both grids are declared through the sweep runner but marked
//! [`SweepGrid::serial`]: these cells measure host wall time, and
//! concurrent CPU-bound timing cells would contend for cores/memory
//! bandwidth and corrupt each other's numbers (docs/PERF.md §Parallel
//! sweeps).

use std::sync::Mutex;

use optinic::recovery::hadamard::fwht_blocks;
use optinic::runtime::Engine;
use optinic::util::bench::{fmt_ns, jf, save_results, time_fn, Table};
use optinic::util::json::Json;
use optinic::util::prng::Pcg64;
use optinic::util::sweep::SweepGrid;

fn main() {
    let total_elems = 128 * 1024 * 1024 / 4; // 128 MB of f32
    let splits = [1usize, 4, 16, 64];
    // one shared timing buffer behind a lock: serial execution means no
    // contention, and the transform's runtime is content-independent
    let mut rng = Pcg64::seeded(3);
    let data: Mutex<Vec<f32>> =
        Mutex::new((0..total_elems).map(|_| rng.normal() as f32).collect());

    let grid = SweepGrid::new("tab3", splits.to_vec()).serial();
    let report = grid.run(|_, &k| {
        let p = (total_elems / k).next_power_of_two() / 2; // ≤ n/k, pow2
        let p = p.min(total_elems / k);
        let mut buf = data.lock().unwrap();
        let m = time_fn(&format!("split{k}"), 1, 3, || {
            fwht_blocks(&mut buf[..p * k], p);
        });
        let mut e = Json::obj();
        e.set("mean_ns", m.mean_ns)
            .set("std_ns", m.std_ns)
            .set("block", p);
        e
    });

    let mut table = Table::new(
        "Table 3: Hadamard runtime vs split count (128 MB message, native FWHT)",
        &["splits", "block size", "mean", "std", "vs 1 split"],
    );
    let mut out = Json::obj();
    let base = jf(&report.results[0], "mean_ns");
    for (k, r) in grid.cells.iter().zip(&report.results) {
        table.row(&[
            k.to_string(),
            (jf(r, "block") as u64).to_string(),
            fmt_ns(jf(r, "mean_ns")),
            fmt_ns(jf(r, "std_ns")),
            format!("{:.2}x", base / jf(r, "mean_ns")),
        ]);
        out.set(&k.to_string(), r.clone());
    }
    table.print();
    println!("paper: 64 splits run 2.5x faster than the monolithic transform.");

    // the L1 Pallas kernel through PJRT for its registered shapes. This
    // stays a plain sequential loop (not a sweep grid): it threads one
    // `&mut Engine` through every shape — the engine caches compiled
    // executables and the optional XLA client is not a `Send` type.
    match Engine::load_default() {
        Ok(mut engine) => {
            let mut t2 = Table::new(
                "L1 Pallas kernel via PJRT (AOT'd shapes)",
                &["shape", "mean", "GB/s"],
            );
            for (rows, p) in engine.hadamard_shapes() {
                let input: Vec<f32> = (0..rows * p).map(|i| (i as f32).sin()).collect();
                let m = time_fn(&format!("hadamard {rows}x{p}"), 1, 5, || {
                    let _ = engine.hadamard(rows, p, &input).unwrap();
                });
                let bytes = (rows * p * 4 * 2) as f64; // read + write
                t2.row(&[
                    format!("{rows}x{p}"),
                    fmt_ns(m.mean_ns),
                    format!("{:.2}", bytes / m.mean_ns),
                ]);
            }
            t2.print();
        }
        Err(e) => println!("(skipping PJRT kernel timing: {e})"),
    }
    save_results("tab3_hadamard_split", out);
}
