//! Fig 2 reproduction: training and inference accuracy remain stable under
//! partial network drops (≤ 5%).
//!
//! (a) train the model under forced packet-drop rates and report final
//!     held-out accuracy; (b) serve it and compare lossy-vs-clean accuracy.
//! Also exercises §5.2.1's regularization note: small random drops may
//! *slightly* improve generalization.

use optinic::coordinator::{CommPattern, EnvKind, ServeCfg, Server, TrainCfg, Trainer};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{save_results, Table};
use optinic::util::json::Json;

fn main() -> anyhow::Result<()> {
    let drops = [0.0, 0.01, 0.02, 0.05];
    let model = "tiny";
    let steps = 20;

    let mut table = Table::new(
        "Fig 2a: training accuracy vs drop rate (OptiNIC, tiny model)",
        &["drop %", "final loss", "final eval acc", "measured data loss %"],
    );
    let mut results = Json::obj();
    let mut train_rows = vec![];
    for &drop in &drops {
        let mut engine = Engine::load_default()?;
        let mut cfg = TrainCfg::new(model, EnvKind::Hyperstack4, TransportKind::Optinic);
        cfg.steps = steps;
        cfg.eval_every = steps;
        cfg.pattern = CommPattern::DataParallel;
        cfg.bg_load = 0.0;
        cfg.corrupt_prob = Some(drop);
        let res = Trainer::new(cfg, &mut engine)?.run()?;
        let final_loss = res.records.last().unwrap().train_loss;
        table.row(&[
            format!("{:.0}", drop * 100.0),
            format!("{final_loss:.4}"),
            format!("{:.3}", res.final_accuracy),
            format!("{:.2}", res.total_loss_fraction * 100.0),
        ]);
        train_rows.push((drop, res.final_accuracy));
    }
    table.print();

    // stability check: accuracy at 5% drop within a few points of lossless
    let base = train_rows[0].1;
    let worst = train_rows.iter().map(|r| r.1).fold(f32::INFINITY, f32::min);
    println!(
        "\ntraining-accuracy spread across ≤5% drops: {:.3} (paper: stable)",
        base - worst
    );

    let mut t2 = Table::new(
        "Fig 2b: inference accuracy vs drop rate (lossy vs clean logits path)",
        &["drop %", "acc (lossy)", "acc (clean)", "delta"],
    );
    let mut infer_rows = vec![];
    for &drop in &drops {
        let mut engine = Engine::load_default()?;
        let mut cfg = ServeCfg::new(model, EnvKind::Hyperstack4, TransportKind::Optinic);
        cfg.num_requests = 24;
        cfg.decode_tokens = 1;
        cfg.bg_load = 0.0;
        cfg.corrupt_prob = Some(drop);
        let res = Server::new(cfg, &mut engine)?.run()?;
        t2.row(&[
            format!("{:.0}", drop * 100.0),
            format!("{:.3}", res.lossy_accuracy),
            format!("{:.3}", res.clean_accuracy),
            format!("{:+.3}", res.lossy_accuracy - res.clean_accuracy),
        ]);
        infer_rows.push((drop, res.lossy_accuracy, res.clean_accuracy));
    }
    t2.print();

    results.set(
        "train",
        Json::Arr(
            train_rows
                .iter()
                .map(|(d, a)| {
                    let mut e = Json::obj();
                    e.set("drop", *d).set("acc", *a as f64);
                    e
                })
                .collect(),
        ),
    );
    results.set(
        "infer",
        Json::Arr(
            infer_rows
                .iter()
                .map(|(d, l, c)| {
                    let mut e = Json::obj();
                    e.set("drop", *d).set("lossy", *l).set("clean", *c);
                    e
                })
                .collect(),
        ),
    );
    save_results("fig2_loss_tolerance", results);
    Ok(())
}
