//! Fig 2 reproduction: training and inference accuracy remain stable under
//! partial network drops (≤ 5%).
//!
//! (a) train the model under forced packet-drop rates and report final
//!     held-out accuracy; (b) serve it and compare lossy-vs-clean accuracy.
//! Also exercises §5.2.1's regularization note: small random drops may
//! *slightly* improve generalization.
//!
//! Both drop-rate grids run through the multicore sweep runner: each
//! cell owns its Engine + Trainer/Server, so cells are independent and
//! the merged rows are byte-identical for any `--jobs`.

use optinic::coordinator::{CommPattern, EnvKind, ServeCfg, Server, TrainCfg, Trainer};
use optinic::runtime::Engine;
use optinic::transport::TransportKind;
use optinic::util::bench::{jf, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

fn main() -> anyhow::Result<()> {
    let drops = [0.0, 0.01, 0.02, 0.05];
    let model = "tiny";
    let steps = 20;
    let jobs = jobs_from_args();

    // ---- (a) training accuracy vs drop rate -------------------------------
    let train_grid = SweepGrid::new("fig2a", drops.to_vec()).with_jobs(jobs);
    let train = train_grid.try_run(|_, &drop| -> anyhow::Result<Json> {
        let mut engine = Engine::load_default()?;
        let mut cfg = TrainCfg::new(model, EnvKind::Hyperstack4, TransportKind::Optinic);
        cfg.steps = steps;
        cfg.eval_every = steps;
        cfg.pattern = CommPattern::DataParallel;
        cfg.bg_load = 0.0;
        cfg.corrupt_prob = Some(drop);
        let res = Trainer::new(cfg, &mut engine)?.run()?;
        let mut e = Json::obj();
        e.set("drop", drop)
            .set("final_loss", res.records.last().unwrap().train_loss as f64)
            .set("acc", res.final_accuracy as f64)
            .set("measured_loss_pct", res.total_loss_fraction * 100.0);
        Ok(e)
    })?;

    let mut table = Table::new(
        "Fig 2a: training accuracy vs drop rate (OptiNIC, tiny model)",
        &["drop %", "final loss", "final eval acc", "measured data loss %"],
    );
    for r in &train.results {
        table.row(&[
            format!("{:.0}", jf(r, "drop") * 100.0),
            format!("{:.4}", jf(r, "final_loss")),
            format!("{:.3}", jf(r, "acc")),
            format!("{:.2}", jf(r, "measured_loss_pct")),
        ]);
    }
    table.print();

    // stability check: accuracy at 5% drop within a few points of lossless
    let accs: Vec<f64> = train.results.iter().map(|r| jf(r, "acc")).collect();
    let base = accs[0];
    let worst = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "\ntraining-accuracy spread across ≤5% drops: {:.3} (paper: stable)",
        base - worst
    );

    // ---- (b) inference accuracy vs drop rate ------------------------------
    let infer_grid = SweepGrid::new("fig2b", drops.to_vec()).with_jobs(jobs);
    let infer = infer_grid.try_run(|_, &drop| -> anyhow::Result<Json> {
        let mut engine = Engine::load_default()?;
        let mut cfg = ServeCfg::new(model, EnvKind::Hyperstack4, TransportKind::Optinic);
        cfg.num_requests = 24;
        cfg.decode_tokens = 1;
        cfg.bg_load = 0.0;
        cfg.corrupt_prob = Some(drop);
        let res = Server::new(cfg, &mut engine)?.run()?;
        let mut e = Json::obj();
        e.set("drop", drop)
            .set("lossy", res.lossy_accuracy as f64)
            .set("clean", res.clean_accuracy as f64);
        Ok(e)
    })?;

    let mut t2 = Table::new(
        "Fig 2b: inference accuracy vs drop rate (lossy vs clean logits path)",
        &["drop %", "acc (lossy)", "acc (clean)", "delta"],
    );
    for r in &infer.results {
        t2.row(&[
            format!("{:.0}", jf(r, "drop") * 100.0),
            format!("{:.3}", jf(r, "lossy")),
            format!("{:.3}", jf(r, "clean")),
            format!("{:+.3}", jf(r, "lossy") - jf(r, "clean")),
        ]);
    }
    t2.print();

    let mut results = Json::obj();
    results.set("train", Json::Arr(train.results.clone()));
    results.set("infer", Json::Arr(infer.results.clone()));
    results.set("jobs", train.jobs);
    save_results("fig2_loss_tolerance", results);
    Ok(())
}
