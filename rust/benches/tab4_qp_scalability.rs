//! Table 4 reproduction: NIC state per QP, max QPs in a 4 MiB SRAM budget,
//! and supportable cluster size, for every transport.
//!
//! The transport grid runs through the multicore sweep runner (cells are
//! pure hardware-model evaluations; merged rows are byte-identical for
//! any `--jobs`).

use optinic::hw::qp_state;
use optinic::transport::TransportKind;
use optinic::util::bench::{jf, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

/// Paper's Table 4 rows for comparison.
const PAPER: [(&str, usize, &str, &str); 6] = [
    ("RoCE", 407, "10K", "5K"),
    ("IRN", 596, "8K", "4K"),
    ("SRNIC", 242, "20K", "10K"),
    ("Falcon", 350, "12K", "6K"),
    ("UCCL", 407, "10K", "256"),
    ("OptiNIC", 52, "80K", "40K"),
];

fn main() {
    let grid = SweepGrid::new("tab4", TransportKind::ALL.to_vec()).with_jobs(jobs_from_args());
    let report = grid.run(|_, &kind| {
        let mut e = Json::obj();
        e.set("state_bytes", qp_state::breakdown(kind).total())
            .set("max_qps", qp_state::max_qps(kind))
            .set("cluster", qp_state::cluster_size(kind));
        e
    });

    let mut table = Table::new(
        "Table 4: transport scalability (measured | paper)",
        &[
            "transport",
            "state/QP (B)",
            "paper",
            "max QPs",
            "paper",
            "cluster",
            "paper",
        ],
    );
    let mut out = Json::obj();
    for (i, (kind, r)) in grid.cells.iter().zip(&report.results).enumerate() {
        let (pname, pstate, pqps, pcluster) = PAPER[i];
        assert_eq!(pname, kind.name());
        let cluster = jf(r, "cluster") as u64;
        table.row(&[
            kind.name().to_string(),
            (jf(r, "state_bytes") as u64).to_string(),
            pstate.to_string(),
            format!("{:.1}K", jf(r, "max_qps") / 1000.0),
            pqps.to_string(),
            if cluster >= 1000 {
                format!("{:.1}K", cluster as f64 / 1000.0)
            } else {
                cluster.to_string()
            },
            pcluster.to_string(),
        ]);
        out.set(kind.name(), r.clone());
    }
    table.print();

    println!("\nOptiNIC per-QP context breakdown:");
    for c in qp_state::breakdown(TransportKind::Optinic).components {
        println!("  {:<45} {:>3} B", c.name, c.bytes);
    }
    save_results("tab4_qp_scalability", out);
}
