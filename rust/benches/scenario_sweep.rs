//! Adversarial scenario grid — the PR7 resilience scoreboard.
//!
//! Runs the full burst/fault scenario catalog (`optinic::scenarios`)
//! against the transport families and reports, per cell: completions,
//! tail CCT and its delta vs the same cell's no-adversary baseline, the
//! TTA proxy (total communication time the step sequence paid), stalled
//! QPs, bytes lost, fault accounting (scheduled vs injected), and
//! recovery time after the last network fault. The headline acceptance
//! row: under rolling spine faults + SEU barrage, OptiNIC completes
//! every cell that stalls RoCE.
//!
//! Executed by the deterministic multicore sweep runner (`--jobs N` /
//! `OPTINIC_JOBS`); `--quick` (or PERF_QUICK=1) shrinks the grid for the
//! CI bench-smoke job. Results land in `bench_results/BENCH_PR7.json`.

use optinic::cc::CcKind;
use optinic::scenarios::{run_scenario_cell, ScenarioCell, ScenarioKind};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, jf, quick_mode, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

fn main() {
    let quick = quick_mode();
    // quick: leaf-spine only, RoCE vs OptiNIC, default CC (CI smoke);
    // full: both topologies, four transports, default CC + forced DBLP
    let (topos, transports, ccs, iters): (&[bool], &[TransportKind], &[Option<CcKind>], usize) =
        if quick {
            (
                &[true],
                &[TransportKind::Roce, TransportKind::Optinic],
                &[None],
                2,
            )
        } else {
            (
                &[false, true],
                &[
                    TransportKind::Roce,
                    TransportKind::Irn,
                    TransportKind::Optinic,
                    TransportKind::OptinicHw,
                ],
                &[None, Some(CcKind::Dblp)],
                3,
            )
        };
    let elems = 16 * 1024;

    let mut out = Json::obj();
    out.set("bench", "scenario_sweep (PR7)");
    out.set("quick_mode", quick);
    out.set(
        "workload",
        format!(
            "scenario x transport x cc grid, 4 nodes x {} KB x {} iters, bg 0.2",
            elems * 4 / 1024,
            iters
        ),
    );

    // grid order = emission order: topo ▸ scenario ▸ transport ▸ CC
    let mut cells = Vec::new();
    for &leaf_spine in topos {
        for scenario in ScenarioKind::ALL {
            for &transport in transports {
                for &cc in ccs {
                    let mut cell = ScenarioCell::new(scenario, transport, leaf_spine);
                    cell.cc = cc;
                    cell.elems = elems;
                    cell.iters = iters;
                    cells.push(cell);
                }
            }
        }
    }
    let grid = SweepGrid::new("scenario_sweep", cells).with_jobs(jobs_from_args());
    let report = grid.run(|_, cell| run_scenario_cell(cell));

    // baseline p99 per (topo, transport, cc) — the delta denominator
    let baseline_p99 = |topo: bool, transport: TransportKind, cc: Option<CcKind>| -> f64 {
        grid.cells
            .iter()
            .zip(&report.results)
            .find(|(c, _)| {
                c.scenario == ScenarioKind::Baseline
                    && c.leaf_spine == topo
                    && c.transport == transport
                    && c.cc == cc
            })
            .map(|(_, r)| jf(r, "p99_ns"))
            .unwrap_or(0.0)
    };

    let per_topo = ScenarioKind::ALL.len() * transports.len() * ccs.len();
    for (t, &leaf_spine) in topos.iter().enumerate() {
        let topo_name = if leaf_spine { "leaf-spine" } else { "single" };
        let mut table = Table::new(
            &format!(
                "Resilience scoreboard: {topo_name}, 4 nodes x {} KB x {} iters",
                elems * 4 / 1024,
                iters
            ),
            &[
                "scenario", "transport", "cc", "done", "p99 CCT", "vs base", "stall",
                "lost B", "flt s/i", "recover",
            ],
        );
        let base = t * per_topo;
        for (cell, r) in grid.cells[base..base + per_topo]
            .iter()
            .zip(&report.results[base..base + per_topo])
        {
            let p99 = jf(r, "p99_ns");
            let bp = baseline_p99(cell.leaf_spine, cell.transport, cell.cc);
            let done = r
                .get("completed_all")
                .and_then(Json::as_bool)
                .unwrap_or(false);
            let stalled = r.get("stalled_qps").and_then(Json::as_i64).unwrap_or(0);
            let sched = r
                .get("faults_scheduled")
                .and_then(Json::as_i64)
                .unwrap_or(0);
            let inj = r.get("faults_injected").and_then(Json::as_i64).unwrap_or(0);
            let recovery = jf(r, "recovery_ns");
            table.row(&[
                cell.scenario.name().to_string(),
                cell.transport.name().to_string(),
                cell.cc.map(|c| c.name().to_string()).unwrap_or("def".into()),
                if done {
                    format!("{}/{}", cell.iters, cell.iters)
                } else {
                    format!(
                        "{}/{} STALL",
                        r.get("completions").and_then(Json::as_i64).unwrap_or(0),
                        cell.iters
                    )
                },
                fmt_ns(p99),
                if bp > 0.0 && p99 > 0.0 {
                    format!("{:.2}x", p99 / bp)
                } else {
                    "-".into()
                },
                stalled.to_string(),
                r.get("bytes_lost")
                    .and_then(Json::as_i64)
                    .unwrap_or(0)
                    .to_string(),
                format!("{sched}/{inj}"),
                if recovery > 0.0 {
                    fmt_ns(recovery)
                } else {
                    "-".into()
                },
            ]);
            let mut e = Json::obj();
            e.set("completed_all", done)
                .set("completions", r.get("completions").cloned().unwrap_or(Json::Null))
                .set("p99_ns", p99)
                .set("p99_vs_baseline", if bp > 0.0 { p99 / bp } else { 0.0 })
                .set("tta_proxy_ns", jf(r, "tta_proxy_ns"))
                .set("stalled_qps", stalled as u64)
                .set(
                    "bytes_lost",
                    r.get("bytes_lost").and_then(Json::as_i64).unwrap_or(0) as u64,
                )
                .set("faults_scheduled", sched as u64)
                .set("faults_injected", inj as u64)
                .set("recovery_ns", recovery)
                .set(
                    "spine_plan",
                    r.get("spine_plan")
                        .and_then(Json::as_str)
                        .unwrap_or("n/a"),
                );
            out.set(
                &format!(
                    "{topo_name}/{}/{}/{}",
                    cell.scenario.name(),
                    cell.transport.canonical_name(),
                    cell.cc.map(|c| c.canonical_name()).unwrap_or("default")
                ),
                e,
            );
        }
        table.print();
    }

    // headline acceptance line: every storm cell RoCE stalls on, OptiNIC
    // completes (docs/SCENARIOS.md §Acceptance)
    let storm_ok = grid
        .cells
        .iter()
        .zip(&report.results)
        .filter(|(c, r)| {
            c.transport == TransportKind::Roce
                && matches!(
                    c.scenario,
                    ScenarioKind::RollingSpineFaults | ScenarioKind::PerfectStorm
                )
                && !r
                    .get("completed_all")
                    .and_then(Json::as_bool)
                    .unwrap_or(false)
        })
        .all(|(c, _)| {
            grid.cells
                .iter()
                .zip(&report.results)
                .find(|(oc, _)| {
                    oc.transport == TransportKind::Optinic
                        && oc.scenario == c.scenario
                        && oc.leaf_spine == c.leaf_spine
                        && oc.cc == c.cc
                })
                .map(|(_, or)| {
                    or.get("completed_all")
                        .and_then(Json::as_bool)
                        .unwrap_or(false)
                })
                .unwrap_or(true)
        });
    println!(
        "\nscenario_sweep: {} cells, wall {} on {} jobs | OptiNIC completes every storm cell RoCE stalls: {}",
        report.results.len(),
        fmt_ns(report.wall_ns),
        report.jobs,
        if storm_ok { "YES" } else { "NO" }
    );
    out.set("cells", report.results.len())
        .set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs)
        .set("optinic_completes_where_roce_stalls", storm_ok);
    // the perf/acceptance artifact for this PR (bench-smoke CI job)
    save_results("BENCH_PR7", out);
}
