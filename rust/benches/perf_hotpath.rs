//! §Perf harness: microbenchmarks of the three layers' hot paths, used by
//! the performance pass (EXPERIMENTS.md §Perf records before/after).
//!
//! L3: DES event throughput (packets/s simulated) on a saturated collective;
//!     per-packet costs of the transport receive path.
//! L1-native: FWHT GB/s (the recovery hot loop).
//! Codec: encode/decode throughput for the training gradient path.

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::recovery::{decode, encode, Codec};
use optinic::sim::cluster::{App, AppCtx, Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, save_results, time_fn, Table};
use optinic::util::json::Json;
use optinic::util::prng::Pcg64;
use optinic::verbs::{CqEvent, MrId, NodeId, QpHandle, QpType, RemoteBuf, Wqe};

/// Posts `count` one-sided WRITEs of `msg_bytes` each, either one
/// `post_send` (= one doorbell) per WQE or a single `post_send_batch`.
/// Simulated completion time difference = the doorbell-batching win.
struct PostStorm {
    qp: QpHandle,
    src: MrId,
    dst: MrId,
    rkey: u32,
    count: usize,
    msg_bytes: usize,
    batched: bool,
    done: usize,
}

impl App for PostStorm {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        let mk = |i: usize, src: MrId, dst: MrId, rkey: u32, len: usize| {
            Wqe::write(
                i as u64,
                src,
                0,
                len,
                RemoteBuf {
                    mr: dst,
                    offset: 0,
                    rkey,
                },
            )
            .with_timeout(500_000_000)
        };
        if self.batched {
            let batch: Vec<(QpHandle, Wqe)> = (0..self.count)
                .map(|i| (self.qp, mk(i, self.src, self.dst, self.rkey, self.msg_bytes)))
                .collect();
            ctx.endpoint().post_send_batch(batch);
        } else {
            for i in 0..self.count {
                let wqe = mk(i, self.src, self.dst, self.rkey, self.msg_bytes);
                ctx.endpoint().post_send(self.qp, wqe);
            }
        }
    }
    fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
        if !ev.is_recv() {
            self.done += 1;
        }
    }
    fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
    fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: optinic::net::CtrlMsg) {}
    fn is_done(&self) -> bool {
        self.done >= self.count
    }
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Returns (simulated ns to drain all sends, engine events processed,
/// host wall ns).
fn run_post_storm(batched: bool, count: usize, msg_bytes: usize) -> (u64, u64, f64) {
    let t0 = std::time::Instant::now();
    let mut fab = FabricCfg::cloudlab(2);
    fab.corrupt_prob = 0.0;
    let mut cluster = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic).with_seed(5));
    let src = cluster.mem.register(0, msg_bytes);
    let dst = cluster.mem.register(1, msg_bytes);
    let (qa, _qb) = cluster.connect(0, 1, QpType::Xp);
    let rkey = cluster.mem.rkey(dst);
    cluster.set_app(
        0,
        Box::new(PostStorm {
            qp: qa,
            src,
            dst,
            rkey,
            count,
            msg_bytes,
            batched,
            done: 0,
        }),
    );
    cluster.start_apps();
    assert!(cluster.run(), "post storm did not complete");
    (
        cluster.time,
        cluster.events_processed,
        t0.elapsed().as_nanos() as f64,
    )
}

fn main() {
    let mut out = Json::obj();
    let mut table = Table::new("hot-path microbenchmarks", &["bench", "metric", "value"]);

    // ---- L3: DES throughput ---------------------------------------------------
    for transport in [TransportKind::Optinic, TransportKind::Roce] {
        let elems = 4 * 1024 * 1024 / 4;
        let t0 = std::time::Instant::now();
        let mut cluster = Cluster::new(
            ClusterCfg::new(FabricCfg::cloudlab(8), transport)
                .with_seed(1)
                .with_bg_load(0.2),
        );
        let ws = Workspace::new(&mut cluster, elems, 1);
        let inputs: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0f32; elems]).collect();
        let mut driver = Driver::new(1);
        for _ in 0..3 {
            ws.load_inputs(&mut cluster, &inputs);
            let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
            if transport == TransportKind::Roce {
                spec = spec.reliable();
            } else {
                spec.exchange_stats = true;
            }
            driver.run(&mut cluster, &ws, &spec);
        }
        let wall = t0.elapsed();
        let evps = cluster.events_processed as f64 / wall.as_secs_f64();
        let ppps = cluster.metrics.pkts_sent as f64 / wall.as_secs_f64();
        table.row(&[
            format!("DES 3x 4MB AllReduce ({})", transport.name()),
            "events/s | pkts/s".into(),
            format!("{:.2}M | {:.2}M", evps / 1e6, ppps / 1e6),
        ]);
        let mut e = Json::obj();
        e.set("events_per_sec", evps).set("pkts_per_sec", ppps);
        out.set(&format!("des_{}", transport.name()), e);
    }

    // ---- verbs v2: doorbell batching (batched vs unbatched post_send) -----------
    // 512 single-fragment WRITEs: unbatched rings 512 doorbells, batched
    // rings one. The simulated-time delta is the measured doorbell win;
    // events/wall show the engine-side savings.
    {
        let count = 512;
        let msg_bytes = 1024;
        let (t_un, ev_un, wall_un) = run_post_storm(false, count, msg_bytes);
        let (t_b, ev_b, wall_b) = run_post_storm(true, count, msg_bytes);
        table.row(&[
            format!("post_send x{count} unbatched"),
            "sim time | events | wall".into(),
            format!("{} | {} | {}", fmt_ns(t_un as f64), ev_un, fmt_ns(wall_un)),
        ]);
        table.row(&[
            format!("post_send_batch x{count}"),
            "sim time | events | wall".into(),
            format!("{} | {} | {}", fmt_ns(t_b as f64), ev_b, fmt_ns(wall_b)),
        ]);
        table.row(&[
            "doorbell batching win".into(),
            "sim ns saved".into(),
            format!("{}", fmt_ns(t_un.saturating_sub(t_b) as f64)),
        ]);
        let mut e = Json::obj();
        e.set("unbatched_sim_ns", t_un)
            .set("batched_sim_ns", t_b)
            .set("unbatched_events", ev_un)
            .set("batched_events", ev_b);
        out.set("doorbell_batching", e);
        assert!(
            t_b < t_un,
            "batched posting must beat per-WQE doorbells ({t_b} !< {t_un})"
        );
    }

    // ---- L1-native: FWHT bandwidth ---------------------------------------------
    let n = 16 * 1024 * 1024; // 64 MB
    let mut rng = Pcg64::seeded(2);
    let mut buf: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    for p in [256usize, 1024, 4096] {
        let m = time_fn(&format!("fwht p={p}"), 1, 5, || {
            optinic::recovery::hadamard::fwht_blocks(&mut buf, p);
        });
        let gbps = (n * 4) as f64 / m.mean_ns; // bytes/ns == GB/s
        table.row(&[
            format!("native FWHT 64MB p={p}"),
            "GB/s".into(),
            format!("{gbps:.2}"),
        ]);
        out.set(&format!("fwht_p{p}_gbps"), gbps);
    }

    // ---- codec: gradient encode/decode ------------------------------------------
    let grads: Vec<f32> = (0..4_000_000).map(|i| (i as f32).sin()).collect();
    let codec = Codec::HadamardBlockStride { p: 256, stride: 64 };
    let m_enc = time_fn("encode", 1, 5, || {
        let _ = encode(&grads, codec);
    });
    let wire = encode(&grads, codec);
    let m_dec = time_fn("decode", 1, 5, || {
        let _ = decode(&wire, codec, grads.len());
    });
    table.row(&[
        "codec encode 16MB grads".into(),
        "time | GB/s".into(),
        format!(
            "{} | {:.2}",
            fmt_ns(m_enc.mean_ns),
            (grads.len() * 4) as f64 / m_enc.mean_ns
        ),
    ]);
    table.row(&[
        "codec decode 16MB grads".into(),
        "time | GB/s".into(),
        format!(
            "{} | {:.2}",
            fmt_ns(m_dec.mean_ns),
            (grads.len() * 4) as f64 / m_dec.mean_ns
        ),
    ]);
    out.set("encode_gbps", (grads.len() * 4) as f64 / m_enc.mean_ns);
    out.set("decode_gbps", (grads.len() * 4) as f64 / m_dec.mean_ns);

    table.print();
    save_results("perf_hotpath", out);
}
