//! §Perf harness: microbenchmarks of the three layers' hot paths, used by
//! the performance pass (EXPERIMENTS.md §Perf records before/after).
//!
//! L3: DES event throughput (packets/s simulated) on a saturated collective;
//!     per-packet costs of the transport receive path; the event-engine
//!     A/B (timing wheel + packet trains vs the legacy heap engine) on a
//!     fig6-style tail workload — recorded to `bench_results/BENCH_PR2.json`
//!     as the perf-trajectory artifact for the event-engine overhaul.
//! Sweep harness: the PR4 serial-vs-parallel A/B of the multicore sweep
//!     runner on a small collective grid — byte-identical merged results
//!     asserted, wall times + speedup recorded to
//!     `bench_results/BENCH_PR4.json` (the CI bench-smoke job runs this
//!     with `--jobs 2`).
//! L1-native: FWHT GB/s (the recovery hot loop).
//! Codec: encode/decode throughput for the training gradient path.
//!
//! The wall-clock-timing sections declare their grids [`SweepGrid::serial`]
//! — concurrent timing cells would corrupt each other's measurements.
//!
//! `--quick` (or PERF_QUICK=1) shrinks workloads for CI smoke runs.

use std::sync::Mutex;

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::recovery::{decode, encode, Codec};
use optinic::sim::cluster::{App, AppCtx, Cluster, ClusterCfg, TRAIN_MAX_DEFAULT};
use optinic::sim::SchedKind;
use optinic::transport::TransportKind;
use optinic::util::bench::{
    fmt_ns, quick_mode, run_collective_cell, save_results, time_fn, CollectiveCell, InputSet,
    Table,
};
use optinic::util::json::Json;
use optinic::util::prng::Pcg64;
use optinic::util::sweep::{explicit_cores, jobs_from_args, SweepGrid};
use optinic::verbs::{CqEvent, MrId, NodeId, QpHandle, QpType, RemoteBuf, Wqe};

/// One measured engine configuration on the fig6-style workload.
struct EngineRun {
    wall_ns: f64,
    events: u64,
    pkts: u64,
    sim_ns: u64,
}

impl EngineRun {
    fn events_per_sec(&self) -> f64 {
        self.events as f64 / (self.wall_ns / 1e9)
    }
    fn pkts_per_sec(&self) -> f64 {
        self.pkts as f64 / (self.wall_ns / 1e9)
    }
    fn to_json(&self) -> Json {
        let mut e = Json::obj();
        e.set("wall_ns", self.wall_ns)
            .set("events_processed", self.events)
            .set("pkts_sent", self.pkts)
            .set("sim_ns", self.sim_ns)
            .set("events_per_sec", self.events_per_sec())
            .set("pkts_per_sec", self.pkts_per_sec());
        e
    }
}

/// Fig6-style tail workload (8 nodes, 25 GbE, bg traffic + loss,
/// AllReduceRing with adaptive timeouts) under a chosen engine config.
fn run_fig6_style(sched: SchedKind, train_max: usize, mb: usize, iters: usize) -> EngineRun {
    let nodes = 8;
    let elems = mb * 1024 * 1024 / 4;
    let mut fab = FabricCfg::cloudlab(nodes);
    fab.corrupt_prob = 5e-5;
    let mut cluster = Cluster::new(
        ClusterCfg::new(fab, TransportKind::Optinic)
            .with_seed(23)
            .with_bg_load(0.25)
            .with_scheduler(sched)
            .with_train_max(train_max),
    );
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..nodes).map(|_| vec![1.0f32; elems]).collect();
    let mut driver = Driver::new(1);
    // time only the simulated runs — cluster/workspace/input setup is
    // identical across engine configs and would dilute the A/B ratios
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        spec.exchange_stats = true;
        driver.run(&mut cluster, &ws, &spec);
    }
    EngineRun {
        wall_ns: t0.elapsed().as_nanos() as f64,
        events: cluster.events_processed,
        pkts: cluster.metrics.pkts_sent,
        sim_ns: cluster.time,
    }
}

/// Execute the three-config engine grid (serially — cells time host
/// wall) and return the runs in grid order.
fn engine_rep_runs(
    grid: &SweepGrid<(SchedKind, usize, &'static str)>,
    mb: usize,
    iters: usize,
) -> [EngineRun; 3] {
    let rep = grid.run(|_, &(sched, train_max, _)| run_fig6_style(sched, train_max, mb, iters));
    rep.results
        .try_into()
        .unwrap_or_else(|_| panic!("engine grid must have exactly 3 configs"))
}

/// One measured run of the fig6-style workload on a leaf-spine fabric
/// under a chosen engine: `cores: None` = the legacy serial event loop,
/// `Some(n)` = the PR9 partitioned conservative engine with `n` worker
/// threads. Carries the merged-metrics fingerprint (the byte-identity
/// gate) and the partitioned engine's null-message accounting.
struct PartRun {
    run: EngineRun,
    metrics_json: String,
    epochs: u64,
    envelopes: u64,
    envelope_bytes: u64,
}

/// Fig6-style tail workload on a `leaves`-leaf leaf-spine fabric (one
/// partition per leaf), identical across engine configs except for the
/// engine itself.
fn run_partitioned_ab(
    cores: Option<usize>,
    nodes: usize,
    leaves: usize,
    spines: usize,
    mb: usize,
    iters: usize,
) -> PartRun {
    let elems = mb * 1024 * 1024 / 4;
    let mut fab = FabricCfg::cloudlab(nodes).with_leaf_spine(leaves, spines);
    fab.corrupt_prob = 5e-5;
    let mut ccfg = ClusterCfg::new(fab, TransportKind::Optinic)
        .with_seed(23)
        .with_bg_load(0.25);
    if let Some(n) = cores {
        ccfg = ccfg.with_cores(n);
    }
    let mut cluster = Cluster::new(ccfg);
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..nodes).map(|_| vec![1.0f32; elems]).collect();
    let mut driver = Driver::new(1);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        spec.exchange_stats = true;
        driver.run(&mut cluster, &ws, &spec);
    }
    PartRun {
        run: EngineRun {
            wall_ns: t0.elapsed().as_nanos() as f64,
            events: cluster.events_processed,
            pkts: cluster.metrics.pkts_sent,
            sim_ns: cluster.time,
        },
        metrics_json: cluster.metrics.to_json().to_string_compact(),
        epochs: cluster.part_epochs,
        envelopes: cluster.part_envelopes,
        envelope_bytes: cluster.part_envelope_bytes,
    }
}

/// Posts `count` one-sided WRITEs of `msg_bytes` each, either one
/// `post_send` (= one doorbell) per WQE or a single `post_send_batch`.
/// Simulated completion time difference = the doorbell-batching win.
struct PostStorm {
    qp: QpHandle,
    src: MrId,
    dst: MrId,
    rkey: u32,
    count: usize,
    msg_bytes: usize,
    batched: bool,
    done: usize,
}

impl App for PostStorm {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        let mk = |i: usize, src: MrId, dst: MrId, rkey: u32, len: usize| {
            Wqe::write(
                i as u64,
                src,
                0,
                len,
                RemoteBuf {
                    mr: dst,
                    offset: 0,
                    rkey,
                },
            )
            .with_timeout(500_000_000)
        };
        if self.batched {
            let batch: Vec<(QpHandle, Wqe)> = (0..self.count)
                .map(|i| (self.qp, mk(i, self.src, self.dst, self.rkey, self.msg_bytes)))
                .collect();
            ctx.endpoint().post_send_batch(batch);
        } else {
            for i in 0..self.count {
                let wqe = mk(i, self.src, self.dst, self.rkey, self.msg_bytes);
                ctx.endpoint().post_send(self.qp, wqe);
            }
        }
    }
    fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
        if !ev.is_recv() {
            self.done += 1;
        }
    }
    fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
    fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: optinic::net::CtrlMsg) {}
    fn is_done(&self) -> bool {
        self.done >= self.count
    }
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Returns (simulated ns to drain all sends, engine events processed,
/// host wall ns).
fn run_post_storm(batched: bool, count: usize, msg_bytes: usize) -> (u64, u64, f64) {
    let t0 = std::time::Instant::now();
    let mut fab = FabricCfg::cloudlab(2);
    fab.corrupt_prob = 0.0;
    let mut cluster = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic).with_seed(5));
    let src = cluster.mem.register(0, msg_bytes);
    let dst = cluster.mem.register(1, msg_bytes);
    let (qa, _qb) = cluster.connect(0, 1, QpType::Xp);
    let rkey = cluster.mem.rkey(dst);
    cluster.set_app(
        0,
        Box::new(PostStorm {
            qp: qa,
            src,
            dst,
            rkey,
            count,
            msg_bytes,
            batched,
            done: 0,
        }),
    );
    cluster.start_apps();
    assert!(cluster.run(), "post storm did not complete");
    (
        cluster.time,
        cluster.events_processed,
        t0.elapsed().as_nanos() as f64,
    )
}

fn main() {
    let quick = quick_mode();
    let mut out = Json::obj();
    let mut table = Table::new("hot-path microbenchmarks", &["bench", "metric", "value"]);

    // ---- event engine: wheel + packet trains vs the legacy heap engine ---------
    // The PR2 headline measurement: same fig6-style workload, three engine
    // configs. `heap + train_max 1` is bit-for-bit the pre-overhaul engine
    // behavior; `wheel + trains` is the new default. Declared as a grid,
    // executed serially — the cells time host wall.
    {
        let (mb, iters) = if quick { (2, 2) } else { (8, 3) };
        let configs = [
            (SchedKind::Heap, 1usize, "heap, no trains (legacy)"),
            (SchedKind::Wheel, 1, "wheel, no trains"),
            (SchedKind::Wheel, TRAIN_MAX_DEFAULT, "wheel + trains (default)"),
        ];
        let engine_grid = SweepGrid::new("engine-ab", configs.to_vec()).serial();
        let [legacy, wheel_only, full] = engine_rep_runs(&engine_grid, mb, iters);
        // labels come from the grid cells themselves so config and
        // caption can never drift apart
        for ((_, _, name), r) in engine_grid
            .cells
            .iter()
            .zip([&legacy, &wheel_only, &full])
        {
            table.row(&[
                format!("fig6-style 8x{mb}MB x{iters}: {name}"),
                "wall | events | ev/s | pkt/s".into(),
                format!(
                    "{} | {} | {:.2}M | {:.2}M",
                    fmt_ns(r.wall_ns),
                    r.events,
                    r.events_per_sec() / 1e6,
                    r.pkts_per_sec() / 1e6
                ),
            ]);
        }
        let wall_speedup = legacy.wall_ns / full.wall_ns;
        let pkt_speedup = full.pkts_per_sec() / legacy.pkts_per_sec();
        let ev_speedup = full.events_per_sec() / legacy.events_per_sec();
        table.row(&[
            "event-engine overhaul".into(),
            "wall speedup | pkt/s speedup".into(),
            format!("{wall_speedup:.2}x | {pkt_speedup:.2}x"),
        ]);
        let mut pr2 = Json::obj();
        pr2.set("bench", "event-engine overhaul (PR2)")
            .set(
                "workload",
                format!(
                    "fig6-style AllReduceRing, 8 nodes x {mb} MB x {iters} iters, \
                     bg 0.25, corrupt 5e-5, OptiNIC"
                ),
            )
            .set("quick_mode", quick)
            .set("heap_no_trains", legacy.to_json())
            .set("wheel_no_trains", wheel_only.to_json())
            .set("wheel_trains", full.to_json())
            .set("scheduler_events_per_sec_speedup", {
                wheel_only.events_per_sec() / legacy.events_per_sec()
            })
            .set("events_per_sec_speedup", ev_speedup)
            .set("pkts_per_sec_speedup", pkt_speedup)
            .set("wall_clock_speedup", wall_speedup);
        out.set("event_engine", pr2.clone());
        // the perf-trajectory artifact for this PR (bench-smoke CI job)
        save_results("BENCH_PR2", pr2);
    }

    // ---- sweep harness: serial vs parallel grid execution (PR4) ----------------
    // The same small fig6-style collective grid is executed twice through
    // the sweep runner — once with one worker, once with `--jobs N`
    // (default: max(2, cores)). The merged results MUST be byte-identical
    // (asserted here: this artifact doubles as the determinism gate), and
    // the wall-clock ratio is the harness's headline speedup, recorded to
    // bench_results/BENCH_PR4.json by the CI bench-smoke job.
    {
        let (elems, iters, nodes) = if quick {
            (64 * 1024, 1, 4)
        } else {
            (512 * 1024, 2, 8)
        };
        let transports = [
            TransportKind::Roce,
            TransportKind::Irn,
            TransportKind::Optinic,
            TransportKind::OptinicHw,
        ];
        let sizes = [elems / 2, elems];
        let mut cells = Vec::new();
        for transport in transports {
            for &e in &sizes {
                let mut fab = FabricCfg::cloudlab(nodes);
                fab.corrupt_prob = 5e-5;
                let mut cell =
                    CollectiveCell::new(fab, transport, CollectiveKind::AllReduceRing, e);
                cell.seed = 23;
                cell.bg_load = 0.25;
                cell.iters = iters;
                cells.push(cell);
            }
        }
        let inputs = InputSet::ones(elems);
        let jobs = jobs_from_args().max(2);
        let grid = SweepGrid::new("pr4-harness-ab", cells);
        let serial = grid
            .clone()
            .with_jobs(1)
            .run(|_, cell| run_collective_cell(cell, &inputs));
        let parallel = grid
            .with_jobs(jobs)
            .run(|_, cell| run_collective_cell(cell, &inputs));
        assert_eq!(
            Json::Arr(serial.results.clone()).to_string_pretty(),
            Json::Arr(parallel.results.clone()).to_string_pretty(),
            "parallel sweep must merge byte-identically to the serial run"
        );
        let wall_speedup = serial.wall_ns / parallel.wall_ns.max(1.0);
        table.row(&[
            format!(
                "sweep harness: {} cells ({} transports x {} sizes x{iters})",
                serial.results.len(),
                transports.len(),
                sizes.len()
            ),
            format!("serial | jobs={} | speedup", parallel.jobs),
            format!(
                "{} | {} | {wall_speedup:.2}x",
                fmt_ns(serial.wall_ns),
                fmt_ns(parallel.wall_ns)
            ),
        ]);
        let mut pr4 = Json::obj();
        pr4.set("bench", "deterministic multicore sweep harness (PR4)")
            .set(
                "workload",
                format!(
                    "AllReduceRing grid, {} transports x {} sizes (up to {} KB) x {iters} \
                     iters, {nodes} nodes, bg 0.25, corrupt 5e-5",
                    transports.len(),
                    sizes.len(),
                    elems * 4 / 1024
                ),
            )
            .set("quick_mode", quick)
            // the clamped count the pool actually ran with, not the request
            .set("jobs", parallel.jobs)
            .set("serial", serial.wall_json())
            .set("parallel", parallel.wall_json())
            .set("serial_wall_ns", serial.wall_ns)
            .set("parallel_wall_ns", parallel.wall_ns)
            .set("wall_speedup", wall_speedup)
            .set("results_identical", true);
        out.set("sweep_harness", pr4.clone());
        // the perf/acceptance artifact for this PR (bench-smoke CI job)
        save_results("BENCH_PR4", pr4);
    }

    // ---- partitioned conservative engine: serial vs multi-core (PR9) -----------
    // One fig6-style simulation on a leaf-spine fabric through three
    // engines: the legacy serial loop (baseline universe), the
    // partitioned engine at cores=1 (the single-core oracle), and the
    // partitioned engine at --cores N. cores=1 vs cores=N merged metrics
    // MUST be byte-identical (asserted: the artifact doubles as the
    // determinism gate); wall/events-per-sec speedups are judged against
    // the legacy serial loop. Declared serial — the cells time host wall.
    {
        let (mb, iters, nodes, leaves, spines) =
            if quick { (2, 2, 8, 4, 2) } else { (8, 3, 16, 4, 4) };
        let cores = explicit_cores().unwrap_or(4).max(2);
        let part_grid = SweepGrid::new(
            "partitioned-ab",
            vec![
                (None, "legacy serial loop"),
                (Some(1usize), "partitioned, 1 core (oracle)"),
                (Some(cores), "partitioned, N cores"),
            ],
        )
        .serial();
        let rep = part_grid.run(|_, &(c, _)| {
            run_partitioned_ab(c, nodes, leaves, spines, mb, iters)
        });
        let [legacy, one, multi]: [PartRun; 3] = rep
            .results
            .try_into()
            .unwrap_or_else(|_| panic!("partitioned grid must have exactly 3 configs"));
        assert_eq!(
            one.metrics_json, multi.metrics_json,
            "partitioned engine must merge byte-identically for any --cores"
        );
        for ((_, name), r) in part_grid.cells.iter().zip([&legacy, &one, &multi]) {
            table.row(&[
                format!("partitioned A/B {nodes}x{mb}MB x{iters}: {name}"),
                "wall | events | ev/s".into(),
                format!(
                    "{} | {} | {:.2}M",
                    fmt_ns(r.run.wall_ns),
                    r.run.events,
                    r.run.events_per_sec() / 1e6
                ),
            ]);
        }
        let wall_speedup = legacy.run.wall_ns / multi.run.wall_ns.max(1.0);
        let ev_speedup = multi.run.events_per_sec() / legacy.run.events_per_sec();
        table.row(&[
            format!("partitioned engine, {cores} cores"),
            "wall speedup | ev/s speedup | epochs | envelopes".into(),
            format!(
                "{wall_speedup:.2}x | {ev_speedup:.2}x | {} | {}",
                multi.epochs, multi.envelopes
            ),
        ]);
        let mut overhead = Json::obj();
        overhead
            .set("epochs", multi.epochs)
            .set("envelopes", multi.envelopes)
            .set("envelope_bytes", multi.envelope_bytes)
            .set(
                "envelopes_per_epoch",
                if multi.epochs > 0 {
                    multi.envelopes as f64 / multi.epochs as f64
                } else {
                    0.0
                },
            );
        let mut pr9 = Json::obj();
        pr9.set("bench", "partitioned conservative engine (PR9)")
            .set(
                "workload",
                format!(
                    "fig6-style AllReduceRing, {nodes} nodes leaf-spine \
                     ({leaves} leaves x {spines} spines) x {mb} MB x {iters} iters, \
                     bg 0.25, corrupt 5e-5, OptiNIC"
                ),
            )
            .set("quick_mode", quick)
            .set("cores", cores)
            .set("legacy_serial", legacy.run.to_json())
            .set("partitioned_1core", one.run.to_json())
            .set("partitioned_multicore", multi.run.to_json())
            .set("metrics_byte_identical_1_vs_n", true)
            .set("events_per_sec_speedup", ev_speedup)
            .set("wall_clock_speedup", wall_speedup)
            .set("null_message_overhead", overhead);
        out.set("partitioned_engine", pr9.clone());
        // the perf/acceptance artifact for this PR (bench-smoke CI job)
        save_results("BENCH_PR9", pr9);
    }

    // ---- L3: DES throughput ---------------------------------------------------
    // transport grid, serial: the cells time host wall (events/s)
    {
        let elems = if quick { 1024 * 1024 / 4 } else { 4 * 1024 * 1024 / 4 };
        let des_inputs = InputSet::ones(elems);
        let des_grid = SweepGrid::new(
            "des-throughput",
            vec![TransportKind::Optinic, TransportKind::Roce],
        )
        .serial();
        let des_rep = des_grid.run(|_, &transport| {
            let t0 = std::time::Instant::now();
            let mut cluster = Cluster::new(
                ClusterCfg::new(FabricCfg::cloudlab(8), transport)
                    .with_seed(1)
                    .with_bg_load(0.2),
            );
            let ws = Workspace::new(&mut cluster, elems, 1);
            let ranks = des_inputs.ranks(8, elems);
            let mut driver = Driver::new(1);
            for _ in 0..3 {
                ws.load_input_slices(&mut cluster, &ranks);
                let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
                if transport == TransportKind::Roce {
                    spec = spec.reliable();
                } else {
                    spec.exchange_stats = true;
                }
                driver.run(&mut cluster, &ws, &spec);
            }
            let wall = t0.elapsed().as_secs_f64();
            (
                cluster.events_processed as f64 / wall,
                cluster.metrics.pkts_sent as f64 / wall,
            )
        });
        for (transport, (evps, ppps)) in des_grid.cells.iter().zip(&des_rep.results) {
            table.row(&[
                format!(
                    "DES 3x {}MB AllReduce ({})",
                    elems * 4 / (1024 * 1024),
                    transport.name()
                ),
                "events/s | pkts/s".into(),
                format!("{:.2}M | {:.2}M", evps / 1e6, ppps / 1e6),
            ]);
            let mut e = Json::obj();
            e.set("events_per_sec", *evps).set("pkts_per_sec", *ppps);
            out.set(&format!("des_{}", transport.name()), e);
        }
    }

    // ---- verbs v2: doorbell batching (batched vs unbatched post_send) -----------
    // 512 single-fragment WRITEs: unbatched rings 512 doorbells, batched
    // rings one. The simulated-time delta is the measured doorbell win;
    // events/wall show the engine-side savings.
    {
        let count = 512;
        let msg_bytes = 1024;
        let db_grid = SweepGrid::new("doorbell-ab", vec![false, true]).serial();
        let db_rep = db_grid.run(|_, &batched| run_post_storm(batched, count, msg_bytes));
        let (t_un, ev_un, wall_un) = db_rep.results[0];
        let (t_b, ev_b, wall_b) = db_rep.results[1];
        table.row(&[
            format!("post_send x{count} unbatched"),
            "sim time | events | wall".into(),
            format!("{} | {} | {}", fmt_ns(t_un as f64), ev_un, fmt_ns(wall_un)),
        ]);
        table.row(&[
            format!("post_send_batch x{count}"),
            "sim time | events | wall".into(),
            format!("{} | {} | {}", fmt_ns(t_b as f64), ev_b, fmt_ns(wall_b)),
        ]);
        table.row(&[
            "doorbell batching win".into(),
            "sim ns saved".into(),
            format!("{}", fmt_ns(t_un.saturating_sub(t_b) as f64)),
        ]);
        let mut e = Json::obj();
        e.set("unbatched_sim_ns", t_un)
            .set("batched_sim_ns", t_b)
            .set("unbatched_events", ev_un)
            .set("batched_events", ev_b);
        out.set("doorbell_batching", e);
        assert!(
            t_b < t_un,
            "batched posting must beat per-WQE doorbells ({t_b} !< {t_un})"
        );
    }

    // ---- L1-native: FWHT bandwidth ---------------------------------------------
    // block-size grid, serial (timing cells) over one shared buffer
    {
        let n = if quick { 4 * 1024 * 1024 } else { 16 * 1024 * 1024 };
        let fwht_iters = if quick { 2 } else { 5 };
        let mut rng = Pcg64::seeded(2);
        let buf: Mutex<Vec<f32>> =
            Mutex::new((0..n).map(|_| rng.normal() as f32).collect());
        let fwht_grid = SweepGrid::new("fwht-bw", vec![256usize, 1024, 4096]).serial();
        let fwht_rep = fwht_grid.run(|_, &p| {
            let mut data = buf.lock().unwrap();
            let m = time_fn(&format!("fwht p={p}"), 1, fwht_iters, || {
                optinic::recovery::hadamard::fwht_blocks(&mut data, p);
            });
            (n * 4) as f64 / m.mean_ns // bytes/ns == GB/s
        });
        for (p, gbps) in fwht_grid.cells.iter().zip(&fwht_rep.results) {
            table.row(&[
                format!("native FWHT {}MB p={p}", n * 4 / (1024 * 1024)),
                "GB/s".into(),
                format!("{gbps:.2}"),
            ]);
            out.set(&format!("fwht_p{p}_gbps"), *gbps);
        }
    }

    // ---- codec: gradient encode/decode ------------------------------------------
    let grad_elems = if quick { 1_000_000 } else { 4_000_000 };
    let grads: Vec<f32> = (0..grad_elems).map(|i| (i as f32).sin()).collect();
    let codec = Codec::HadamardBlockStride { p: 256, stride: 64 };
    let m_enc = time_fn("encode", 1, 5, || {
        let _ = encode(&grads, codec);
    });
    let wire = encode(&grads, codec);
    let m_dec = time_fn("decode", 1, 5, || {
        let _ = decode(&wire, codec, grads.len());
    });
    table.row(&[
        format!("codec encode {}MB grads", grad_elems * 4 / 1_000_000),
        "time | GB/s".into(),
        format!(
            "{} | {:.2}",
            fmt_ns(m_enc.mean_ns),
            (grads.len() * 4) as f64 / m_enc.mean_ns
        ),
    ]);
    table.row(&[
        format!("codec decode {}MB grads", grad_elems * 4 / 1_000_000),
        "time | GB/s".into(),
        format!(
            "{} | {:.2}",
            fmt_ns(m_dec.mean_ns),
            (grads.len() * 4) as f64 / m_dec.mean_ns
        ),
    ]);
    out.set("encode_gbps", (grads.len() * 4) as f64 / m_enc.mean_ns);
    out.set("decode_gbps", (grads.len() * 4) as f64 / m_dec.mean_ns);

    table.print();
    save_results("perf_hotpath", out);
}
