//! §Perf harness: microbenchmarks of the three layers' hot paths, used by
//! the performance pass (EXPERIMENTS.md §Perf records before/after).
//!
//! L3: DES event throughput (packets/s simulated) on a saturated collective;
//!     per-packet costs of the transport receive path.
//! L1-native: FWHT GB/s (the recovery hot loop).
//! Codec: encode/decode throughput for the training gradient path.

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::recovery::{decode, encode, Codec};
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, save_results, time_fn, Table};
use optinic::util::json::Json;
use optinic::util::prng::Pcg64;

fn main() {
    let mut out = Json::obj();
    let mut table = Table::new("hot-path microbenchmarks", &["bench", "metric", "value"]);

    // ---- L3: DES throughput ---------------------------------------------------
    for transport in [TransportKind::Optinic, TransportKind::Roce] {
        let elems = 4 * 1024 * 1024 / 4;
        let t0 = std::time::Instant::now();
        let mut cluster = Cluster::new(
            ClusterCfg::new(FabricCfg::cloudlab(8), transport)
                .with_seed(1)
                .with_bg_load(0.2),
        );
        let ws = Workspace::new(&mut cluster, elems, 1);
        let inputs: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0f32; elems]).collect();
        let mut driver = Driver::new(1);
        for _ in 0..3 {
            ws.load_inputs(&mut cluster, &inputs);
            let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
            if transport == TransportKind::Roce {
                spec = spec.reliable();
            } else {
                spec.exchange_stats = true;
            }
            driver.run(&mut cluster, &ws, &spec);
        }
        let wall = t0.elapsed();
        let evps = cluster.events_processed as f64 / wall.as_secs_f64();
        let ppps = cluster.metrics.pkts_sent as f64 / wall.as_secs_f64();
        table.row(&[
            format!("DES 3x 4MB AllReduce ({})", transport.name()),
            "events/s | pkts/s".into(),
            format!("{:.2}M | {:.2}M", evps / 1e6, ppps / 1e6),
        ]);
        let mut e = Json::obj();
        e.set("events_per_sec", evps).set("pkts_per_sec", ppps);
        out.set(&format!("des_{}", transport.name()), e);
    }

    // ---- L1-native: FWHT bandwidth ---------------------------------------------
    let n = 16 * 1024 * 1024; // 64 MB
    let mut rng = Pcg64::seeded(2);
    let mut buf: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    for p in [256usize, 1024, 4096] {
        let m = time_fn(&format!("fwht p={p}"), 1, 5, || {
            optinic::recovery::hadamard::fwht_blocks(&mut buf, p);
        });
        let gbps = (n * 4) as f64 / m.mean_ns; // bytes/ns == GB/s
        table.row(&[
            format!("native FWHT 64MB p={p}"),
            "GB/s".into(),
            format!("{gbps:.2}"),
        ]);
        out.set(&format!("fwht_p{p}_gbps"), gbps);
    }

    // ---- codec: gradient encode/decode ------------------------------------------
    let grads: Vec<f32> = (0..4_000_000).map(|i| (i as f32).sin()).collect();
    let codec = Codec::HadamardBlockStride { p: 256, stride: 64 };
    let m_enc = time_fn("encode", 1, 5, || {
        let _ = encode(&grads, codec);
    });
    let wire = encode(&grads, codec);
    let m_dec = time_fn("decode", 1, 5, || {
        let _ = decode(&wire, codec, grads.len());
    });
    table.row(&[
        "codec encode 16MB grads".into(),
        "time | GB/s".into(),
        format!(
            "{} | {:.2}",
            fmt_ns(m_enc.mean_ns),
            (grads.len() * 4) as f64 / m_enc.mean_ns
        ),
    ]);
    table.row(&[
        "codec decode 16MB grads".into(),
        "time | GB/s".into(),
        format!(
            "{} | {:.2}",
            fmt_ns(m_dec.mean_ns),
            (grads.len() * 4) as f64 / m_dec.mean_ns
        ),
    ]);
    out.set("encode_gbps", (grads.len() * 4) as f64 / m_enc.mean_ns);
    out.set("decode_gbps", (grads.len() * 4) as f64 / m_dec.mean_ns);

    table.print();
    save_results("perf_hotpath", out);
}
