//! Fig 6 reproduction: average and tail (p99) collective completion time
//! across ALL transports. Paper: OptiNIC delivers both the lowest mean and
//! the lowest p99; IRN/SRNIC modestly reduce mean but keep large tails;
//! Falcon/UCCL match RoCE's mean with elevated tails.
//!
//! Grid declared as data, executed by the multicore sweep runner
//! (`--jobs N` / `OPTINIC_JOBS`); merged rows are byte-identical for any
//! job count.

use optinic::collectives::CollectiveKind;
use optinic::net::FabricCfg;
use optinic::transport::TransportKind;
use optinic::util::bench::{
    fmt_ns, jf, run_collective_cell, save_results, CollectiveCell, InputSet, Table,
};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_bounded_by_cell_bytes, SweepGrid};

fn main() {
    let nodes = 8;
    let mb = 20;
    let iters = 6;
    let elems = mb * 1024 * 1024 / 4;
    // sweep every configuration, including the OptiNIC (HW) variant
    let transports = TransportKind::ALL_WITH_VARIANTS;
    let collectives = [
        CollectiveKind::AllReduceRing,
        CollectiveKind::AllGather,
        CollectiveKind::ReduceScatter,
    ];

    let mut cells = Vec::new();
    for kind in collectives {
        for transport in transports {
            // heavier ambient stress for the tail experiment
            let mut fab = FabricCfg::cloudlab(nodes);
            fab.corrupt_prob = 5e-5;
            let mut cell = CollectiveCell::new(fab, transport, kind, elems);
            cell.seed = 23;
            cell.bg_load = 0.25;
            cell.iters = iters;
            cell.exchange_stats = true;
            cell.reliable = !matches!(
                transport,
                TransportKind::Optinic | TransportKind::OptinicHw
            );
            cells.push(cell);
        }
    }
    let inputs = InputSet::ones(elems);
    // ~0.7 GB of cluster buffers per in-flight 20 MB cell: bound the
    // default worker count by that footprint (explicit --jobs wins)
    let cell_bytes = cells.iter().map(|c| c.est_cluster_bytes()).max().unwrap();
    let grid = SweepGrid::new("fig6", cells).with_jobs(jobs_bounded_by_cell_bytes(cell_bytes));
    let report = grid.run(|_, cell| run_collective_cell(cell, &inputs));

    let mut out = Json::obj();
    for (k, kind) in collectives.iter().enumerate() {
        let mut table = Table::new(
            &format!("Fig 6: {} CCT, {} MB, 8 nodes, 25 GbE + bg + loss", kind.name(), mb),
            &["transport", "mean CCT", "p99 CCT", "tail/mean"],
        );
        let base = k * transports.len();
        for (cell, r) in grid.cells[base..base + transports.len()]
            .iter()
            .zip(&report.results[base..base + transports.len()])
        {
            let (mean, p99) = (jf(r, "mean_ns"), jf(r, "p99_ns"));
            table.row(&[
                cell.transport.name().to_string(),
                fmt_ns(mean),
                fmt_ns(p99),
                format!("{:.2}", p99 / mean),
            ]);
            let mut e = Json::obj();
            e.set("mean_ns", mean).set("p99_ns", p99);
            out.set(&format!("{}/{}", kind.name(), cell.transport.name()), e);
        }
        table.print();
    }
    // sweep wall time: the perf-trajectory number tracked since the
    // event-engine overhaul (BENCH_PR2) — now also parallelized (PR4)
    println!(
        "\nfig6 sweep wall time: {} ({} cells on {} jobs)",
        fmt_ns(report.wall_ns),
        report.results.len(),
        report.jobs
    );
    out.set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs);
    save_results("fig6_cct_tail", out);
}
