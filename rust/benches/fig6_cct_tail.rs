//! Fig 6 reproduction: average and tail (p99) collective completion time
//! across ALL transports. Paper: OptiNIC delivers both the lowest mean and
//! the lowest p99; IRN/SRNIC modestly reduce mean but keep large tails;
//! Falcon/UCCL match RoCE's mean with elevated tails.

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, save_results, Table};
use optinic::util::json::Json;
use optinic::util::stats::Samples;

fn main() {
    let nodes = 8;
    let mb = 20;
    let iters = 6;
    let elems = mb * 1024 * 1024 / 4;
    // sweep every configuration, including the OptiNIC (HW) variant
    let transports = TransportKind::ALL_WITH_VARIANTS;
    let mut out = Json::obj();
    let t0 = std::time::Instant::now();
    for kind in [
        CollectiveKind::AllReduceRing,
        CollectiveKind::AllGather,
        CollectiveKind::ReduceScatter,
    ] {
        let mut table = Table::new(
            &format!("Fig 6: {} CCT, {} MB, 8 nodes, 25 GbE + bg + loss", kind.name(), mb),
            &["transport", "mean CCT", "p99 CCT", "tail/mean"],
        );
        for transport in transports {
            // heavier ambient stress for the tail experiment
            let mut fab = FabricCfg::cloudlab(nodes);
            fab.corrupt_prob = 5e-5;
            let mut cluster = Cluster::new(
                ClusterCfg::new(fab, transport).with_seed(23).with_bg_load(0.25),
            );
            let ws = Workspace::new(&mut cluster, elems, 1);
            let inputs: Vec<Vec<f32>> = (0..nodes).map(|_| vec![1.0f32; elems]).collect();
            let mut driver = Driver::new(1);
            let mut s = Samples::new();
            for _ in 0..iters {
                ws.load_inputs(&mut cluster, &inputs);
                let mut spec = CollectiveSpec::new(kind, elems);
                spec.exchange_stats = true;
                if !matches!(transport, TransportKind::Optinic | TransportKind::OptinicHw) {
                    spec = spec.reliable();
                }
                let res = driver.run(&mut cluster, &ws, &spec);
                s.push(res.cct_ns as f64);
            }
            table.row(&[
                transport.name().to_string(),
                fmt_ns(s.mean()),
                fmt_ns(s.p99()),
                format!("{:.2}", s.p99() / s.mean()),
            ]);
            let mut e = Json::obj();
            e.set("mean_ns", s.mean()).set("p99_ns", s.p99());
            out.set(&format!("{}/{}", kind.name(), transport.name()), e);
        }
        table.print();
    }
    // sweep wall time: the event-engine overhaul's headline target
    // (tracked alongside bench_results/BENCH_PR2.json)
    let wall = t0.elapsed().as_nanos() as f64;
    println!("\nfig6 sweep wall time: {}", fmt_ns(wall));
    out.set("sweep_wall_ns", wall);
    save_results("fig6_cct_tail", out);
}
