//! Fig 6 reproduction: average and tail (p99) collective completion time
//! across ALL transports. Paper: OptiNIC delivers both the lowest mean and
//! the lowest p99; IRN/SRNIC modestly reduce mean but keep large tails;
//! Falcon/UCCL match RoCE's mean with elevated tails.
//!
//! Since the leaf–spine rework the grid carries a topology column: every
//! cell runs on the single-switch fabric AND on a 2-leaf × 2-spine Clos
//! (2:1 oversubscribed at 8 nodes), so the tail numbers show what real
//! multi-hop contention — ECMP collisions vs per-packet spraying — does
//! to each design. The single-tier vs leaf–spine tail-CCT comparison is
//! recorded as `bench_results/BENCH_PR5.json` (uploaded by CI's
//! bench-smoke job alongside BENCH_PR2–PR4); `--quick` / PERF_QUICK=1
//! shrinks the grid for CI.
//!
//! Grid declared as data, executed by the multicore sweep runner
//! (`--jobs N` / `OPTINIC_JOBS`); merged rows are byte-identical for any
//! job count.

use optinic::collectives::CollectiveKind;
use optinic::net::FabricCfg;
use optinic::transport::TransportKind;
use optinic::util::bench::{
    fmt_ns, jf, quick_mode, run_collective_cell, save_results, CollectiveCell, InputSet,
    Table,
};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_bounded_by_cell_bytes, SweepGrid};

fn main() {
    let quick = quick_mode();
    // quick: 4 nodes × 256 KB × 2 iters × 1 collective (CI smoke);
    // full: 8 nodes × 20 MB × 6 iters × 3 collectives
    let (nodes, elems, iters, collectives): (usize, usize, usize, &[CollectiveKind]) = if quick
    {
        (4, 64 * 1024, 2, &[CollectiveKind::AllReduceRing])
    } else {
        (
            8,
            20 * 1024 * 1024 / 4,
            6,
            &[
                CollectiveKind::AllReduceRing,
                CollectiveKind::AllGather,
                CollectiveKind::ReduceScatter,
            ],
        )
    };
    let transports = TransportKind::ALL_WITH_VARIANTS;
    let topos = [false, true]; // single-switch, then leaf–spine

    // grid order = emission order: topo ▸ collective ▸ transport
    let mut cells = Vec::new();
    for &leaf_spine in &topos {
        for &kind in collectives {
            for transport in transports {
                // heavier ambient stress for the tail experiment
                let mut fab = FabricCfg::cloudlab(nodes);
                if leaf_spine {
                    // 2:1 oversubscription at 8 nodes (4 hosts/leaf, 2
                    // uplinks) — the contention regime tails come from
                    fab = fab.with_leaf_spine(2, 2);
                }
                fab.corrupt_prob = 5e-5;
                let mut cell = CollectiveCell::new(fab, transport, kind, elems);
                cell.seed = 23;
                cell.bg_load = 0.25;
                cell.iters = iters;
                cell.exchange_stats = true;
                cell.reliable = !matches!(
                    transport,
                    TransportKind::Optinic | TransportKind::OptinicHw
                );
                cells.push(cell);
            }
        }
    }
    let inputs = InputSet::ones(elems);
    // ~0.7 GB of cluster buffers per in-flight 20 MB cell: bound the
    // default worker count by that footprint (explicit --jobs wins)
    let cell_bytes = cells.iter().map(|c| c.est_cluster_bytes()).max().unwrap();
    let grid = SweepGrid::new("fig6", cells).with_jobs(jobs_bounded_by_cell_bytes(cell_bytes));
    let report = grid.run(|_, cell| run_collective_cell(cell, &inputs));

    let mut out = Json::obj();
    let mut pr5_rows = Vec::new();
    let per_topo = collectives.len() * transports.len();
    for (t, &leaf_spine) in topos.iter().enumerate() {
        let topo_name = if leaf_spine { "leaf-spine" } else { "single" };
        for (k, kind) in collectives.iter().enumerate() {
            let mut table = Table::new(
                &format!(
                    "Fig 6: {} CCT, {} KB, {} nodes, {topo_name} + bg + loss",
                    kind.name(),
                    elems * 4 / 1024,
                    nodes
                ),
                &["transport", "mean CCT", "p99 CCT", "tail/mean"],
            );
            let base = t * per_topo + k * transports.len();
            for (cell, r) in grid.cells[base..base + transports.len()]
                .iter()
                .zip(&report.results[base..base + transports.len()])
            {
                let (mean, p99) = (jf(r, "mean_ns"), jf(r, "p99_ns"));
                table.row(&[
                    cell.transport.name().to_string(),
                    fmt_ns(mean),
                    fmt_ns(p99),
                    format!("{:.2}", p99 / mean),
                ]);
                let mut e = Json::obj();
                e.set("mean_ns", mean).set("p99_ns", p99);
                out.set(
                    &format!("{topo_name}/{}/{}", kind.name(), cell.transport.name()),
                    e,
                );
                let mut row = Json::obj();
                row.set("topo", topo_name)
                    .set("collective", kind.name())
                    .set("transport", cell.transport.name())
                    .set("mean_ns", mean)
                    .set("p99_ns", p99)
                    .set(
                        "completed",
                        r.get("completed").and_then(Json::as_bool).unwrap_or(false),
                    );
                pr5_rows.push(row);
            }
            table.print();
        }
    }
    // sweep wall time: the perf-trajectory number tracked since the
    // event-engine overhaul (BENCH_PR2) — now also parallelized (PR4)
    println!(
        "\nfig6 sweep wall time: {} ({} cells on {} jobs)",
        fmt_ns(report.wall_ns),
        report.results.len(),
        report.jobs
    );
    out.set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs)
        .set("quick_mode", quick);
    save_results("fig6_cct_tail", out);

    // the PR5 acceptance artifact: single-tier vs leaf–spine tail CCT,
    // row per (topo, collective, transport)
    let mut pr5 = Json::obj();
    pr5.set("bench", "fig6 topology grid (PR5)")
        .set("quick_mode", quick)
        .set(
            "workload",
            format!(
                "{} nodes x {} KB x {} iters, bg 0.25, corrupt 5e-5, single vs leaf-spine(2x2)",
                nodes,
                elems * 4 / 1024,
                iters
            ),
        )
        .set("rows", Json::Arr(pr5_rows))
        .set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs);
    save_results("BENCH_PR5", pr5);
}
