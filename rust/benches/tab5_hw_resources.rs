//! Table 5 reproduction: FPGA resource utilization + resilience (MTBF) for
//! every transport at 10 K QPs on the Alveo U250 model, against the paper's
//! published synthesis results.
//!
//! The transport grid runs through the multicore sweep runner (cells are
//! pure synthesis-model evaluations).

use optinic::hw;
use optinic::transport::TransportKind;
use optinic::util::bench::{jf, save_results, Table};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_from_args, SweepGrid};

/// Paper Table 5 (LUT K, LUTRAM K, FF K, BRAM, Power W, MTBF h).
const PAPER: [(&str, f64, f64, f64, f64, f64, f64); 6] = [
    ("RoCE", 312.4, 23.3, 562.1, 1500.0, 34.7, 42.8),
    ("IRN", 319.6, 24.2, 573.1, 2200.0, 35.9, 30.9),
    ("SRNIC", 304.5, 22.5, 551.5, 900.0, 33.5, 57.8),
    ("Falcon", 309.8, 23.1, 559.2, 1600.0, 34.3, 40.5),
    ("UCCL", 312.4, 23.3, 562.1, 1500.0, 34.7, 42.8),
    ("OptiNIC", 298.4, 21.7, 543.0, 500.0, 32.5, 80.5),
];

fn main() {
    let grid = SweepGrid::new("tab5", TransportKind::ALL.to_vec()).with_jobs(jobs_from_args());
    let report = grid.run(|_, &kind| {
        let r = hw::synthesize(kind);
        let mut e = Json::obj();
        e.set("lut", r.lut)
            .set("lutram", r.lutram)
            .set("ff", r.ff)
            .set("bram", r.bram)
            .set("power_w", r.power_w)
            .set("mtbf_hours", r.mtbf_hours);
        e
    });

    let mut table = Table::new(
        "Table 5: hardware resources @ 10K QPs (measured | paper)",
        &[
            "transport", "LUT", "paper", "BRAM", "paper", "power W", "paper",
            "MTBF h", "paper",
        ],
    );
    let mut out = Json::obj();
    for (i, (kind, r)) in grid.cells.iter().zip(&report.results).enumerate() {
        let p = PAPER[i];
        assert_eq!(p.0, kind.name());
        table.row(&[
            kind.name().to_string(),
            format!("{:.1}K", jf(r, "lut") / 1000.0),
            format!("{:.1}K", p.1),
            format!("{:.0}", jf(r, "bram")),
            format!("{:.0}", p.4),
            format!("{:.1}", jf(r, "power_w")),
            format!("{:.1}", p.5),
            format!("{:.1}", jf(r, "mtbf_hours")),
            format!("{:.1}", p.6),
        ]);
        out.set(kind.name(), r.clone());
    }
    table.print();

    let (roce, opt) = (&report.results[0], &report.results[5]);
    println!(
        "\nheadlines: BRAM reduction {:.1}x (paper: 2.7x) | MTBF gain {:.2}x (paper: ~1.9x)",
        jf(roce, "bram") / jf(opt, "bram"),
        jf(opt, "mtbf_hours") / jf(roce, "mtbf_hours")
    );
    save_results("tab5_hw_resources", out);
}
