//! Fig 5 reproduction: collective communication time across transports,
//! message sizes (20–80 MB), and collective types; RoCE vs OptiNIC vs
//! OptiNIC (HW). Paper: OptiNIC is 1.6–2.5× faster than RoCE; observed
//! loss stays under 1% on average (§5.3.1).
//!
//! Topology column (PR5): every (collective, transport, size) cell runs
//! on the single-switch fabric AND on a 2-leaf × 2-spine Clos, so the
//! speedup claim is checked under genuine multi-hop contention too.
//!
//! The topo × collective × transport × size grid is declared as data and
//! executed by the deterministic multicore sweep runner (`--jobs N`,
//! env `OPTINIC_JOBS`); merged output is byte-identical for any job
//! count (docs/PERF.md §Parallel sweeps).

use optinic::collectives::CollectiveKind;
use optinic::net::FabricCfg;
use optinic::transport::TransportKind;
use optinic::util::bench::{
    fmt_ns, jf, run_collective_cell, save_results, CollectiveCell, InputSet, Table,
};
use optinic::util::json::Json;
use optinic::util::sweep::{jobs_bounded_by_cell_bytes, SweepGrid};

fn main() {
    let sizes_mb = [20usize, 40, 60, 80];
    let iters = 2;
    let nodes = 8;
    let topos = [false, true]; // single-switch, then leaf–spine
    let transports = [
        TransportKind::Roce,
        TransportKind::Optinic,
        TransportKind::OptinicHw,
    ];
    let collectives = [
        CollectiveKind::AllReduceRing,
        CollectiveKind::AllGather,
        CollectiveKind::ReduceScatter,
    ];

    // grid order = emission order: topo ▸ collective ▸ transport ▸ size
    let mut cells = Vec::new();
    for &leaf_spine in &topos {
        for kind in collectives {
            for transport in transports {
                for &mb in &sizes_mb {
                    let elems = mb * 1024 * 1024 / 4;
                    let mut fab = FabricCfg::cloudlab(nodes);
                    if leaf_spine {
                        fab = fab.with_leaf_spine(2, 2);
                    }
                    let mut cell = CollectiveCell::new(fab, transport, kind, elems);
                    cell.seed = 11;
                    cell.bg_load = 0.2;
                    cell.iters = iters;
                    cell.exchange_stats = true;
                    // Fig 5's reliable baseline is RoCE only
                    cell.reliable = transport == TransportKind::Roce;
                    cells.push(cell);
                }
            }
        }
    }
    let inputs = InputSet::ones(cells.iter().map(|c| c.elems).max().unwrap());
    // an 80 MB cell registers ~2 GB of cluster buffers; derive the
    // default worker count from that footprint so the grid fits
    // commodity runners (explicit --jobs still wins)
    let cell_bytes = cells.iter().map(|c| c.est_cluster_bytes()).max().unwrap();
    let grid = SweepGrid::new("fig5", cells).with_jobs(jobs_bounded_by_cell_bytes(cell_bytes));
    let report = grid.run(|_, cell| run_collective_cell(cell, &inputs));

    let mut out = Json::obj();
    let per_kind = transports.len() * sizes_mb.len();
    let per_topo = collectives.len() * per_kind;
    for (t, &leaf_spine) in topos.iter().enumerate() {
        let topo_name = if leaf_spine { "leaf-spine" } else { "single" };
        for (k, kind) in collectives.iter().enumerate() {
            let mut table = Table::new(
                &format!("Fig 5: {} (8 nodes, 25 GbE, 20% bg, {topo_name})", kind.name()),
                &["transport", "MB", "mean CCT", "std", "loss %"],
            );
            let mut roce_means: Vec<f64> = vec![];
            let mut opt_means: Vec<f64> = vec![];
            let base = t * per_topo + k * per_kind;
            for (cell, r) in grid.cells[base..base + per_kind]
                .iter()
                .zip(&report.results[base..base + per_kind])
            {
                let mean = jf(r, "mean_ns");
                match cell.transport {
                    TransportKind::Roce => roce_means.push(mean),
                    TransportKind::Optinic => opt_means.push(mean),
                    _ => {}
                }
                table.row(&[
                    cell.transport.name().to_string(),
                    cell.size_mb().to_string(),
                    fmt_ns(mean),
                    fmt_ns(jf(r, "std_ns")),
                    format!("{:.3}", jf(r, "loss_pct")),
                ]);
                let mut e = Json::obj();
                e.set("mean_ns", mean).set("std_ns", jf(r, "std_ns"));
                out.set(
                    &format!(
                        "{topo_name}/{}/{}/{}MB",
                        kind.name(),
                        cell.transport.name(),
                        cell.size_mb()
                    ),
                    e,
                );
            }
            table.print();
            let speedups: Vec<f64> = roce_means
                .iter()
                .zip(opt_means.iter())
                .map(|(r, o)| r / o)
                .collect();
            println!(
                "{topo_name}/{}: OptiNIC speedup over RoCE by size: {:?} (paper: 1.6–2.5x)",
                kind.name(),
                speedups
                    .iter()
                    .map(|s| format!("{s:.2}x"))
                    .collect::<Vec<_>>()
            );
        }
    }
    println!(
        "\nfig5 sweep: {} cells on {} jobs in {}",
        report.results.len(),
        report.jobs,
        fmt_ns(report.wall_ns)
    );
    out.set("sweep_wall_ns", report.wall_ns)
        .set("jobs", report.jobs);
    save_results("fig5_collectives", out);
}
