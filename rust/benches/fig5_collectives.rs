//! Fig 5 reproduction: collective communication time across transports,
//! message sizes (20–80 MB), and collective types; RoCE vs OptiNIC vs
//! OptiNIC (HW). Paper: OptiNIC is 1.6–2.5× faster than RoCE; observed
//! loss stays under 1% on average (§5.3.1).

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::{fmt_ns, save_results, Table};
use optinic::util::json::Json;
use optinic::util::stats::Samples;

fn main() {
    let sizes_mb = [20usize, 40, 60, 80];
    let iters = 2;
    let nodes = 8;
    let transports = [
        TransportKind::Roce,
        TransportKind::Optinic,
        TransportKind::OptinicHw,
    ];
    let mut out = Json::obj();
    for kind in [
        CollectiveKind::AllReduceRing,
        CollectiveKind::AllGather,
        CollectiveKind::ReduceScatter,
    ] {
        let mut table = Table::new(
            &format!("Fig 5: {} (8 nodes, 25 GbE, 20% bg)", kind.name()),
            &["transport", "MB", "mean CCT", "std", "loss %"],
        );
        let mut roce_means: Vec<f64> = vec![];
        let mut opt_means: Vec<f64> = vec![];
        for transport in transports {
            for &mb in &sizes_mb {
                let elems = mb * 1024 * 1024 / 4;
                let mut cluster = Cluster::new(
                    ClusterCfg::new(FabricCfg::cloudlab(nodes), transport)
                        .with_seed(11)
                        .with_bg_load(0.2),
                );
                let ws = Workspace::new(&mut cluster, elems, 1);
                let inputs: Vec<Vec<f32>> =
                    (0..nodes).map(|_| vec![1.0f32; elems]).collect();
                let mut driver = Driver::new(1);
                let mut s = Samples::new();
                let mut loss = 0.0;
                for _ in 0..iters {
                    ws.load_inputs(&mut cluster, &inputs);
                    let mut spec = CollectiveSpec::new(kind, elems);
                    spec.exchange_stats = true;
                    if transport == TransportKind::Roce {
                        spec = spec.reliable();
                    }
                    let res = driver.run(&mut cluster, &ws, &spec);
                    s.push(res.cct_ns as f64);
                    loss += res.loss_fraction;
                }
                match transport {
                    TransportKind::Roce => roce_means.push(s.mean()),
                    TransportKind::Optinic => opt_means.push(s.mean()),
                    _ => {}
                }
                table.row(&[
                    transport.name().to_string(),
                    mb.to_string(),
                    fmt_ns(s.mean()),
                    fmt_ns(s.std()),
                    format!("{:.3}", loss / iters as f64 * 100.0),
                ]);
                let mut e = Json::obj();
                e.set("mean_ns", s.mean()).set("std_ns", s.std());
                out.set(&format!("{}/{}/{}MB", kind.name(), transport.name(), mb), e);
            }
        }
        table.print();
        let speedups: Vec<f64> = roce_means
            .iter()
            .zip(opt_means.iter())
            .map(|(r, o)| r / o)
            .collect();
        println!(
            "{}: OptiNIC speedup over RoCE by size: {:?} (paper: 1.6–2.5x)",
            kind.name(),
            speedups
                .iter()
                .map(|s| format!("{s:.2}x"))
                .collect::<Vec<_>>()
        );
    }
    save_results("fig5_collectives", out);
}
