//! Zero-dependency subset of the `log` facade, vendored so the workspace
//! builds offline. Records go to stderr when `OPTINIC_LOG` is set (any
//! value); otherwise logging is a no-op. The simulator's determinism
//! contract must not depend on logging side effects, so there is no
//! leveled filtering — it is all-or-nothing by design.

/// Backend for the level macros. Public only for macro expansion.
pub fn __log(level: &str, args: std::fmt::Arguments<'_>) {
    if std::env::var_os("OPTINIC_LOG").is_some() {
        eprintln!("[{level}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::__log("ERROR", format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::__log("WARN", format_args!($($t)*)) };
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::__log("INFO", format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::__log("DEBUG", format_args!($($t)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($t:tt)*) => { $crate::__log("TRACE", format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand_and_run() {
        crate::info!("hello {}", 1);
        crate::warn!("w");
        crate::debug!("d {x}", x = 2);
        crate::error!("e");
        crate::trace!("t");
    }
}
