//! Zero-dependency, API-compatible subset of the `anyhow` crate, vendored
//! so the workspace builds offline (the container bakes no registry).
//!
//! Implements exactly the surface the `optinic` crate uses:
//! * [`Error`] — boxed dynamic error with a context chain; `{}` prints the
//!   outermost message, `{:#}` the full `a: b: c` chain (matching anyhow).
//! * [`Result`] with a defaulted error parameter.
//! * [`anyhow!`], [`ensure!`] macros.
//! * [`Context`] for `.context(..)` / `.with_context(..)` on `Result`.
//! * blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts concrete errors.

use std::fmt;

/// Boxed error with a human-readable context chain.
pub struct Error {
    /// Outermost message first; deeper causes follow.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    fn wrap(mut self, ctx: String) -> Error {
        self.chain.insert(0, ctx);
        self
    }

    /// The `a: b: c` rendering used by `{:#}` and `Debug`.
    fn full(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.full())
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.full())
    }
}

// NOTE: like real anyhow, `Error` itself does NOT implement
// `std::error::Error` — that would conflict with the blanket `From` below.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(ctx.to_string()))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

/// Construct an [`Error`] from a message, a format string, or an
/// error-like expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

/// Early-return with an error unconditionally (parity with anyhow).
#[macro_export]
macro_rules! bail {
    ($($rest:tt)+) => {
        return Err($crate::anyhow!($($rest)+));
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn chain_rendering() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn ensure_and_anyhow() {
        fn guarded(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(guarded(3).unwrap(), 3);
        let e = guarded(30).unwrap_err();
        assert_eq!(format!("{e}"), "x too big: 30");
        let m = anyhow!("plain {} message", 7);
        assert_eq!(format!("{m}"), "plain 7 message");
        let from_string = Error::msg(String::from("s"));
        assert_eq!(format!("{from_string}"), "s");
    }
}
