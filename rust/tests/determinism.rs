//! Determinism regression suite for the event-engine overhaul.
//!
//! Contract: same seed + same config ⇒ bit-identical simulation — clock,
//! event count, and every metric — (a) across repeated runs and (b)
//! across the timing-wheel and reference-heap schedulers, for EVERY
//! transport variant. The fingerprint is the full `Metrics::to_json()`
//! serialization plus the engine clock and event counter, so any drift
//! in packet order, RNG consumption, timer behavior, or train coalescing
//! shows up as a diff.

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::sim::SchedKind;
use optinic::transport::TransportKind;

/// Run a small but adversarial workload (loss + background traffic +
/// adaptive timeouts, two iterations so estimator state carries over) and
/// fingerprint the entire observable simulation state.
fn fingerprint(kind: TransportKind, sched: SchedKind) -> String {
    let nodes = 4;
    let elems = 8 * 1024; // 32 KB message
    let mut fab = FabricCfg::cloudlab(nodes);
    fab.corrupt_prob = 2e-4; // loss/retransmission paths exercised
    let cfg = ClusterCfg::new(fab, kind)
        .with_seed(42)
        .with_bg_load(0.2)
        .with_scheduler(sched);
    let mut cluster = Cluster::new(cfg);
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..nodes)
        .map(|r| (0..elems).map(|i| ((r * elems + i) % 97) as f32).collect())
        .collect();
    let mut driver = Driver::new(1);
    for _ in 0..2 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        if matches!(kind, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec.exchange_stats = true;
        } else {
            spec = spec.reliable();
        }
        let res = driver.run(&mut cluster, &ws, &spec);
        assert!(res.completed, "{kind:?}/{sched:?}: run did not complete");
    }
    format!(
        "t={} ev={} metrics={}",
        cluster.time,
        cluster.events_processed,
        cluster.metrics.to_json().to_string_compact()
    )
}

/// (a) Replay determinism on the default (wheel) scheduler.
#[test]
fn same_seed_same_metrics_all_transports() {
    for kind in TransportKind::ALL_WITH_VARIANTS {
        let a = fingerprint(kind, SchedKind::Wheel);
        let b = fingerprint(kind, SchedKind::Wheel);
        assert_eq!(a, b, "{kind:?}: wheel replay diverged");
    }
}

/// (b) Wheel-vs-heap parity: the scheduler backend must be invisible.
#[test]
fn wheel_matches_heap_all_transports() {
    for kind in TransportKind::ALL_WITH_VARIANTS {
        let w = fingerprint(kind, SchedKind::Wheel);
        let h = fingerprint(kind, SchedKind::Heap);
        assert_eq!(w, h, "{kind:?}: wheel-vs-heap parity broken");
    }
}

/// Smaller workload for the CC grid (6 algorithms × 2 engine families ×
/// 3 runs each): same adversarial ingredients, fewer bytes.
fn cc_fingerprint(kind: TransportKind, cc: optinic::cc::CcKind, sched: SchedKind) -> String {
    let nodes = 4;
    let elems = 2 * 1024; // 8 KB message
    let mut fab = FabricCfg::cloudlab(nodes);
    fab.corrupt_prob = 2e-4;
    let cfg = ClusterCfg::new(fab, kind)
        .with_seed(42)
        .with_bg_load(0.2)
        .with_scheduler(sched)
        .with_cc(cc);
    let mut cluster = Cluster::new(cfg);
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..nodes)
        .map(|r| (0..elems).map(|i| ((r * elems + i) % 97) as f32).collect())
        .collect();
    let mut driver = Driver::new(1);
    for _ in 0..2 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        if matches!(kind, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec.exchange_stats = true;
        } else {
            spec = spec.reliable();
        }
        let res = driver.run(&mut cluster, &ws, &spec);
        assert!(
            res.completed,
            "{kind:?}/{cc:?}/{sched:?}: run did not complete"
        );
    }
    format!(
        "t={} ev={} metrics={}",
        cluster.time,
        cluster.events_processed,
        cluster.metrics.to_json().to_string_compact()
    )
}

/// (c) The CC v2 grid: every algorithm over both engine families (the
/// best-effort engine and the shared reliable engine) must be replayable
/// AND scheduler-invariant — the `cc_sweep` bench rests on this.
#[test]
fn cc_grid_same_seed_same_metrics_wheel_and_heap() {
    for cc in optinic::cc::CcKind::ALL {
        for kind in [TransportKind::OptinicHw, TransportKind::Irn] {
            let a = cc_fingerprint(kind, cc, SchedKind::Wheel);
            let b = cc_fingerprint(kind, cc, SchedKind::Wheel);
            assert_eq!(a, b, "{kind:?}/{cc:?}: wheel replay diverged");
            let h = cc_fingerprint(kind, cc, SchedKind::Heap);
            assert_eq!(a, h, "{kind:?}/{cc:?}: wheel-vs-heap parity broken");
        }
    }
}
