//! Determinism regression suite for the event-engine overhaul.
//!
//! Contract: same seed + same config ⇒ bit-identical simulation — clock,
//! event count, and every metric — (a) across repeated runs and (b)
//! across the timing-wheel and reference-heap schedulers, for EVERY
//! transport variant. The fingerprint is the full `Metrics::to_json()`
//! serialization plus the engine clock and event counter, so any drift
//! in packet order, RNG consumption, timer behavior, or train coalescing
//! shows up as a diff.

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::sim::SchedKind;
use optinic::transport::TransportKind;

/// Run a small but adversarial workload (loss + background traffic +
/// adaptive timeouts, two iterations so estimator state carries over) and
/// fingerprint the entire observable simulation state.
fn fingerprint(kind: TransportKind, sched: SchedKind) -> String {
    let mut fab = FabricCfg::cloudlab(4);
    fab.corrupt_prob = 2e-4; // loss/retransmission paths exercised
    fingerprint_on(fab, kind, sched)
}

/// Same fingerprint over an arbitrary fabric shape (the leaf–spine grid
/// reuses the workload with multi-hop routing/spraying in play).
fn fingerprint_on(fab: FabricCfg, kind: TransportKind, sched: SchedKind) -> String {
    let nodes = fab.nodes;
    let elems = 8 * 1024; // 32 KB message
    let cfg = ClusterCfg::new(fab, kind)
        .with_seed(42)
        .with_bg_load(0.2)
        .with_scheduler(sched);
    let mut cluster = Cluster::new(cfg);
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..nodes)
        .map(|r| (0..elems).map(|i| ((r * elems + i) % 97) as f32).collect())
        .collect();
    let mut driver = Driver::new(1);
    for _ in 0..2 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        if matches!(kind, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec.exchange_stats = true;
        } else {
            spec = spec.reliable();
        }
        let res = driver.run(&mut cluster, &ws, &spec);
        assert!(res.completed, "{kind:?}/{sched:?}: run did not complete");
    }
    format!(
        "t={} ev={} metrics={}",
        cluster.time,
        cluster.events_processed,
        cluster.metrics.to_json().to_string_compact()
    )
}

/// (a) Replay determinism on the default (wheel) scheduler.
#[test]
fn same_seed_same_metrics_all_transports() {
    for kind in TransportKind::ALL_WITH_VARIANTS {
        let a = fingerprint(kind, SchedKind::Wheel);
        let b = fingerprint(kind, SchedKind::Wheel);
        assert_eq!(a, b, "{kind:?}: wheel replay diverged");
    }
}

/// (b) Wheel-vs-heap parity: the scheduler backend must be invisible.
#[test]
fn wheel_matches_heap_all_transports() {
    for kind in TransportKind::ALL_WITH_VARIANTS {
        let w = fingerprint(kind, SchedKind::Wheel);
        let h = fingerprint(kind, SchedKind::Heap);
        assert_eq!(w, h, "{kind:?}: wheel-vs-heap parity broken");
    }
}

/// (b') The same contracts over the leaf–spine fabric: multi-hop
/// routing, per-packet spraying, per-hop ECN, and per-port PFC must be
/// replayable AND scheduler-invariant for every transport variant.
#[test]
fn leaf_spine_replay_and_wheel_matches_heap_all_transports() {
    for kind in TransportKind::ALL_WITH_VARIANTS {
        let fab = || {
            let mut f = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            f.corrupt_prob = 2e-4;
            f
        };
        let a = fingerprint_on(fab(), kind, SchedKind::Wheel);
        let b = fingerprint_on(fab(), kind, SchedKind::Wheel);
        assert_eq!(a, b, "{kind:?}: leaf–spine wheel replay diverged");
        let h = fingerprint_on(fab(), kind, SchedKind::Heap);
        assert_eq!(a, h, "{kind:?}: leaf–spine wheel-vs-heap parity broken");
    }
}

/// Smaller workload for the CC grid (6 algorithms × 2 engine families ×
/// 3 runs each): same adversarial ingredients, fewer bytes.
fn cc_fingerprint(kind: TransportKind, cc: optinic::cc::CcKind, sched: SchedKind) -> String {
    let nodes = 4;
    let elems = 2 * 1024; // 8 KB message
    let mut fab = FabricCfg::cloudlab(nodes);
    fab.corrupt_prob = 2e-4;
    let cfg = ClusterCfg::new(fab, kind)
        .with_seed(42)
        .with_bg_load(0.2)
        .with_scheduler(sched)
        .with_cc(cc);
    let mut cluster = Cluster::new(cfg);
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..nodes)
        .map(|r| (0..elems).map(|i| ((r * elems + i) % 97) as f32).collect())
        .collect();
    let mut driver = Driver::new(1);
    for _ in 0..2 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        if matches!(kind, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec.exchange_stats = true;
        } else {
            spec = spec.reliable();
        }
        let res = driver.run(&mut cluster, &ws, &spec);
        assert!(
            res.completed,
            "{kind:?}/{cc:?}/{sched:?}: run did not complete"
        );
    }
    format!(
        "t={} ev={} metrics={}",
        cluster.time,
        cluster.events_processed,
        cluster.metrics.to_json().to_string_compact()
    )
}

/// (c) The CC v2 grid: every algorithm over both engine families (the
/// best-effort engine and the shared reliable engine) must be replayable
/// AND scheduler-invariant — the `cc_sweep` bench rests on this.
#[test]
fn cc_grid_same_seed_same_metrics_wheel_and_heap() {
    for cc in optinic::cc::CcKind::ALL {
        for kind in [TransportKind::OptinicHw, TransportKind::Irn] {
            let a = cc_fingerprint(kind, cc, SchedKind::Wheel);
            let b = cc_fingerprint(kind, cc, SchedKind::Wheel);
            assert_eq!(a, b, "{kind:?}/{cc:?}: wheel replay diverged");
            let h = cc_fingerprint(kind, cc, SchedKind::Heap);
            assert_eq!(a, h, "{kind:?}/{cc:?}: wheel-vs-heap parity broken");
        }
    }
}

// ---- jobs-parity suite (PR4: deterministic multicore sweep harness) --------
//
// Contract: the SAME grid run through the sweep runner with `--jobs 1`
// and `--jobs 4` must produce byte-identical merged Json — including the
// full `Metrics::to_json()` rows — for both scheduler backends. This is
// what lets every figure bench parallelize without touching simulation
// fidelity: cells are pure over their own `Cluster`, results are merged
// keyed by cell index in fixed grid order, and host wall-time never
// enters the merged output.

use optinic::util::bench::{CollectiveCell, InputSet};
use optinic::util::json::Json;
use optinic::util::sweep::SweepGrid;

/// A small but adversarial transport × size grid (loss + bg traffic +
/// CC forced on half the cells) whose cells return their summary Json
/// PLUS the complete metrics serialization of their private cluster.
fn parity_grid(sched: SchedKind) -> SweepGrid<(CollectiveCell, SchedKind)> {
    let mut cells = Vec::new();
    for kind in [
        TransportKind::Roce,
        TransportKind::Irn,
        TransportKind::Optinic,
        TransportKind::OptinicHw,
    ] {
        for (elems, cc) in [
            (2 * 1024usize, None),
            (4 * 1024, Some(optinic::cc::CcKind::Dcqcn)),
        ] {
            let mut fab = FabricCfg::cloudlab(4);
            fab.corrupt_prob = 2e-4;
            let mut cell =
                CollectiveCell::new(fab, kind, CollectiveKind::AllReduceRing, elems);
            cell.seed = 42;
            cell.bg_load = 0.2;
            cell.iters = 2;
            cell.cc = cc;
            cells.push((cell, sched));
        }
    }
    SweepGrid::new("jobs-parity", cells)
}

/// Cell body: ONE simulation of the cell spec under the scheduler being
/// tested, emitting the CCT samples AND the complete `Metrics::to_json()`
/// serialization — the merged output pins the full metric surface, not
/// just summaries.
fn parity_cell(spec: &(CollectiveCell, SchedKind), inputs: &InputSet) -> Json {
    let (cell, sched) = spec;
    let mut ccfg = ClusterCfg::new(cell.fabric.clone(), cell.transport)
        .with_seed(cell.seed)
        .with_bg_load(cell.bg_load)
        .with_scheduler(*sched);
    if let Some(k) = cell.cc {
        ccfg = ccfg.with_cc(k);
    }
    let mut cluster = Cluster::new(ccfg);
    let ws = Workspace::new(&mut cluster, cell.elems, 1);
    let ranks = inputs.ranks(cluster.nodes(), cell.elems);
    let mut driver = Driver::new(1);
    let mut ccts = Vec::new();
    for _ in 0..cell.iters {
        ws.load_input_slices(&mut cluster, &ranks);
        let mut spec = CollectiveSpec::new(cell.kind, cell.elems);
        spec.exchange_stats = cell.exchange_stats;
        if cell.reliable {
            spec = spec.reliable();
        }
        let res = driver.run(&mut cluster, &ws, &spec);
        ccts.push(Json::Num(res.cct_ns as f64));
    }
    let mut o = Json::obj();
    o.set("transport", cell.transport.name())
        .set("cct_ns", Json::Arr(ccts))
        .set("t", cluster.time)
        .set("ev", cluster.events_processed)
        .set("metrics", cluster.metrics.to_json());
    o
}

/// The headline acceptance test: `--jobs 1` vs `--jobs 4`, byte for
/// byte, on both scheduler backends.
#[test]
fn jobs_parity_merged_json_byte_identical() {
    for sched in [SchedKind::Wheel, SchedKind::Heap] {
        let grid = parity_grid(sched);
        let inputs = InputSet::ones(4 * 1024);
        let one = grid
            .clone()
            .with_jobs(1)
            .run(|_, spec| parity_cell(spec, &inputs));
        let four = grid
            .clone()
            .with_jobs(4)
            .run(|_, spec| parity_cell(spec, &inputs));
        let a = Json::Arr(one.results).to_string_pretty();
        let b = Json::Arr(four.results).to_string_pretty();
        assert_eq!(one.jobs, 1);
        assert_eq!(four.jobs, 4);
        assert!(a.contains("\"pkts_sent\""), "metrics rows must be pinned");
        assert_eq!(a, b, "{sched:?}: jobs=1 vs jobs=4 merged Json diverged");
    }
}

/// Leaf–spine jobs parity: a fig6-style topology × transport × CC grid
/// through the sweep runner, byte-comparing merged Json INCLUDING the
/// full metrics rows, at `--jobs 1` vs `--jobs 4`, on both scheduler
/// backends — the acceptance gate for parallelizing topology sweeps.
fn topo_parity_grid(sched: SchedKind) -> SweepGrid<(CollectiveCell, SchedKind)> {
    let mut cells = Vec::new();
    for leaf_spine in [false, true] {
        for kind in [
            TransportKind::Roce,
            TransportKind::Irn,
            TransportKind::Optinic,
        ] {
            for cc in [None, Some(optinic::cc::CcKind::Dcqcn), Some(optinic::cc::CcKind::Hpcc)]
            {
                let mut fab = FabricCfg::cloudlab(4);
                if leaf_spine {
                    fab = fab.with_leaf_spine(2, 2);
                }
                fab.corrupt_prob = 2e-4;
                let mut cell =
                    CollectiveCell::new(fab, kind, CollectiveKind::AllReduceRing, 2 * 1024);
                cell.seed = 42;
                cell.bg_load = 0.2;
                cell.iters = 2;
                cell.cc = cc;
                cells.push((cell, sched));
            }
        }
    }
    SweepGrid::new("topo-jobs-parity", cells)
}

#[test]
fn leaf_spine_jobs_parity_merged_json_byte_identical() {
    for sched in [SchedKind::Wheel, SchedKind::Heap] {
        let grid = topo_parity_grid(sched);
        let inputs = InputSet::ones(2 * 1024);
        let one = grid
            .clone()
            .with_jobs(1)
            .run(|_, spec| parity_cell(spec, &inputs));
        let four = grid
            .clone()
            .with_jobs(4)
            .run(|_, spec| parity_cell(spec, &inputs));
        let a = Json::Arr(one.results).to_string_pretty();
        let b = Json::Arr(four.results).to_string_pretty();
        assert!(a.contains("\"pkts_sent\""), "metrics rows must be pinned");
        assert_eq!(
            a, b,
            "{sched:?}: leaf–spine jobs=1 vs jobs=4 merged Json diverged"
        );
    }
}

// ---- serving-grid jobs parity (PR6: open-loop serving subsystem) -----------

use optinic::serving::{run_serving_cell, ArrivalKind, ServingCell};

/// The serve_sweep acceptance core: {OptiNIC, RoCE} × {poisson, diurnal}
/// × {single-switch, leaf-spine}, shrunk to a CI-sized request budget.
fn serving_parity_grid(sched: SchedKind) -> SweepGrid<ServingCell> {
    let mut cells = Vec::new();
    for leaf_spine in [false, true] {
        for arrival in [ArrivalKind::Poisson, ArrivalKind::diurnal_default()] {
            for transport in [TransportKind::Optinic, TransportKind::Roce] {
                let mut cell = ServingCell::new(transport, arrival, leaf_spine);
                cell.requests_per_tenant = 6;
                cell.scheduler = sched;
                cells.push(cell);
            }
        }
    }
    SweepGrid::new("serving-jobs-parity", cells)
}

/// Serving-grid jobs parity: the full open-loop serving stack (workload
/// generation, disaggregated pools, KV migration, SLO accounting) run
/// through the sweep harness must merge byte-identically for any worker
/// count, on both scheduler backends — the acceptance gate for
/// `serve_sweep --jobs N`.
#[test]
fn serving_jobs_parity_merged_json_byte_identical() {
    for sched in [SchedKind::Wheel, SchedKind::Heap] {
        let grid = serving_parity_grid(sched);
        let one = grid.clone().with_jobs(1).run(|_, cell| run_serving_cell(cell));
        let four = grid.clone().with_jobs(4).run(|_, cell| run_serving_cell(cell));
        let a = Json::Arr(one.results).to_string_pretty();
        let b = Json::Arr(four.results).to_string_pretty();
        assert_eq!(one.jobs, 1);
        assert_eq!(four.jobs, 4);
        assert!(
            a.contains("\"kv_bytes_moved\""),
            "serving rows must carry KV-migration accounting"
        );
        assert!(a.contains("\"ttft_p999_ns\""), "tail rows must be pinned");
        assert_eq!(a, b, "{sched:?}: serving jobs=1 vs jobs=4 diverged");
    }
}

/// Oversubscription parity: more workers than cells must change nothing.
#[test]
fn jobs_parity_oversubscribed() {
    let grid = parity_grid(SchedKind::Wheel);
    let inputs = InputSet::ones(4 * 1024);
    let a = grid
        .clone()
        .with_jobs(1)
        .run(|_, spec| parity_cell(spec, &inputs));
    let b = grid
        .clone()
        .with_jobs(64)
        .run(|_, spec| parity_cell(spec, &inputs));
    assert_eq!(
        Json::Arr(a.results).to_string_pretty(),
        Json::Arr(b.results).to_string_pretty()
    );
}

// ---- scenario-grid parity (PR7: adversarial burst/fault catalog) -----------

use optinic::scenarios::{run_scenario_cell, ScenarioCell, ScenarioKind};

/// The scenario_sweep acceptance core: every catalog entry × {OptiNIC,
/// RoCE} × {default CC, forced DBLP} on the leaf–spine fabric, shrunk to
/// a CI-sized workload. Choreography (phase-boundary incasts, stragglers,
/// rolling spine faults, SEU barrages) must be as replayable as the
/// engine it drives.
fn scenario_parity_grid(sched: SchedKind) -> SweepGrid<ScenarioCell> {
    let mut cells = Vec::new();
    for scenario in ScenarioKind::ALL {
        for transport in [TransportKind::Optinic, TransportKind::Roce] {
            for cc in [None, Some(optinic::cc::CcKind::Dblp)] {
                let mut cell = ScenarioCell::new(scenario, transport, true);
                cell.cc = cc;
                cell.elems = 4 * 1024;
                cell.iters = 2;
                cell.scheduler = sched;
                cells.push(cell);
            }
        }
    }
    SweepGrid::new("scenario-jobs-parity", cells)
}

/// Scenario-grid determinism: byte-identical merged scoreboards (which
/// embed the full `Metrics::to_json()` surface) across repeat runs,
/// wheel vs heap, and jobs=1 vs jobs=4 — the acceptance gate for
/// `scenario_sweep --jobs N` and the `optinic scenario` CLI.
#[test]
fn scenario_jobs_parity_merged_json_byte_identical() {
    let mut by_sched = Vec::new();
    for sched in [SchedKind::Wheel, SchedKind::Heap] {
        let grid = scenario_parity_grid(sched);
        let one = grid
            .clone()
            .with_jobs(1)
            .run(|_, cell| run_scenario_cell(cell));
        let four = grid
            .clone()
            .with_jobs(4)
            .run(|_, cell| run_scenario_cell(cell));
        let a = Json::Arr(one.results).to_string_pretty();
        let b = Json::Arr(four.results).to_string_pretty();
        assert!(
            a.contains("\"metrics\""),
            "scoreboards must embed the full metrics surface"
        );
        assert!(
            a.contains("\"faults_scheduled\""),
            "fault accounting must be pinned in the scoreboard"
        );
        assert_eq!(a, b, "{sched:?}: scenario jobs=1 vs jobs=4 diverged");
        // replay parity: a second serial pass is byte-identical too
        let again = grid
            .clone()
            .with_jobs(1)
            .run(|_, cell| run_scenario_cell(cell));
        assert_eq!(a, Json::Arr(again.results).to_string_pretty());
        by_sched.push(a);
    }
    assert_eq!(
        by_sched[0], by_sched[1],
        "scenario grid: wheel vs heap diverged"
    );
}

// ---- fat-tree + hybrid-fidelity engine (PR8: cluster-scale fabric) ---------

use optinic::net::{FidelityMode, NetFault};
use optinic::sim::{run_scale_cell, ScaleCell};

/// The 3-tier fabric under test: 2 pods × 2 leaves × 4 hosts, 2 spines
/// per pod, 2 cores — every path length (2/4/6 hops) and every tier of
/// ECMP choice is exercised by a 16-rank ring.
fn ft_fab() -> FabricCfg {
    let mut f = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
    f.corrupt_prob = 2e-4;
    f
}

/// (b'') The replay and scheduler-parity contracts over the 3-tier
/// fat-tree, through the full packet engine: tier-salted ECMP up-path
/// choices, core forwarding, and cross-pod spraying must be replayable
/// AND scheduler-invariant.
#[test]
fn fat_tree_replay_and_wheel_matches_heap() {
    for kind in [
        TransportKind::Roce,
        TransportKind::Irn,
        TransportKind::Optinic,
        TransportKind::OptinicHw,
    ] {
        let a = fingerprint_on(ft_fab(), kind, SchedKind::Wheel);
        let b = fingerprint_on(ft_fab(), kind, SchedKind::Wheel);
        assert_eq!(a, b, "{kind:?}: fat-tree wheel replay diverged");
        let h = fingerprint_on(ft_fab(), kind, SchedKind::Heap);
        assert_eq!(a, h, "{kind:?}: fat-tree wheel-vs-heap parity broken");
    }
}

/// Fat-tree sweep-harness parity: the multi-pod grid merged through the
/// parallel runner must stay byte-identical for any worker count.
fn fat_tree_parity_grid(sched: SchedKind) -> SweepGrid<(CollectiveCell, SchedKind)> {
    let mut cells = Vec::new();
    for kind in [TransportKind::Roce, TransportKind::Optinic] {
        for cc in [None, Some(optinic::cc::CcKind::Hpcc)] {
            let mut cell = CollectiveCell::new(ft_fab(), kind, CollectiveKind::AllReduceRing, 2 * 1024);
            cell.seed = 42;
            cell.bg_load = 0.2;
            cell.iters = 2;
            cell.cc = cc;
            cells.push((cell, sched));
        }
    }
    SweepGrid::new("fat-tree-jobs-parity", cells)
}

#[test]
fn fat_tree_jobs_parity_merged_json_byte_identical() {
    for sched in [SchedKind::Wheel, SchedKind::Heap] {
        let grid = fat_tree_parity_grid(sched);
        let inputs = InputSet::ones(2 * 1024);
        let one = grid
            .clone()
            .with_jobs(1)
            .run(|_, spec| parity_cell(spec, &inputs));
        let four = grid
            .clone()
            .with_jobs(4)
            .run(|_, spec| parity_cell(spec, &inputs));
        let a = Json::Arr(one.results).to_string_pretty();
        let b = Json::Arr(four.results).to_string_pretty();
        assert!(a.contains("\"pkts_sent\""), "metrics rows must be pinned");
        assert_eq!(
            a, b,
            "{sched:?}: fat-tree jobs=1 vs jobs=4 merged Json diverged"
        );
    }
}

/// A small hybrid-engine grid over the same fat-tree: fidelity × spray ×
/// flat/hierarchical, each cell with a mid-run up-link failure so the
/// fault → designation → reroute machinery is inside the fingerprint.
fn hybrid_scale_grid(sched: SchedKind) -> Vec<ScaleCell> {
    let mut cells = Vec::new();
    for fidelity in [FidelityMode::Packet, FidelityMode::Flow, FidelityMode::Hybrid] {
        for (spray, hier) in [(false, false), (true, false), (false, true)] {
            let fab = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
            let mut cell = ScaleCell::new(fab, CollectiveKind::AllReduceRing, 16 * 1024);
            cell.fidelity = fidelity;
            cell.spray = spray;
            cell.hier = hier;
            cell.sched = sched;
            // link 17 is a pod-0 leaf→spine up-link (ids 16..24 are up1)
            cell.faults = vec![(5_000, NetFault::LinkDown(17))];
            cells.push(cell);
        }
    }
    cells
}

/// Hybrid-engine determinism: every cell of the fidelity grid replays
/// bit-identically (full `ScaleResult`, tails + engine accounting) and
/// is invariant to the scheduler backend — the acceptance gate for
/// `scale_sweep` and `optinic sweep --fidelity`.
#[test]
fn hybrid_scale_grid_replay_and_wheel_matches_heap() {
    let wheel: Vec<_> = hybrid_scale_grid(SchedKind::Wheel)
        .iter()
        .map(run_scale_cell)
        .collect();
    let again: Vec<_> = hybrid_scale_grid(SchedKind::Wheel)
        .iter()
        .map(run_scale_cell)
        .collect();
    assert_eq!(wheel, again, "hybrid grid: wheel replay diverged");
    let heap: Vec<_> = hybrid_scale_grid(SchedKind::Heap)
        .iter()
        .map(run_scale_cell)
        .collect();
    assert_eq!(wheel, heap, "hybrid grid: wheel-vs-heap parity broken");
    assert!(wheel.iter().all(|r| r.completed), "grid cell stalled");
}

/// Where the policy forces packet fidelity (every chunk below the bulk
/// threshold), hybrid must equal the packet reference EXACTLY — same
/// tails, same flow/packet/resolve counts (docs/SCALE.md §Validation).
#[test]
fn hybrid_equals_packet_exactly_when_policy_forces_packet() {
    let mk = |fidelity| {
        let fab = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
        // 16 Ki elems → 4 KiB ring chunks, far below the 256 KiB bulk
        // threshold: the hybrid policy sends every flow down the packet path
        let mut cell = ScaleCell::new(fab, CollectiveKind::AllReduceRing, 16 * 1024);
        cell.fidelity = fidelity;
        cell.spray = true;
        cell
    };
    let hybrid = run_scale_cell(&mk(FidelityMode::Hybrid));
    let packet = run_scale_cell(&mk(FidelityMode::Packet));
    assert_eq!(hybrid.fluid_started, 0, "sub-threshold flows must not go fluid");
    assert_eq!(hybrid, packet, "hybrid != packet where policy forces packet");
}

// ---- partitioned-engine parity (PR9: multi-core single-run DES) ------------
//
// Contract: the partitioned conservative engine (`--cores N`) is a pure
// wall-clock knob. `--cores 1` runs the identical windowed code on one
// worker — THE single-core oracle — and any larger core count must
// reproduce its full fingerprint (clock, event count, complete
// `Metrics::to_json()`) byte for byte, on both scheduler backends.
// Single-switch fabrics have one partition and fall back to the legacy
// loop, so the grid below uses the two multi-tier fabrics.

/// Partitioned-engine fingerprint: the adversarial workload (loss + bg
/// traffic + 2 carried-over iterations) at a given worker count.
fn partitioned_fingerprint(
    fab: FabricCfg,
    kind: TransportKind,
    cc: Option<optinic::cc::CcKind>,
    sched: SchedKind,
    cores: usize,
) -> String {
    let nodes = fab.nodes;
    let elems = 4 * 1024; // 16 KB message
    let mut cfg = ClusterCfg::new(fab, kind)
        .with_seed(42)
        .with_bg_load(0.2)
        .with_scheduler(sched)
        .with_cores(cores);
    if let Some(k) = cc {
        cfg = cfg.with_cc(k);
    }
    let mut cluster = Cluster::new(cfg);
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..nodes)
        .map(|r| (0..elems).map(|i| ((r * elems + i) % 97) as f32).collect())
        .collect();
    let mut driver = Driver::new(1);
    for _ in 0..2 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        if matches!(kind, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec.exchange_stats = true;
        } else {
            spec = spec.reliable();
        }
        let res = driver.run(&mut cluster, &ws, &spec);
        assert!(
            res.completed,
            "{kind:?}/{cc:?}/{sched:?}/cores={cores}: run did not complete"
        );
    }
    format!(
        "t={} ev={} metrics={}",
        cluster.time,
        cluster.events_processed,
        cluster.metrics.to_json().to_string_compact()
    )
}

/// The leaf–spine fabric of the partitioned grid (2 partitions).
fn ls_fab() -> FabricCfg {
    let mut f = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
    f.corrupt_prob = 2e-4;
    f
}

/// The headline PR9 acceptance test: transport × CC × {leaf–spine,
/// fat-tree}, `--cores 1` vs `--cores 4`, full-fingerprint byte compare,
/// with BOTH the wheel and the heap as the single-core oracle.
#[test]
fn partitioned_matches_single_core_byte_identical() {
    let fabs: [fn() -> FabricCfg; 2] = [ls_fab, ft_fab];
    // CC forcing mirrors the cc_grid suite: both engine families
    let combos: [(TransportKind, Option<optinic::cc::CcKind>); 6] = [
        (TransportKind::Roce, None),
        (TransportKind::Irn, None),
        (TransportKind::Optinic, None),
        (TransportKind::OptinicHw, None),
        (TransportKind::OptinicHw, Some(optinic::cc::CcKind::Dcqcn)),
        (TransportKind::Irn, Some(optinic::cc::CcKind::Dcqcn)),
    ];
    for fab in fabs {
        for (kind, cc) in combos {
            for sched in [SchedKind::Wheel, SchedKind::Heap] {
                let one = partitioned_fingerprint(fab(), kind, cc, sched, 1);
                let four = partitioned_fingerprint(fab(), kind, cc, sched, 4);
                assert_eq!(
                    one, four,
                    "{kind:?}/{cc:?}/{sched:?}: cores=1 vs cores=4 diverged"
                );
            }
        }
    }
}

/// Mid-run spine failure: both up-links into spine 0 and its down-links
/// die at the same instant in DIFFERENT partitions (and at the spine's
/// owner), then recover — pinning cross-partition `Event::NetFault`
/// ordering through the reroute machinery, cores=1 vs cores=4, on both
/// scheduler backends.
#[test]
fn partitioned_spine_fault_ordering_byte_identical() {
    let run = |sched: SchedKind, cores: usize| {
        let fab = ls_fab();
        let topo = fab.topology();
        let nodes = fab.nodes;
        let elems = 4 * 1024;
        let cfg = ClusterCfg::new(fab, TransportKind::Optinic)
            .with_seed(42)
            .with_bg_load(0.2)
            .with_scheduler(sched)
            .with_cores(cores);
        let mut cluster = Cluster::new(cfg);
        let dead = [
            topo.up_link(0, 0),
            topo.up_link(1, 0),
            topo.down_link(0, 0),
            topo.down_link(0, 1),
        ];
        for l in dead {
            cluster.schedule_net_fault(20_000, NetFault::LinkDown(l));
            cluster.schedule_net_fault(600_000, NetFault::LinkUp(l));
        }
        let ws = Workspace::new(&mut cluster, elems, 1);
        let inputs: Vec<Vec<f32>> = (0..nodes)
            .map(|r| (0..elems).map(|i| ((r * elems + i) % 97) as f32).collect())
            .collect();
        let mut driver = Driver::new(1);
        for _ in 0..2 {
            ws.load_inputs(&mut cluster, &inputs);
            let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
            spec.exchange_stats = true;
            let res = driver.run(&mut cluster, &ws, &spec);
            assert!(res.completed, "{sched:?}/cores={cores}: spine-fault run stalled");
        }
        format!(
            "t={} ev={} metrics={}",
            cluster.time,
            cluster.events_processed,
            cluster.metrics.to_json().to_string_compact()
        )
    };
    for sched in [SchedKind::Wheel, SchedKind::Heap] {
        let one = run(sched, 1);
        assert_eq!(one, run(sched, 4), "{sched:?}: spine-fault cores parity broken");
        assert_eq!(one, run(sched, 2), "{sched:?}: spine-fault cores=2 parity broken");
    }
}

// ---- CC-authoritative rate plane (PR10: one CC seam, both engines) ---------
//
// Contract: with `ScaleCell::cc` forced, every fluid/hybrid cell drives
// its flows through the SAME `RateAuthority` seam the packet engine
// uses — synthesized epoch signals, capped water-fill, credit grants —
// and the coupled plane must be exactly as deterministic as the
// uncoupled one: replayable, scheduler-invariant, core-count-invariant,
// byte for byte over the full `ScaleResult` (which embeds `cc_epochs`
// and `cc_marks`).

use optinic::cc::CcKind;

/// The CC-coupled fluid grid: {DCQCN, Swift, EQDS, DBLP} × {Flow,
/// Hybrid} × {leaf–spine, fat-tree}, each with a mid-run up-link
/// failure so LossHint synthesis and post-reroute re-solves are inside
/// the byte-compared fingerprint. Chunk sizes sit at the bulk threshold
/// so hybrid cells exercise the fluid path.
fn cc_fluid_grid(sched: SchedKind, cores: Option<usize>) -> Vec<ScaleCell> {
    let mut cells = Vec::new();
    for cc in [CcKind::Dcqcn, CcKind::Swift, CcKind::Eqds, CcKind::Dblp] {
        for fidelity in [FidelityMode::Flow, FidelityMode::Hybrid] {
            // leaf–spine: 2×2, 4 ranks; kill one leaf-0 up-link
            let ls = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            let up = ls.topology().up_link(0, 0);
            let mut cell = ScaleCell::new(ls, CollectiveKind::AllReduceRing, 256 * 1024);
            cell.fidelity = fidelity;
            cell.sched = sched;
            cell.iters = 2;
            cell.faults = vec![(5_000, NetFault::LinkDown(up))];
            cell.cores = cores;
            cells.push(cell.with_cc(cc));
            // fat-tree: 2/2/2/2, 16 ranks; link 17 is a pod-0 leaf→spine
            // up-link (ids 16..24 are up1), as in the hybrid grid above
            let ft = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
            let mut cell = ScaleCell::new(ft, CollectiveKind::AllReduceRing, 1024 * 1024);
            cell.fidelity = fidelity;
            cell.sched = sched;
            cell.iters = 2;
            cell.faults = vec![(5_000, NetFault::LinkDown(17))];
            cell.cores = cores;
            cells.push(cell.with_cc(cc));
        }
    }
    cells
}

/// The headline PR10 determinism gate: replay, wheel-vs-heap, and
/// cores=1 vs cores=4 over the CC-coupled grid, full `ScaleResult`
/// byte compare — and every cell must actually run the coupled plane
/// (`cc_epochs > 0`) rather than silently dropping the forced CC.
#[test]
fn fluid_cc_replay_wheel_heap_cores_parity() {
    let wheel: Vec<_> = cc_fluid_grid(SchedKind::Wheel, None)
        .iter()
        .map(run_scale_cell)
        .collect();
    let again: Vec<_> = cc_fluid_grid(SchedKind::Wheel, None)
        .iter()
        .map(run_scale_cell)
        .collect();
    assert_eq!(wheel, again, "CC-coupled grid: wheel replay diverged");
    let heap: Vec<_> = cc_fluid_grid(SchedKind::Heap, None)
        .iter()
        .map(run_scale_cell)
        .collect();
    assert_eq!(wheel, heap, "CC-coupled grid: wheel-vs-heap parity broken");
    let cores: Vec<_> = cc_fluid_grid(SchedKind::Wheel, Some(4))
        .iter()
        .map(run_scale_cell)
        .collect();
    assert_eq!(wheel, cores, "CC-coupled grid: cores=1 vs cores=4 diverged");
    for r in &wheel {
        assert!(r.completed, "CC-coupled cell stalled");
        assert!(r.cc_epochs > 0, "forced CC must drive the coupled plane");
    }
}

/// Calibration: for EVERY CcKind, the CC-coupled fluid solver's tail
/// must track the CC-coupled packet-walk reference within the
/// documented 15% tolerance (docs/SCALE.md §CC-coupled rate law) — the
/// two engine families read the same seam, so forcing a policy must
/// bend both tails together, not just one.
#[test]
fn fluid_cc_tracks_packet_reference() {
    for cc in CcKind::ALL {
        let mk = |fidelity| {
            // 4-rank single-switch ring, 160 KiB chunks: big enough for
            // several CC epochs, small enough for a packet reference
            let fab = FabricCfg::cloudlab(4);
            let mut cell = ScaleCell::new(fab, CollectiveKind::AllReduceRing, 160 * 1024);
            cell.fidelity = fidelity;
            cell.iters = 1;
            cell.with_cc(cc)
        };
        let fluid = run_scale_cell(&mk(FidelityMode::Flow));
        let packet = run_scale_cell(&mk(FidelityMode::Packet));
        assert!(fluid.completed && packet.completed, "{cc:?}: cell stalled");
        assert!(fluid.fluid_started > 0, "{cc:?}: Flow fidelity must go fluid");
        assert!(packet.pkts_walked > 0, "{cc:?}: reference must walk packets");
        assert!(fluid.cc_epochs > 0 && packet.cc_epochs > 0);
        let (f, p) = (fluid.p99_ns as f64, packet.p99_ns as f64);
        let ratio = f / p;
        assert!(
            (0.85..=1.15).contains(&ratio),
            "{cc:?}: fluid p99 {f} vs packet p99 {p}: ratio {ratio:.3} \
             outside the documented 15% tolerance"
        );
    }
}

/// The tentpole's zero-branch guard: the fluid engine must not dispatch
/// on the CC algorithm anywhere in non-test code — every policy
/// decision flows through the shared `RateAuthority` seam, so adding an
/// eighth CcKind cannot require touching net/flowsim.rs. (The type name
/// may appear in imports and signatures; path-qualified variants — the
/// `::` form — are what a per-engine branch would need.)
#[test]
fn flowsim_has_no_cc_kind_branches() {
    let src = include_str!("../src/net/flowsim.rs");
    let body = src
        .split("#[cfg(test)]")
        .next()
        .expect("split always yields a first segment");
    let pat = concat!("CcKind", "::");
    assert!(
        !body.contains(pat),
        "net/flowsim.rs non-test code mentions `{pat}` — the fluid engine \
         must stay policy-agnostic behind the RateAuthority seam"
    );
}

/// Where hybrid takes the fluid fast path (256 KiB ring chunks), its
/// tail CCT must track the packet reference within the documented 15%
/// store-and-forward tolerance — the integration-level validation cell.
#[test]
fn hybrid_tail_cct_tracks_packet_reference_within_tolerance() {
    let mk = |fidelity| {
        let fab = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
        // 1 Mi elems → 256 KiB (64-MTU) chunks, right at the bulk threshold
        let mut cell = ScaleCell::new(fab, CollectiveKind::AllReduceRing, 1024 * 1024);
        cell.fidelity = fidelity;
        cell.iters = 1;
        cell
    };
    let hybrid = run_scale_cell(&mk(FidelityMode::Hybrid));
    let packet = run_scale_cell(&mk(FidelityMode::Packet));
    assert!(hybrid.completed && packet.completed);
    assert!(hybrid.fluid_started > 0, "bulk chunks must take the fluid path");
    let (h, p) = (hybrid.p99_ns as f64, packet.p99_ns as f64);
    let ratio = h / p;
    assert!(
        (0.85..=1.15).contains(&ratio),
        "hybrid p99 {h} vs packet p99 {p}: ratio {ratio:.3} outside the \
         documented 15% tolerance"
    );
}
