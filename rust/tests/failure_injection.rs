//! Failure-injection integration tests: SEU bit-flips into live transport
//! state (§2.4) and adversarial network conditions. The contract under
//! test: OptiNIC keeps completing (self-healing 52 B state), reliable
//! designs may stall but must never return corrupt data.

use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;

fn run_with_faults(transport: TransportKind, faults: usize, seed: u64) -> (usize, usize, usize) {
    let mut fab = FabricCfg::cloudlab(4);
    fab.corrupt_prob = 0.0;
    let mut cluster = Cluster::new(ClusterCfg::new(fab, transport).with_seed(seed));
    let elems = 16 * 1024;
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems]).collect();
    // inject faults spread over the first ~10 ms
    for i in 0..faults {
        cluster.schedule_fault(100_000 + i as u64 * 700_000);
    }
    let mut driver = Driver::new(1);
    let mut ok = 0;
    let mut failed = 0;
    for _ in 0..12 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        spec.exchange_stats = true;
        if !matches!(transport, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec = spec.reliable();
        }
        cluster.cfg.max_sim_time = cluster.time + 100 * optinic::sim::MS;
        let res = driver.run(&mut cluster, &ws, &spec);
        if res.completed && !res.per_rank.iter().any(|r| r.failed) {
            ok += 1;
        } else {
            failed += 1;
            break;
        }
    }
    (ok, failed, cluster.total_stalled_qps())
}

#[test]
fn optinic_survives_fault_barrage() {
    let (ok, failed, stalled) = run_with_faults(TransportKind::Optinic, 12, 5);
    assert_eq!(failed, 0, "OptiNIC must not fail under SEU faults");
    assert_eq!(stalled, 0, "OptiNIC QPs never stall");
    assert_eq!(ok, 12);
}

#[test]
fn reliable_designs_never_return_corrupt_data_under_faults() {
    // RoCE may stall (that's the point), but any collective it *does*
    // complete must be exact.
    let mut fab = FabricCfg::cloudlab(4);
    fab.corrupt_prob = 0.0;
    let mut cluster = Cluster::new(ClusterCfg::new(fab, TransportKind::Roce).with_seed(6));
    let elems = 8 * 1024;
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..4)
        .map(|r| (0..elems).map(|i| (r * elems + i) as f32 * 1e-3).collect())
        .collect();
    cluster.schedule_fault(150_000);
    cluster.schedule_fault(450_000);
    let mut driver = Driver::new(1);
    for _ in 0..6 {
        ws.load_inputs(&mut cluster, &inputs);
        let spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems).reliable();
        cluster.cfg.max_sim_time = cluster.time + 50 * optinic::sim::MS;
        let res = driver.run(&mut cluster, &ws, &spec);
        if !res.completed || res.per_rank.iter().any(|r| r.failed) {
            return; // stalled — acceptable for reliable designs
        }
        for r in 0..4 {
            let out = ws.read_output(&cluster, r, CollectiveKind::AllReduceRing);
            for i in 0..elems {
                let want: f32 = (0..4).map(|w| inputs[w][i]).sum();
                assert!(
                    (out[i] - want).abs() <= 1e-3 * (1.0 + want.abs()),
                    "rank {r} elem {i}: corrupt data returned: {} vs {want}",
                    out[i]
                );
            }
        }
    }
}

#[test]
fn fault_rate_ordering_follows_mtbf() {
    // scheduling from the SEU model: lower-MTBF designs receive more
    // upsets over the same horizon
    use optinic::hw::fault::schedule_faults;
    let horizon = 2 * optinic::sim::SEC;
    let mut counts = vec![];
    for kind in [TransportKind::Irn, TransportKind::Roce, TransportKind::Optinic] {
        let mut c = Cluster::new(ClusterCfg::new(FabricCfg::cloudlab(4), kind));
        counts.push(schedule_faults(&mut c, kind, horizon, 2e8, 9));
    }
    assert!(counts[0] > counts[1], "IRN (lowest MTBF) gets most faults");
    assert!(counts[1] > counts[2], "OptiNIC (highest MTBF) gets fewest");
}

/// Link-flap scenario over the leaf–spine fabric: BOTH spines blackhole
/// from 0.2 ms to 6 ms (covering the RoCE retry budget of ~8 × RTO ≈
/// 1.5 ms), then return. OptiNIC's deadline-bounded completion rides the
/// flap out — every rank finalizes (partially where it must) and the
/// collective completes. RoCE's cross-leaf QPs exhaust `max_retries`
/// during the blackhole and stall permanently (QP error), so its
/// collective never completes even after the links return.
#[test]
fn link_flap_optinic_completes_roce_stalls() {
    use optinic::hw::fault::schedule_spine_failure;
    let run = |transport: TransportKind| {
        let mut fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        fab.corrupt_prob = 0.0;
        let mut cluster = Cluster::new(ClusterCfg::new(fab, transport).with_seed(12));
        let down_at = 200_000; // 0.2 ms — mid-collective
        let up_at = 6_000_000; // 6 ms — well past the RoCE retry budget
        for spine in 0..2 {
            schedule_spine_failure(&mut cluster, spine, down_at, Some(up_at))
                .expect("leaf–spine fabric accepts spine failures");
        }
        let elems = 16 * 1024;
        let ws = Workspace::new(&mut cluster, elems, 1);
        let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems]).collect();
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        if matches!(transport, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec.exchange_stats = true;
        } else {
            spec = spec.reliable();
        }
        cluster.cfg.max_sim_time = cluster.time + 100 * optinic::sim::MS;
        let mut driver = Driver::new(1);
        let res = driver.run(&mut cluster, &ws, &spec);
        let any_failed = res.per_rank.iter().any(|r| r.failed);
        (
            res.completed,
            any_failed,
            cluster.total_stalled_qps(),
            cluster.metrics.counter("net_faults"),
        )
    };
    let (ok, failed, stalled, faults) = run(TransportKind::Optinic);
    assert!(faults >= 8, "spine flaps must actually fire");
    assert!(ok, "OptiNIC must complete through a spine flap");
    assert!(!failed, "OptiNIC ranks must not fail");
    assert_eq!(stalled, 0, "OptiNIC QPs never stall");
    let (ok, failed, stalled, _) = run(TransportKind::Roce);
    assert!(
        !ok || failed || stalled > 0,
        "RoCE must stall on a flap outlasting its retry budget"
    );
}

#[test]
fn extreme_loss_still_terminates() {
    // 20% packet corruption: OptiNIC must still complete within bounds
    let mut fab = FabricCfg::cloudlab(4);
    fab.corrupt_prob = 0.2;
    let mut cluster =
        Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic).with_seed(8));
    let elems = 32 * 1024;
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems]).collect();
    ws.load_inputs(&mut cluster, &inputs);
    let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
    spec.exchange_stats = true;
    let mut driver = Driver::new(1);
    let res = driver.run(&mut cluster, &ws, &spec);
    assert!(res.completed, "bounded completion must hold at 20% loss");
    assert!(res.loss_fraction > 0.05, "loss should actually be observed");
}
