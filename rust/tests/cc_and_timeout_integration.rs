//! Integration tests for §3.1.3 (CC composability over best effort) and
//! §3.1.2 (adaptive timeouts + verb semantics) at the cluster level.

use optinic::cc::CcKind;
use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;

fn cct_with_cc(cc: CcKind, bg: f64) -> (u64, f64, bool) {
    // ablation: with_cc forces the algorithm — no EQDS substitution
    let cfg = ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::OptinicHw)
        .with_seed(31)
        .with_bg_load(bg)
        .with_cc(cc);
    let mut cluster = Cluster::new(cfg);
    let elems = 256 * 1024;
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems]).collect();
    let mut driver = Driver::new(1);
    let mut last = (0, 0.0, false);
    for _ in 0..3 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        spec.exchange_stats = true;
        let res = driver.run(&mut cluster, &ws, &spec);
        last = (res.cct_ns, res.loss_fraction, res.completed);
    }
    last
}

/// §3.1.3: every CC scheme completes collectives over the best-effort
/// substrate (EQDS is the default; the others must also function).
#[test]
fn all_cc_schemes_compose_with_best_effort() {
    for cc in [CcKind::Eqds, CcKind::Dcqcn, CcKind::Swift, CcKind::Timely, CcKind::Hpcc] {
        let (cct, loss, completed) = cct_with_cc(cc, 0.15);
        assert!(completed, "{}: did not complete", cc.name());
        assert!(cct > 0);
        assert!(loss < 0.35, "{}: excessive loss {loss}", cc.name());
    }
}

/// Adaptive timeouts tighten over repeated invocations and stay above the
/// actual completion time in the steady state.
#[test]
fn adaptive_timeout_tracks_cct() {
    let mut cluster = Cluster::new(
        ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::Optinic)
            .with_seed(17)
            .with_bg_load(0.1),
    );
    let elems = 128 * 1024;
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems]).collect();
    let mut driver = Driver::new(5);
    let mut history = vec![];
    for _ in 0..8 {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        spec.exchange_stats = true;
        let res = driver.run(&mut cluster, &ws, &spec);
        assert!(res.completed);
        history.push((res.timeout_used.unwrap(), res.cct_ns));
    }
    // warmup bound is generous; converged bound is much tighter
    let (t_first, _) = history[0];
    let (t_last, cct_last) = *history.last().unwrap();
    assert!(
        t_last < t_first / 2,
        "timeout should tighten: {t_first} → {t_last}"
    );
    // steady state: timeout within [1x, 8x] of actual CCT
    assert!(t_last as f64 >= cct_last as f64 * 0.9, "{t_last} vs {cct_last}");
    assert!(
        (t_last as f64) < cct_last as f64 * 8.0,
        "timeout {t_last} too loose vs cct {cct_last}"
    );
}

/// One-sided WRITE under OptiNIC: placement via RETH on every fragment;
/// sender completes on transmit; no recv WQE involved.
#[test]
fn one_sided_write_places_data() {
    use optinic::sim::cluster::{App, AppCtx};
    use optinic::verbs::{CqEvent, MrId, NodeId, QpHandle, QpType, RemoteBuf, Wqe};

    struct Writer {
        qp: QpHandle,
        src: MrId,
        dst: MrId,
        done: bool,
        rkey: u32,
    }
    impl App for Writer {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            let wqe = Wqe::write(
                1,
                self.src,
                0,
                4096,
                RemoteBuf {
                    mr: self.dst,
                    offset: 128,
                    rkey: self.rkey,
                },
            )
            .with_timeout(5_000_000);
            ctx.endpoint().post_send(self.qp, wqe);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            match ev {
                CqEvent::SendDone { wr_id: 1, .. }
                | CqEvent::TimeoutFired { wr_id: 1, is_recv: false, .. } => {
                    self.done = true;
                }
                _ => {}
            }
        }
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: optinic::net::CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.done
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    let mut cluster =
        Cluster::new(ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic).with_seed(3));
    let src = cluster.mem.register(0, 4096);
    let dst = cluster.mem.register(1, 8192);
    cluster.mem.write_f32(src, 0, &vec![7.5f32; 1024]);
    let (qa, _qb) = cluster.connect(0, 1, QpType::Xp);
    let rkey = cluster.mem.rkey(dst);
    cluster.set_app(
        0,
        Box::new(Writer {
            qp: qa,
            src,
            dst,
            done: false,
            rkey,
        }),
    );
    cluster.start_apps();
    assert!(cluster.run());
    // sender completes on transmit (§3.1.2): drain in-flight fragments
    cluster.run_until(cluster.time + 10_000_000);
    // data should be placed at offset 128
    let placed = cluster.mem.read_f32(dst, 32, 1024);
    assert!(
        placed.iter().filter(|&&v| v == 7.5).count() >= 1000,
        "WRITE data not placed"
    );
}

/// PFC only engages for RoCE: under a 7-to-1 incast RoCE asserts pauses;
/// OptiNIC never touches PFC.
#[test]
fn pfc_engages_only_for_roce() {
    use optinic::sim::cluster::{App, AppCtx};
    use optinic::verbs::{CqEvent, MrId, NodeId, QpHandle, QpType, RemoteBuf, Wqe};

    struct Incaster {
        qp: QpHandle,
        src: MrId,
        dst: MrId,
        rkey: u32,
        done: bool,
    }
    impl App for Incaster {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            let wqe = Wqe::write(
                1,
                self.src,
                0,
                256 * 1024,
                RemoteBuf {
                    mr: self.dst,
                    offset: 0,
                    rkey: self.rkey,
                },
            )
            .with_timeout(200_000_000);
            ctx.endpoint().post_send(self.qp, wqe);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            if !ev.is_recv() {
                self.done = true;
            }
        }
        fn on_wake(&mut self, _c: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: optinic::net::CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.done
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    let run = |transport| {
        let mut fab = FabricCfg::cloudlab(8);
        fab.queue_cap_bytes = 128 * 1024;
        fab.pfc_xoff = 64 * 1024;
        fab.pfc_xon = 24 * 1024;
        let mut cluster =
            Cluster::new(ClusterCfg::new(fab, transport).with_seed(4).with_bg_load(0.0));
        // 7 writers blast node 0 simultaneously — real incast
        for sender in 1..8 {
            let src = cluster.mem.register(sender, 256 * 1024);
            let dst = cluster.mem.register(0, 256 * 1024);
            cluster.mem.fill(src, 0xAB);
            let (qa, _qb) = cluster.connect(sender, 0, QpType::Xp);
            let rkey = cluster.mem.rkey(dst);
            cluster.set_app(
                sender,
                Box::new(Incaster {
                    qp: qa,
                    src,
                    dst,
                    rkey,
                    done: false,
                }),
            );
        }
        cluster.cfg.max_sim_time = 2 * optinic::sim::SEC;
        cluster.start_apps();
        assert!(cluster.run(), "{transport:?} incast did not complete");
        cluster.run_until(cluster.time + 5_000_000);
        cluster.metrics.pfc_pause_events
    };
    let roce_pauses = run(TransportKind::Roce);
    let opt_pauses = run(TransportKind::Optinic);
    assert!(roce_pauses > 0, "RoCE under incast should trigger PFC");
    assert_eq!(opt_pauses, 0, "OptiNIC must not use PFC");
}
