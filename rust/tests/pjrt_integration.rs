//! Cross-layer integration: the Rust-native recovery hot path must agree
//! with the L1 Pallas kernel executed through PJRT (the AOT artifacts), and
//! the L2 model artifacts must compose with the L3 coordinator.
//!
//! Quarantined behind the `pjrt` feature: this whole file executes AOT'd
//! HLO through the XLA CPU client and requires `make artifacts` — both
//! environment-dependent. On a machine with the XLA toolchain, add the
//! `xla` dependency and run `cargo test --features pjrt`
//! (see rust/Cargo.toml for why the dep is not pre-declared).
#![cfg(feature = "pjrt")]

use optinic::recovery::hadamard::fwht_blocks;
use optinic::runtime::Engine;

#[test]
fn native_fwht_matches_pallas_kernel() {
    let mut engine = Engine::load_default().expect("run `make artifacts`");
    for (rows, p) in engine.hadamard_shapes() {
        let data: Vec<f32> = (0..rows * p)
            .map(|i| ((i * 2654435761) % 1000) as f32 / 500.0 - 1.0)
            .collect();
        let via_pjrt = engine.hadamard(rows, p, &data).unwrap();
        let mut native = data.clone();
        fwht_blocks(&mut native, p);
        let mut max_err = 0.0f32;
        for (a, b) in via_pjrt.iter().zip(native.iter()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < 1e-3,
            "{rows}x{p}: native vs Pallas max err {max_err}"
        );
    }
}

#[test]
fn gradient_roundtrip_through_codec_and_pjrt() {
    // real model gradients → encode (native) → decode → apply via PJRT:
    // the full training dataflow minus the network.
    let mut engine = Engine::load_default().expect("make artifacts");
    let info = engine.manifest.model("tiny").unwrap().clone();
    let params = engine.init_params("tiny").unwrap();
    let corpus = optinic::data::Corpus::new(info.vocab, 99);
    let toks = corpus.batch(info.batch, info.seq_len + 1, 0);
    let (_, grads) = engine.fwd_bwd("tiny", &params, &toks).unwrap();

    let codec = optinic::recovery::Codec::HadamardBlockStride { p: 256, stride: 64 };
    let wire = optinic::recovery::encode(&grads, codec);
    let back = optinic::recovery::decode(&wire, codec, grads.len());
    let mse = optinic::recovery::mse(&grads, &back);
    assert!(mse < 1e-10, "lossless roundtrip mse {mse}");

    // encoded-space reduction equals decoded-space reduction (linearity)
    let wire2: Vec<f32> = wire.iter().map(|v| v * 2.0).collect();
    let back2 = optinic::recovery::decode(&wire2, codec, grads.len());
    for (a, b) in back2.iter().zip(grads.iter()) {
        assert!((a - 2.0 * b).abs() < 1e-4);
    }
}

#[test]
fn model_tiers_all_load() {
    let e = Engine::load_default().expect("make artifacts");
    for name in ["tiny", "small", "medium"] {
        let info = e.manifest.model(name).unwrap();
        assert!(info.param_count > 0);
        let p = e.init_params(name).unwrap();
        assert_eq!(p.len(), info.param_count);
    }
}

#[test]
fn accuracy_artifact_consistent_with_infer() {
    // argmax(infer logits) vs targets must equal the accuracy artifact's
    // own computation (two independent HLO paths through the same model)
    let mut e = Engine::load_default().expect("make artifacts");
    let info = e.manifest.model("tiny").unwrap().clone();
    let params = e.init_params("tiny").unwrap();
    let corpus = optinic::data::Corpus::new(info.vocab, 7);
    let toks = corpus.batch(info.batch, info.seq_len + 1, 3);
    let acc = e.accuracy("tiny", &params, &toks).unwrap();
    assert!((0.0..=1.0).contains(&acc));

    // manual last-position check through infer
    let inp: Vec<i32> = toks
        .chunks(info.seq_len + 1)
        .flat_map(|row| row[..info.seq_len].to_vec())
        .collect();
    let logits = e.infer("tiny", &params, &inp).unwrap();
    assert_eq!(logits.len(), info.batch * info.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
}
