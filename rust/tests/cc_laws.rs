//! Property tests pinning each CC algorithm to its paper dynamics
//! (proptest_mini): DCQCN's multiplicative cut + staged recovery, Swift's
//! target-delay convergence, HPCC's utilization bound, EQDS credit
//! conservation. These run the control laws head-less through the CC v2
//! signal vocabulary — no transport, no event loop — so a regression in a
//! law cannot hide behind end-to-end noise.

use optinic::cc::eqds::Eqds;
use optinic::cc::{CcCtx, CcSignal, CongestionControl};
use optinic::prop_assert;
use optinic::sim::SimTime;
use optinic::util::proptest_mini::{check, Gen, IntRange, PropConfig, VecGen};

const LINE: f64 = 3.125; // 25 GbE, bytes/ns
const BASE_RTT: u64 = 5_000;

fn ctx(now: SimTime) -> CcCtx {
    CcCtx {
        now,
        qpn: 1,
        bytes: 0,
        hops: 2,
    }
}

fn cfg(seed: u64) -> PropConfig {
    PropConfig {
        cases: 96,
        seed,
        max_shrink_steps: 64,
    }
}

// ---- DCQCN: multiplicative cut + staged recovery ---------------------------

/// Every adequately-spaced mark cuts the rate multiplicatively (down to
/// the floor), and clean acknowledged bytes afterwards recover the rate
/// monotonically without ever exceeding line rate.
#[test]
fn dcqcn_cut_then_staged_recovery() {
    let gen = VecGen {
        elem: IntRange { lo: 1, hi: 8 },
        min_len: 1,
        max_len: 6,
    };
    check("dcqcn-cut-recovery", cfg(0xdc01), &gen, |marks: &Vec<u64>| {
        let mut cc = optinic::cc::CcKind::Dcqcn.build(LINE, BASE_RTT);
        let mut now: SimTime = 100_000;
        for &reps in marks {
            for _ in 0..reps {
                let before = cc.rate();
                cc.on_signal(CcSignal::EcnMark, &ctx(now));
                now += 60_000; // beyond the 50 µs cut guard
                prop_assert!(
                    cc.rate() <= before + 1e-12,
                    "mark raised rate {before} -> {}",
                    cc.rate()
                );
                prop_assert!(
                    cc.rate() >= LINE / 100.0 - 1e-12,
                    "rate fell through the floor: {}",
                    cc.rate()
                );
            }
        }
        let cut = cc.rate();
        prop_assert!(cut < LINE, "marks must have cut below line rate");
        // staged recovery: monotone, bounded by line rate
        let mut prev = cc.rate();
        for _ in 0..400 {
            cc.on_signal(
                CcSignal::AckBatch {
                    acked_bytes: 64 * 1024,
                    marked: false,
                },
                &ctx(now),
            );
            prop_assert!(
                cc.rate() >= prev - 1e-12,
                "recovery went backwards: {prev} -> {}",
                cc.rate()
            );
            prop_assert!(cc.rate() <= LINE + 1e-9, "exceeded line rate");
            prev = cc.rate();
        }
        prop_assert!(
            cc.rate() > cut,
            "no recovery from {cut} after clean acks"
        );
        Ok(())
    });
}

// ---- Swift: target-delay convergence ---------------------------------------

/// Sustained RTTs far above target drive the rate into the floor region;
/// sustained RTTs below target converge it back to line rate. Both hold
/// for any overshoot factor and any congestion-episode length.
#[test]
fn swift_target_delay_convergence() {
    // (overshoot factor ×10, congested updates)
    struct Case;
    impl Gen<(u64, u64)> for Case {
        fn generate(&self, rng: &mut optinic::util::prng::Pcg64) -> (u64, u64) {
            (30 + rng.below(170), 40 + rng.below(60))
        }
        fn shrink(&self, &(f, n): &(u64, u64)) -> Vec<(u64, u64)> {
            let mut out = Vec::new();
            if f > 30 {
                out.push((30, n));
            }
            if n > 40 {
                out.push((f, 40));
            }
            out
        }
    }
    check("swift-convergence", cfg(0x5f71), &Case, |&(f10, n)| {
        let target = 1.5 * BASE_RTT as f64 + 10_000.0; // Swift's target
        let mut cc = optinic::cc::CcKind::Swift.build(LINE, BASE_RTT);
        let mut now: SimTime = 1;
        // congestion: RTT = (f10/10)× target, one update per base RTT
        let high = (target * f10 as f64 / 10.0) as u64;
        for _ in 0..n {
            cc.on_signal(CcSignal::RttSample { rtt_ns: high }, &ctx(now));
            now += BASE_RTT;
        }
        prop_assert!(
            cc.rate() <= 0.05 * LINE,
            "rate {} did not collapse under {f10}/10x target RTT",
            cc.rate()
        );
        prop_assert!(cc.rate() > 0.0, "rate must stay positive");
        // drain: RTT well below target, spaced to max the additive step
        for _ in 0..200 {
            cc.on_signal(
                CcSignal::RttSample { rtt_ns: BASE_RTT },
                &ctx(now),
            );
            now += 10 * BASE_RTT;
        }
        prop_assert!(
            cc.rate() >= 0.99 * LINE,
            "rate {} did not converge back to line",
            cc.rate()
        );
        Ok(())
    });
}

// ---- HPCC: utilization bound ------------------------------------------------

/// On an idle port (empty queue, no measured output) the INT law leaves
/// the rate at line; with a standing queue of d × BDP (d ≥ 5) the rate
/// collapses below 0.2·line — and it recovers once the queue drains.
/// (The txRate side of the law is pinned by the unit tests in
/// `cc/hpcc.rs`: saturated port backs off, η-utilized port holds.)
#[test]
fn hpcc_utilization_bound() {
    let gen = IntRange { lo: 5, hi: 40 };
    check("hpcc-utilization", cfg(0x4bcc), &gen, |&d: &u64| {
        let bdp = LINE * BASE_RTT as f64;
        let mut cc = optinic::cc::CcKind::Hpcc.build(LINE, BASE_RTT);
        let mut now: SimTime = 1;
        let int = |qdepth: u32| CcSignal::IntTelemetry {
            qdepth,
            tx_bytes: 0,
            link_rate: LINE,
        };
        // empty queues: utilization target keeps the rate near line
        for _ in 0..100 {
            cc.on_signal(int(0), &ctx(now));
            now += 2 * BASE_RTT;
        }
        prop_assert!(
            cc.rate() >= 0.85 * LINE && cc.rate() <= LINE + 1e-9,
            "empty-queue rate {} outside [0.85, 1.0]·line",
            cc.rate()
        );
        // standing queue of d × BDP: collapse
        let deep = (d as f64 * bdp) as u32;
        for _ in 0..60 {
            cc.on_signal(int(deep), &ctx(now));
            now += 2 * BASE_RTT;
        }
        prop_assert!(
            cc.rate() <= 0.2 * LINE,
            "rate {} did not collapse under {d}x BDP queue",
            cc.rate()
        );
        prop_assert!(cc.rate() >= LINE / 1000.0 - 1e-12, "floor violated");
        // drain: recovery
        let low = cc.rate();
        for _ in 0..300 {
            cc.on_signal(int(0), &ctx(now));
            now += 2 * BASE_RTT;
        }
        prop_assert!(cc.rate() > low, "no recovery after queue drained");
        Ok(())
    });
}

// ---- EQDS: credit conservation ----------------------------------------------

/// Random interleavings of credit grants and transmission attempts keep
/// the books balanced: balances never go negative, admitted bytes beyond
/// the speculative window never exceed granted credit, refusal happens
/// only when neither bucket covers the request, and the conservation
/// identity consumed = granted − credit + speculation-spent holds exactly.
#[test]
fn eqds_credit_conservation() {
    let gen = VecGen {
        elem: IntRange { lo: 0, hi: 60_000 },
        min_len: 1,
        max_len: 64,
    };
    check("eqds-conservation", cfg(0xe9d5), &gen, |ops: &Vec<u64>| {
        let mut cc = Eqds::new(LINE, 10_000); // speculative = BDP = 31250
        let spec0 = cc.speculative_bytes();
        for &op in ops {
            let bytes = (op / 3 % 20_000) as usize + 1;
            match op % 3 {
                0 => cc.on_signal(CcSignal::CreditGrant { bytes }, &ctx(0)),
                _ => {
                    let spec_before = cc.speculative_bytes();
                    let credit_before = cc.credit_balance();
                    let sent = cc.try_send(bytes);
                    if !sent {
                        prop_assert!(
                            (bytes as i64) > spec_before && (bytes as i64) > credit_before,
                            "refused {bytes} B with spec={spec_before} credit={credit_before}"
                        );
                    }
                }
            }
            prop_assert!(cc.credit_balance() >= 0, "credit went negative");
            prop_assert!(cc.speculative_bytes() >= 0, "speculation went negative");
            let spent_spec = spec0 - cc.speculative_bytes();
            prop_assert!(
                cc.consumed_bytes() as i64
                    == cc.granted_bytes() as i64 - cc.credit_balance() + spent_spec,
                "conservation identity broken: consumed={} granted={} credit={} spec_spent={}",
                cc.consumed_bytes(),
                cc.granted_bytes(),
                cc.credit_balance(),
                spent_spec
            );
            // credits granted ≥ bytes admitted beyond speculation
            prop_assert!(
                cc.consumed_bytes() as i64 - spent_spec <= cc.granted_bytes() as i64,
                "admitted more than was ever granted"
            );
        }
        Ok(())
    });
}

/// Receiver side: the pull pacer never grants more than was announced,
/// and grants are always positive and chunk-bounded.
#[test]
fn eqds_grants_never_exceed_demand() {
    let gen = VecGen {
        elem: IntRange { lo: 0, hi: 30_000 },
        min_len: 1,
        max_len: 48,
    };
    check("eqds-grant-bound", cfg(0x6ea7), &gen, |ops: &Vec<u64>| {
        let mut cc = Eqds::new(LINE, 10_000);
        let mut announced: u64 = 0;
        for &op in ops {
            let bytes = (op / 2 % 10_000) as usize + 1;
            if op % 2 == 0 {
                cc.on_demand(bytes);
                announced += bytes as u64;
            } else if let Some((g, gap)) = cc.next_grant(bytes) {
                prop_assert!(g > 0 && g <= bytes, "grant {g} outside (0, chunk]");
                prop_assert!(gap >= 1, "grant pacing gap must be positive");
            }
            prop_assert!(
                cc.issued_bytes() + cc.demand_pending() as u64 == announced,
                "issued {} + pending {} != announced {announced}",
                cc.issued_bytes(),
                cc.demand_pending()
            );
        }
        Ok(())
    });
}
