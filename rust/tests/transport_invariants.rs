//! Property-based integration tests over the transport layer: randomized
//! message patterns, sizes, and loss rates, checking the invariants each
//! design must hold. Uses the in-crate property framework
//! (`util::proptest_mini`) — failures print a replayable seed.

use optinic::collectives::{chunk_bounds, CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::net::FabricCfg;
use optinic::prop_assert;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::proptest_mini::{check, Gen, IntRange, PropConfig};
use optinic::util::prng::Pcg64;

/// Random collective scenario.
#[derive(Clone, Debug)]
struct Scenario {
    nodes: usize,
    elems: usize,
    kind: CollectiveKind,
    corrupt_ppm: u64,
    bg_load_pct: u64,
    seed: u64,
}

struct ScenarioGen;

impl Gen<Scenario> for ScenarioGen {
    fn generate(&self, rng: &mut Pcg64) -> Scenario {
        let kinds = [
            CollectiveKind::AllReduceRing,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllToAll,
        ];
        Scenario {
            nodes: [2, 4, 8][rng.index(3)],
            elems: 256 << rng.below(6), // 256 .. 8192
            kind: kinds[rng.index(kinds.len())],
            corrupt_ppm: rng.below(3000),
            bg_load_pct: rng.below(30),
            seed: rng.next_u64(),
        }
    }
    fn shrink(&self, s: &Scenario) -> Vec<Scenario> {
        let mut out = vec![];
        if s.elems > 256 {
            let mut c = s.clone();
            c.elems /= 2;
            out.push(c);
        }
        if s.corrupt_ppm > 0 {
            let mut c = s.clone();
            c.corrupt_ppm = 0;
            out.push(c);
        }
        if s.bg_load_pct > 0 {
            let mut c = s.clone();
            c.bg_load_pct = 0;
            out.push(c);
        }
        out
    }
}

fn run_scenario(
    s: &Scenario,
    transport: TransportKind,
) -> (optinic::collectives::CollectiveResult, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut fab = FabricCfg::cloudlab(s.nodes);
    fab.corrupt_prob = s.corrupt_ppm as f64 / 1e6;
    let mut cluster = Cluster::new(
        ClusterCfg::new(fab, transport)
            .with_seed(s.seed)
            .with_bg_load(s.bg_load_pct as f64 / 100.0),
    );
    let ws = Workspace::new(&mut cluster, s.elems, 1);
    let mut rng = Pcg64::seeded(s.seed ^ 1);
    let inputs: Vec<Vec<f32>> = (0..s.nodes)
        .map(|_| (0..s.elems).map(|_| rng.normal() as f32).collect())
        .collect();
    ws.load_inputs(&mut cluster, &inputs);
    let mut spec = CollectiveSpec::new(s.kind, s.elems);
    spec.exchange_stats = true;
    if !matches!(transport, TransportKind::Optinic | TransportKind::OptinicHw) {
        spec = spec.reliable();
    }
    let mut driver = Driver::new(3);
    let res = driver.run(&mut cluster, &ws, &spec);
    let outputs = (0..s.nodes)
        .map(|r| ws.read_output(&cluster, r, s.kind))
        .collect();
    (res, inputs, outputs)
}

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        seed: 0xDEC0DE,
        max_shrink_steps: 12,
    }
}

/// OptiNIC invariant #1: bounded completion — every scenario terminates
/// (no deadlock, no unbounded stall), loss or not.
#[test]
fn optinic_always_completes() {
    check("optinic-always-completes", cfg(24), &ScenarioGen, |s| {
        let (res, _, _) = run_scenario(s, TransportKind::Optinic);
        prop_assert!(res.completed, "scenario {s:?} did not complete");
        Ok(())
    });
}

/// OptiNIC invariant #2: lossless fabric ⇒ numerically exact collectives
/// (best-effort ≠ sloppy; without drops the result is bit-comparable).
#[test]
fn optinic_exact_when_lossless() {
    check("optinic-exact-when-lossless", cfg(16), &ScenarioGen, |s| {
        let mut s = s.clone();
        s.corrupt_ppm = 0;
        s.bg_load_pct = 0;
        let (res, inputs, outputs) = run_scenario(&s, TransportKind::Optinic);
        prop_assert!(res.completed, "did not complete");
        verify_exact(&s, &inputs, &outputs)
    });
}

/// Reliable invariant: IRN delivers exact results even under loss.
#[test]
fn irn_exact_under_loss() {
    check("irn-exact-under-loss", cfg(12), &ScenarioGen, |s| {
        let mut s = s.clone();
        s.corrupt_ppm = s.corrupt_ppm.min(1500);
        let (res, inputs, outputs) = run_scenario(&s, TransportKind::Irn);
        prop_assert!(res.completed, "did not complete");
        verify_exact(&s, &inputs, &outputs)
    });
}

/// RoCE (GBN + PFC) also recovers exactly.
#[test]
fn roce_exact_under_loss() {
    check("roce-exact-under-loss", cfg(10), &ScenarioGen, |s| {
        let mut s = s.clone();
        s.corrupt_ppm = s.corrupt_ppm.min(1000);
        let (res, inputs, outputs) = run_scenario(&s, TransportKind::Roce);
        prop_assert!(res.completed, "did not complete");
        verify_exact(&s, &inputs, &outputs)
    });
}

/// OptiNIC invariant #3: under loss, the result is the exact result with
/// some elements zero-substituted — never garbage. For AllGather (no
/// arithmetic), every output element equals the true value or reflects a
/// zeroed span.
#[test]
fn optinic_loss_is_zero_substitution() {
    check("optinic-loss-zero-subst", cfg(12), &ScenarioGen, |s| {
        let mut s = s.clone();
        s.kind = CollectiveKind::AllGather;
        s.corrupt_ppm = 2000;
        let (res, inputs, outputs) = run_scenario(&s, TransportKind::Optinic);
        prop_assert!(res.completed, "did not complete");
        for (r, out) in outputs.iter().enumerate() {
            for c in 0..s.nodes {
                let b = chunk_bounds(c, s.nodes, s.elems);
                for i in b.start..b.start + b.len {
                    let want = inputs[c][i];
                    let got = out[i];
                    // own shard is local — always exact
                    if c == r {
                        prop_assert!(got == want, "own shard corrupted");
                        continue;
                    }
                    let ok = got == want || got == 0.0;
                    prop_assert!(
                        ok,
                        "rank {r} elem {i}: {got} is neither exact ({want}) nor zero"
                    );
                }
            }
        }
        Ok(())
    });
}

fn verify_exact(
    s: &Scenario,
    inputs: &[Vec<f32>],
    outputs: &[Vec<f32>],
) -> Result<(), String> {
    let n = s.nodes;
    match s.kind {
        CollectiveKind::AllReduceRing | CollectiveKind::AllReduceTree => {
            for out in outputs {
                for i in 0..s.elems {
                    let want: f32 = (0..n).map(|r| inputs[r][i]).sum();
                    prop_assert!(
                        (out[i] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                        "allreduce elem {i}: {} vs {want}",
                        out[i]
                    );
                }
            }
        }
        CollectiveKind::AllGather => {
            for out in outputs {
                for c in 0..n {
                    let b = chunk_bounds(c, n, s.elems);
                    for i in b.start..b.start + b.len {
                        prop_assert!(
                            out[i] == inputs[c][i],
                            "allgather chunk {c} elem {i}"
                        );
                    }
                }
            }
        }
        CollectiveKind::ReduceScatter => {
            for (r, out) in outputs.iter().enumerate() {
                let owned = (r + 1) % n;
                let b = chunk_bounds(owned, n, s.elems);
                for i in b.start..b.start + b.len {
                    let want: f32 = (0..n).map(|w| inputs[w][i]).sum();
                    prop_assert!(
                        (out[i] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                        "reducescatter rank {r} elem {i}"
                    );
                }
            }
        }
        CollectiveKind::AllToAll => {
            for (r, out) in outputs.iter().enumerate() {
                for c in 0..n {
                    let ob = chunk_bounds(c, n, s.elems);
                    let ib = chunk_bounds(r, n, s.elems);
                    for k in 0..ob.len.min(ib.len) {
                        prop_assert!(
                            out[ob.start + k] == inputs[c][ib.start + k],
                            "alltoall rank {r} chunk {c} slot {k}"
                        );
                    }
                }
            }
        }
    }
    Ok(())
}

/// Determinism: identical seeds produce identical results, event counts,
/// and byte counters across the whole stack.
#[test]
fn deterministic_replay() {
    let s = Scenario {
        nodes: 4,
        elems: 4096,
        kind: CollectiveKind::AllReduceRing,
        corrupt_ppm: 800,
        bg_load_pct: 20,
        seed: 777,
    };
    let (r1, _, o1) = run_scenario(&s, TransportKind::Optinic);
    let (r2, _, o2) = run_scenario(&s, TransportKind::Optinic);
    assert_eq!(r1.cct_ns, r2.cct_ns);
    assert_eq!(r1.bytes_received(), r2.bytes_received());
    assert_eq!(o1, o2);
}

/// Late packets must never corrupt memory: run with spray jitter (heavy
/// reordering) and verify AllGather under OptiNIC still yields
/// exact-or-zero data.
#[test]
fn reordering_never_corrupts() {
    for seed in [1u64, 2, 3] {
        let mut fab = FabricCfg::cloudlab(4);
        fab.spray_jitter_ns = 50_000;
        fab.corrupt_prob = 1e-3;
        let mut cluster =
            Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic).with_seed(seed));
        let elems = 4096;
        let ws = Workspace::new(&mut cluster, elems, 1);
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..elems).map(|i| (r * 10_000 + i) as f32).collect())
            .collect();
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllGather, elems);
        spec.exchange_stats = true;
        let mut driver = Driver::new(9);
        let res = driver.run(&mut cluster, &ws, &spec);
        assert!(res.completed);
        for r in 0..4 {
            let out = ws.read_output(&cluster, r, CollectiveKind::AllGather);
            for c in 0..4 {
                let b = chunk_bounds(c, 4, elems);
                for i in b.start..b.start + b.len {
                    let v = out[i];
                    assert!(
                        v == inputs[c][i] || (v == 0.0 && c != r),
                        "seed {seed} rank {r} elem {i}: {v} (want {} or 0)",
                        inputs[c][i]
                    );
                }
            }
        }
    }
}
