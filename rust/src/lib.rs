//! # OptiNIC — a resilient, tail-optimal RDMA transport for distributed ML
//!
//! Full reproduction of *OptiNIC: A Resilient and Tail-Optimal RDMA NIC for
//! Distributed ML Workloads* (CS.DC 2025) as a three-layer Rust + JAX +
//! Pallas system. See DESIGN.md for the system inventory and experiment
//! index, EXPERIMENTS.md for paper-vs-measured results, and
//! `docs/VERBS_V2.md` for the application-facing verbs API.
//!
//! Layer map:
//! * **L3 (this crate)** — the deterministic cluster simulator; the
//!   **verbs v2** surface ([`verbs`]: typed `CqEvent`s with first-class
//!   [`verbs::LossMap`]s, `QpHandle`s, doorbell-batched posting, a per-node
//!   SRQ, and the non-allocating completion poll the DES hot loop runs on);
//!   the six RDMA transports (RoCE/IRN/SRNIC/Falcon/UCCL/OptiNIC) behind
//!   one [`transport::Transport`] trait; congestion control ([`cc`]);
//!   collectives with adaptive timeouts ([`collectives`]); loss recovery
//!   that consumes transport loss maps directly ([`recovery`]); the
//!   hardware/fault model ([`hw`]); the training/serving coordinators
//!   ([`coordinator`]); the open-loop multi-tenant serving subsystem
//!   with KV-cache migration and SLO accounting ([`serving`]); and the
//!   adversarial burst/fault scenario catalog ([`scenarios`]).
//! * **L2 (`python/compile/model.py`)** — transformer fwd/bwd/apply/infer
//!   lowered to HLO text at build time.
//! * **L1 (`python/compile/kernels/`)** — Pallas FWHT kernel; executed from
//!   L3 through [`runtime`] (PJRT CPU client, behind the `pjrt` feature —
//!   the default build stubs it so the simulator + tests run offline).

// Crate-wide lint posture: the simulator favors explicit indexed loops and
// constructor-with-config patterns where clippy's defaults disagree;
// keep CI's `-D warnings` actionable rather than noisy.
#![allow(
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::needless_range_loop,
    clippy::type_complexity
)]

pub mod cc;
pub mod collectives;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod net;
pub mod recovery;
pub mod runtime;
pub mod scenarios;
pub mod serving;
pub mod sim;
pub mod transport;
pub mod util;
pub mod verbs;
