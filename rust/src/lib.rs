//! # OptiNIC — a resilient, tail-optimal RDMA transport for distributed ML
//!
//! Full reproduction of *OptiNIC: A Resilient and Tail-Optimal RDMA NIC for
//! Distributed ML Workloads* (CS.DC 2025) as a three-layer Rust + JAX +
//! Pallas system. See DESIGN.md for the system inventory and experiment
//! index, EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * **L3 (this crate)** — the deterministic cluster simulator, the six
//!   RDMA transports (RoCE/IRN/SRNIC/Falcon/UCCL/OptiNIC), congestion
//!   control, collectives with adaptive timeouts, the hardware/fault model,
//!   and the training/serving coordinators.
//! * **L2 (`python/compile/model.py`)** — transformer fwd/bwd/apply/infer
//!   lowered to HLO text at build time.
//! * **L1 (`python/compile/kernels/`)** — Pallas FWHT kernel; executed from
//!   L3 through [`runtime`] (PJRT CPU client).

pub mod cc;
pub mod collectives;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod net;
pub mod recovery;
pub mod runtime;
pub mod sim;
pub mod transport;
pub mod util;
pub mod verbs;
