//! Adaptive timeout estimation (§3.1.2).
//!
//! After each collective, every node records (elapsed time, bytes received)
//! and derives an empirical per-byte cost; it proposes `cost × msg_size` as
//! the next timeout and broadcasts the proposal over the reliable control
//! channel. Before the next invocation of the *same collective on the same
//! group*, each node takes the **median** of all proposals (outlier
//! rejection) and smooths with an EWMA:
//!
//! ```text
//! T_new = α · T_median + (1 − α) · T_old        (α = 0.2)
//! ```
//!
//! With no history, the bootstrap estimate comes from a warmup run:
//!
//! ```text
//! T_init = (1 + γ) · T_warmup + δ               (γ = 0.25, δ = 50 µs)
//! ```
//!
//! Timeouts apply per RDMA operation: phase budgets split the total across
//! a collective's sequential steps (parallel steps share a deadline).

use std::collections::BTreeMap;

use crate::collectives::schedule::CollectiveKind;
use crate::sim::SimTime;

pub const ALPHA: f64 = 0.2;
pub const GAMMA: f64 = 0.25;
pub const DELTA_NS: f64 = 50_000.0; // 50 µs additive slack

/// Identity of a (collective, group, size-class) for timeout bookkeeping.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TimeoutKey {
    pub kind_tag: u8,
    pub group_id: u32,
    /// log2 size bucket so nearby message sizes share an estimate
    pub size_class: u8,
}

impl TimeoutKey {
    pub fn new(kind: CollectiveKind, group_id: u32, msg_bytes: usize) -> TimeoutKey {
        let kind_tag = CollectiveKind::ALL
            .iter()
            .position(|k| *k == kind)
            .unwrap() as u8;
        TimeoutKey {
            kind_tag,
            group_id,
            size_class: (usize::BITS - msg_bytes.max(1).leading_zeros()) as u8,
        }
    }

    /// Pack into a ctrl-message tag.
    pub fn to_tag(self) -> u64 {
        ((self.kind_tag as u64) << 40) | ((self.group_id as u64) << 8) | self.size_class as u64
    }

    pub fn from_tag(tag: u64) -> TimeoutKey {
        TimeoutKey {
            kind_tag: ((tag >> 40) & 0xff) as u8,
            group_id: ((tag >> 8) & 0xffff_ffff) as u32,
            size_class: (tag & 0xff) as u8,
        }
    }
}

#[derive(Clone, Debug, Default)]
struct Entry {
    t_old: Option<f64>,
    proposals: Vec<f64>,
}

/// One node's distributed timeout estimator. All nodes apply identical
/// updates from identical proposal sets, so estimates stay consistent
/// across the group without a coordinator.
#[derive(Clone, Debug, Default)]
pub struct AdaptiveTimeout {
    entries: BTreeMap<TimeoutKey, Entry>,
}

impl AdaptiveTimeout {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current total-collective timeout, if an estimate exists.
    pub fn current(&self, key: TimeoutKey) -> Option<SimTime> {
        self.entries
            .get(&key)
            .and_then(|e| e.t_old)
            .map(|t| t as SimTime)
    }

    /// Bootstrap from a warmup collective's measured duration (§3.1.2):
    /// `T_init = (1+γ)·T_warmup + δ`.
    pub fn bootstrap(&mut self, key: TimeoutKey, warmup_ns: SimTime) -> SimTime {
        let t = (1.0 + GAMMA) * warmup_ns as f64 + DELTA_NS;
        self.entries.entry(key).or_default().t_old = Some(t);
        t as SimTime
    }

    /// Local observation after a collective: elapsed time and bytes
    /// actually received (full + partial). Returns this node's proposal
    /// (per-byte cost × message size) to broadcast to the group.
    pub fn propose(
        &mut self,
        key: TimeoutKey,
        elapsed_ns: SimTime,
        bytes_received: usize,
        msg_bytes: usize,
    ) -> f64 {
        let per_byte = elapsed_ns as f64 / bytes_received.max(1) as f64;
        let proposal = per_byte * msg_bytes as f64 + DELTA_NS;
        self.entries.entry(key).or_default();
        proposal
    }

    /// Record one peer's proposal (including our own).
    pub fn add_proposal(&mut self, key: TimeoutKey, proposal: f64) {
        self.entries.entry(key).or_default().proposals.push(proposal);
    }

    /// Number of proposals currently collected for a key.
    pub fn proposal_count(&self, key: TimeoutKey) -> usize {
        self.entries.get(&key).map(|e| e.proposals.len()).unwrap_or(0)
    }

    /// Fold collected proposals into the canonical estimate:
    /// median across peers, then EWMA against the previous value.
    pub fn finalize_round(&mut self, key: TimeoutKey) -> Option<SimTime> {
        let e = self.entries.get_mut(&key)?;
        if e.proposals.is_empty() {
            return e.t_old.map(|t| t as SimTime);
        }
        let median = crate::util::stats::median_inplace(&mut e.proposals);
        e.proposals.clear();
        let t_new = match e.t_old {
            None => median,
            Some(t_old) => ALPHA * median + (1.0 - ALPHA) * t_old,
        };
        e.t_old = Some(t_new);
        Some(t_new as SimTime)
    }

    /// Per-operation timeout for one sequential step: the total budget is
    /// divided proportionally across the collective's phases (§3.1.2). The
    /// additive slack δ is *not* divided away — every operation keeps at
    /// least δ of headroom, which matters for RTT-dominated small messages
    /// (decode-step collectives are ~KBs, §2.1).
    pub fn per_phase(total: SimTime, phases: usize) -> SimTime {
        (total / phases.max(1) as u64).max(DELTA_NS as SimTime)
    }

    /// Cumulative deadline for the k-th sequential step given the
    /// per-phase slice: step `k` may run until `k + 1` slices from the
    /// start. Rank schedules post every receive up front with these
    /// deadlines; the NIC arms each one as a generation-stamped timer when
    /// the WQE activates and cancels it (lazily, §Perf) the moment the
    /// step completes — early finishers no longer leave a trail of dead
    /// deadline entries churning the scheduler.
    pub fn cumulative_deadline(step_slice: SimTime, step_idx: usize) -> SimTime {
        step_slice.saturating_mul(step_idx as u64 + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> TimeoutKey {
        TimeoutKey::new(CollectiveKind::AllReduceRing, 7, 1 << 20)
    }

    #[test]
    fn tag_roundtrip() {
        let k = key();
        assert_eq!(TimeoutKey::from_tag(k.to_tag()), k);
    }

    #[test]
    fn bootstrap_formula() {
        let mut a = AdaptiveTimeout::new();
        let t = a.bootstrap(key(), 1_000_000);
        assert_eq!(t, (1.25 * 1_000_000.0 + 50_000.0) as u64);
        assert_eq!(a.current(key()), Some(t));
    }

    #[test]
    fn median_rejects_outliers() {
        let mut a = AdaptiveTimeout::new();
        for p in [100.0, 110.0, 105.0, 1e9, 95.0] {
            a.add_proposal(key(), p);
        }
        let t = a.finalize_round(key()).unwrap();
        // first round: no t_old → median directly = 105
        assert_eq!(t, 105);
    }

    #[test]
    fn ewma_smooths_updates() {
        let mut a = AdaptiveTimeout::new();
        a.bootstrap(key(), 1_000_000); // t_old = 1.25e6 + 5e4 = 1.3e6
        a.add_proposal(key(), 2_300_000.0);
        let t = a.finalize_round(key()).unwrap();
        // 0.2*2.3e6 + 0.8*1.3e6 = 1.5e6
        assert_eq!(t, 1_500_000);
    }

    #[test]
    fn proposal_per_byte_cost() {
        let mut a = AdaptiveTimeout::new();
        // 1 ms to receive 1 MiB → next msg 2 MiB → 2 ms + δ
        let p = a.propose(key(), 1_000_000, 1 << 20, 2 << 20);
        assert!((p - (2_000_000.0 + 50_000.0)).abs() < 1.0, "p={p}");
    }

    #[test]
    fn phase_budget_split() {
        assert_eq!(AdaptiveTimeout::per_phase(1_400_000, 14), 100_000);
        // δ floor applies: every operation keeps ≥50 µs of headroom
        assert_eq!(AdaptiveTimeout::per_phase(1_000, 100), 50_000);
    }

    #[test]
    fn cumulative_deadlines_grow_per_step() {
        let slice = AdaptiveTimeout::per_phase(700_000, 7);
        assert_eq!(AdaptiveTimeout::cumulative_deadline(slice, 0), slice);
        assert_eq!(AdaptiveTimeout::cumulative_deadline(slice, 6), 7 * slice);
        // saturates instead of wrapping on absurd budgets
        assert_eq!(
            AdaptiveTimeout::cumulative_deadline(SimTime::MAX / 2, 9),
            SimTime::MAX
        );
    }

    #[test]
    fn distributed_consistency() {
        // two replicas applying identical proposal streams converge to the
        // same estimate
        let mut a = AdaptiveTimeout::new();
        let mut b = AdaptiveTimeout::new();
        for est in [&mut a, &mut b] {
            est.bootstrap(key(), 500_000);
            for p in [600_000.0, 640_000.0, 580_000.0] {
                est.add_proposal(key(), p);
            }
        }
        assert_eq!(a.finalize_round(key()), b.finalize_round(key()));
    }
}
