//! Per-rank collective execution: an [`App`] that walks a [`Step`] schedule
//! over the verbs v2 API, reduces/places received chunks, and reports
//! per-rank completion statistics.
//!
//! Verbs v2 usage:
//! * every receive of the whole schedule is posted up front through ONE
//!   `post_recv_batch` doorbell (the rank no longer rings one doorbell per
//!   step — host posting cost is paid once per iteration);
//! * completions arrive as typed [`CqEvent`]s; `RecvDone` carries the
//!   NIC's [`LossMap`], which this rank hands to
//!   [`crate::recovery::scrub_missing`] so lost spans are *explicitly*
//!   zeroed from the map the NIC reports (not inferred from buffer state)
//!   before the reduction consumes them.
//!
//! Buffer discipline (see DESIGN.md §6):
//! * reductions receive into a *staging* region at the chunk's natural
//!   offset (distinct chunks per step ⇒ no overlap, even when a fast
//!   sender preempts a timed-out message); per-QP recvs (not the SRQ) keep
//!   placement deterministic for the reduction dataflow;
//! * tree reduces receive whole buffers from distinct children on distinct
//!   QPs into per-level staging slabs;
//! * AllToAll places into a separate output region (the input must stay
//!   intact for later sends);
//! * the NIC zeroes each landing zone at message activation, and the loss
//!   map scrub re-asserts it from the completion event (§3.2 "zeroed
//!   during placement" — belt and suspenders, both measured).

use crate::net::CtrlMsg;
use crate::sim::cluster::{App, AppCtx};
use crate::sim::SimTime;
use crate::verbs::{CqEvent, MrId, NodeId, QpHandle, Wqe};

use super::schedule::{CollectiveKind, RecvOp, Step};

/// Where a rank's buffers live (registered once, reused across iterations).
#[derive(Clone, Debug)]
pub struct RankBuffers {
    /// Main data buffer: `elems` f32.
    pub buf: MrId,
    /// Staging for reductions: `elems` f32 (ring) or `elems × levels` (tree).
    pub stage: MrId,
    /// AllToAll output region: `elems` f32.
    pub out: MrId,
}

/// Final statistics from one rank's run.
#[derive(Clone, Debug, Default)]
pub struct RankResult {
    pub finish_time: Option<SimTime>,
    pub start_time: SimTime,
    pub bytes_received: usize,
    pub bytes_expected: usize,
    pub partial_steps: usize,
    /// Bytes reported missing by completion-event loss maps (verbs v2) and
    /// scrubbed before the reduction consumed them.
    pub lost_bytes: usize,
    pub failed: bool,
    /// Timeout proposal derived from this run (if stats exchange is on).
    pub proposal: Option<f64>,
    pub proposals_heard: Vec<f64>,
}

pub struct CollectiveRank {
    pub rank: usize,
    pub n: usize,
    pub kind: CollectiveKind,
    pub elems: usize,
    schedule: Vec<Step>,
    cur: usize,
    bufs: RankBuffers,
    /// QP handle to use toward each peer rank.
    qps: Vec<QpHandle>,
    /// Per-step operation timeout (None ⇒ classic reliable semantics).
    step_timeout: Option<SimTime>,
    stride: u16,
    /// Artificial compute-straggler delay before starting (GPU jitter).
    start_delay: SimTime,
    /// exchange timeout statistics over the ctrl channel after finishing
    exchange_stats: bool,
    // ---- run state ----
    /// per-step receive completion (CQEs can arrive for steps ahead of the
    /// current one when a timeout cascade completes several at once)
    recv_ok: Vec<bool>,
    send_posted: bool,
    send_done: bool,
    /// compute-delay gate: sends may not start before the wake fires
    started: bool,
    result: RankResult,
    done: bool,
}

impl CollectiveRank {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: usize,
        n: usize,
        kind: CollectiveKind,
        elems: usize,
        bufs: RankBuffers,
        qps: Vec<QpHandle>,
        total_timeout: Option<SimTime>,
        stride: u16,
        start_delay: SimTime,
        exchange_stats: bool,
    ) -> CollectiveRank {
        let schedule = kind.schedule(rank, n, elems);
        let phases = kind.phase_count(n);
        let step_timeout = total_timeout
            .map(|t| super::timeout::AdaptiveTimeout::per_phase(t, phases));
        let bytes_expected = schedule
            .iter()
            .filter_map(|s| s.recv.map(|(_, c, _)| c.len * 4))
            .sum();
        let steps = schedule.len();
        CollectiveRank {
            rank,
            n,
            kind,
            elems,
            schedule,
            cur: 0,
            bufs,
            qps,
            step_timeout,
            stride,
            start_delay,
            exchange_stats,
            recv_ok: vec![false; steps],
            send_posted: false,
            send_done: false,
            started: false,
            result: RankResult {
                bytes_expected,
                ..Default::default()
            },
            done: false,
        }
    }

    pub fn result(&self) -> &RankResult {
        &self.result
    }

    fn wr_send(step: usize) -> u64 {
        (step as u64) << 1
    }
    fn wr_recv(step: usize) -> u64 {
        ((step as u64) << 1) | 1
    }

    /// Staging layout: where does step `s`'s reduce-recv land?
    fn stage_offset(&self, step_idx: usize, chunk_start: usize) -> usize {
        match self.kind {
            // tree reduces receive whole buffers: one slab per recv level
            CollectiveKind::AllReduceTree => {
                let level = self
                    .schedule
                    .iter()
                    .take(step_idx)
                    .filter(|s| matches!(s.recv, Some((_, _, RecvOp::Reduce))))
                    .count();
                level * self.elems + chunk_start
            }
            // ring reductions: distinct chunks per step → natural offset
            _ => chunk_start,
        }
    }

    /// Landing target (mr, byte offset, byte len) for step `idx`'s receive.
    fn recv_target(&self, idx: usize) -> Option<(MrId, usize, usize)> {
        let (_, chunk, op) = self.schedule[idx].recv?;
        let (mr, off_elems) = match op {
            RecvOp::Reduce => {
                let off = self.stage_offset(idx, chunk.start);
                (self.bufs.stage, off)
            }
            RecvOp::Place => match self.kind {
                CollectiveKind::AllToAll => (self.bufs.out, chunk.start),
                _ => (self.bufs.buf, chunk.start),
            },
        };
        Some((mr, off_elems * 4, chunk.len * 4))
    }

    /// Post every receive of the schedule up front through ONE
    /// doorbell-batched call, with cumulative deadlines (§3.1.2: the budget
    /// divides across sequential phases, so the k-th step's operation
    /// deadline is (k+1) slices from the start).
    fn post_all_recvs(&mut self, ctx: &mut AppCtx) {
        let mut batch: Vec<(QpHandle, Wqe)> = Vec::with_capacity(self.schedule.len());
        for idx in 0..self.schedule.len() {
            let Some((from, _, _)) = self.schedule[idx].recv else { continue };
            let Some((mr, off_bytes, len_bytes)) = self.recv_target(idx) else { continue };
            // NOTE: landing zones are NOT pre-zeroed here — the buffer may
            // still hold input data earlier steps must send. The NIC zeroes
            // the zone at message activation, and the loss-map scrub on
            // completion re-zeroes any span the map reports missing (§3.2).
            let mut wqe = Wqe::recv(Self::wr_recv(idx), mr, off_bytes, len_bytes);
            if let Some(t) = self.step_timeout {
                // cumulative per-step deadline (§3.1.2); the NIC cancels
                // the timer the moment the step completes (§Perf)
                wqe = wqe.with_timeout(
                    super::timeout::AdaptiveTimeout::cumulative_deadline(t, idx),
                );
            }
            batch.push((self.qps[from], wqe));
        }
        // one posting doorbell for the entire schedule (verbs v2 batching)
        ctx.endpoint().post_recv_batch(batch);
    }

    fn issue_send(&mut self, ctx: &mut AppCtx) {
        let step = self.schedule[self.cur];
        let Some((to, chunk)) = step.send else {
            self.send_done = true;
            return;
        };
        let mut wqe = Wqe::send(
            Self::wr_send(self.cur),
            self.bufs.buf,
            chunk.start * 4,
            chunk.len * 4,
        )
        .with_stride(self.stride);
        if let Some(t) = self.step_timeout {
            wqe = wqe.with_timeout(super::timeout::AdaptiveTimeout::cumulative_deadline(t, 1));
        }
        ctx.endpoint().post_send(self.qps[to], wqe);
    }

    /// Drive the schedule as far as completions allow.
    fn progress(&mut self, ctx: &mut AppCtx) {
        loop {
            if !self.started || self.done || self.result.finish_time.is_some() {
                return;
            }
            if self.cur >= self.schedule.len() {
                self.finish(ctx);
                return;
            }
            let step = self.schedule[self.cur];
            if !self.send_posted {
                self.send_posted = true;
                self.send_done = step.send.is_none();
                if step.send.is_some() {
                    self.issue_send(ctx);
                }
            }
            let recv_ready = step.recv.is_none() || self.recv_ok[self.cur];
            if !(self.send_done && recv_ready) {
                return;
            }
            // step complete: apply its receive operation
            if let Some((_, chunk, RecvOp::Reduce)) = step.recv {
                let off = self.stage_offset(self.cur, chunk.start);
                let incoming = ctx.mem.read_f32(self.bufs.stage, off, chunk.len);
                let mut local =
                    ctx.mem.read_f32(self.bufs.buf, chunk.start, chunk.len);
                for (l, x) in local.iter_mut().zip(incoming.iter()) {
                    *l += x;
                }
                ctx.mem.write_f32(self.bufs.buf, chunk.start, &local);
            }
            self.cur += 1;
            self.send_posted = false;
            self.send_done = false;
        }
    }

    fn finish(&mut self, ctx: &mut AppCtx) {
        if self.result.finish_time.is_some() {
            return;
        }
        self.result.finish_time = Some(ctx.time);
        // AllToAll: copy the self-chunk into the output region
        if self.kind == CollectiveKind::AllToAll {
            let c = super::schedule::chunk_bounds(self.rank, self.n, self.elems);
            let own = ctx.mem.read_f32(self.bufs.buf, c.start, c.len);
            ctx.mem.write_f32(self.bufs.out, c.start, &own);
        }
        if self.exchange_stats {
            // §3.1.2: broadcast (elapsed, bytes) → per-byte proposal
            let elapsed = ctx.time - self.result.start_time;
            let per_byte =
                elapsed as f64 / self.result.bytes_received.max(1) as f64;
            let msg_bytes = self.result.bytes_expected;
            let proposal =
                per_byte * msg_bytes as f64 + super::timeout::DELTA_NS;
            self.result.proposal = Some(proposal);
            self.result.proposals_heard.push(proposal); // own vote
            for peer in 0..self.n {
                if peer != self.rank {
                    ctx.send_ctrl(
                        peer,
                        CtrlMsg {
                            tag: 0x71be0,
                            payload: proposal.to_le_bytes().to_vec(),
                        },
                    );
                }
            }
            // done once all proposals heard (checked in on_ctrl)
            if self.result.proposals_heard.len() == self.n {
                self.done = true;
            }
        } else {
            self.done = true;
        }
    }
}

impl App for CollectiveRank {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        self.result.start_time = ctx.time + self.start_delay;
        // receives are posted immediately (even for delayed ranks — the
        // NIC must be ready before peers send); compute delay gates sends
        self.post_all_recvs(ctx);
        if self.start_delay > 0 {
            ctx.wake_in(self.start_delay, 0);
        } else {
            self.started = true;
            self.progress(ctx);
        }
    }

    fn on_wake(&mut self, ctx: &mut AppCtx, _token: u64) {
        self.started = true;
        if !self.done && self.result.finish_time.is_none() {
            self.progress(ctx);
        }
    }

    fn on_cq_event(&mut self, ctx: &mut AppCtx, ev: CqEvent) {
        if self.done || self.result.finish_time.is_some() {
            return; // late completions after finish are ignorable
        }
        let step = (ev.wr_id() >> 1) as usize;
        match ev {
            CqEvent::QpError { .. } => {
                self.result.failed = true;
                self.result.finish_time = Some(ctx.time);
                self.done = true;
                return;
            }
            CqEvent::RecvDone {
                delivered_bytes,
                expected_bytes,
                loss_map,
                ..
            } => {
                self.result.bytes_received += delivered_bytes;
                if !loss_map.is_complete() {
                    // bounded completion delivered a partial message: zero
                    // exactly the spans the NIC's loss map reports missing,
                    // then reduce — recovery consumes the map directly
                    self.result.partial_steps += 1;
                    self.result.lost_bytes +=
                        expected_bytes.saturating_sub(delivered_bytes);
                    if step < self.schedule.len() {
                        if let Some((mr, base, _)) = self.recv_target(step) {
                            crate::recovery::scrub_missing(ctx.mem, mr, base, &loss_map);
                        }
                    }
                }
                if step < self.recv_ok.len() {
                    self.recv_ok[step] = true;
                }
            }
            CqEvent::TimeoutFired { is_recv: true, expected_bytes, .. } => {
                // receive deadline expired with nothing delivered: the
                // whole landing zone is lost (the NIC zeroed it)
                self.result.partial_steps += 1;
                self.result.lost_bytes += expected_bytes;
                if step < self.recv_ok.len() {
                    self.recv_ok[step] = true;
                }
            }
            CqEvent::SendDone { .. }
            | CqEvent::TimeoutFired { is_recv: false, .. } => {
                if step == self.cur {
                    // sender-side TimeoutFired (CC starvation) still
                    // releases the step: bounded completion means we move
                    // on (§3.1.2)
                    self.send_done = true;
                }
            }
        }
        self.progress(ctx);
    }

    fn on_ctrl(&mut self, _ctx: &mut AppCtx, _from: NodeId, msg: CtrlMsg) {
        if msg.tag == 0x71be0 && msg.payload.len() == 8 {
            let mut b = [0u8; 8];
            b.copy_from_slice(&msg.payload);
            self.result.proposals_heard.push(f64::from_le_bytes(b));
            if self.result.proposals_heard.len() >= self.n
                && self.result.finish_time.is_some()
            {
                self.done = true;
            }
        }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}
