//! Collective communication schedules as pure data.
//!
//! A schedule is, per rank, an ordered list of [`Step`]s; each step may
//! send one chunk and/or receive one chunk, and advances only when both
//! halves complete (normally, partially, or by timeout). Keeping schedules
//! pure makes the algorithms unit-testable without a simulator: the tests
//! below verify, by symbolic execution over chunk ownership sets, that
//! every algorithm delivers exactly the right data to every rank.

/// Element range of a buffer chunk: `[start, start + len)` in f32 elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub start: usize,
    pub len: usize,
}

/// What to do with a received chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvOp {
    /// Accumulate into the main buffer at the chunk offset (reduction).
    Reduce,
    /// Copy into the main buffer at the chunk offset (gather).
    Place,
}

/// One lockstep step of a collective, from one rank's point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Step {
    /// Send `chunk` of the local main buffer to `to` (None = no send).
    pub send: Option<(usize, Chunk)>,
    /// Receive a chunk from `from` and apply `op`.
    pub recv: Option<(usize, Chunk, RecvOp)>,
}

/// Supported collectives (§2.1: AR, AG, RS dominate; AA for MoE/inference).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    AllReduceRing,
    AllReduceTree,
    AllGather,
    ReduceScatter,
    AllToAll,
}

impl CollectiveKind {
    pub const ALL: [CollectiveKind; 5] = [
        CollectiveKind::AllReduceRing,
        CollectiveKind::AllReduceTree,
        CollectiveKind::AllGather,
        CollectiveKind::ReduceScatter,
        CollectiveKind::AllToAll,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::AllReduceRing => "AllReduce(ring)",
            CollectiveKind::AllReduceTree => "AllReduce(tree)",
            CollectiveKind::AllGather => "AllGather",
            CollectiveKind::ReduceScatter => "ReduceScatter",
            CollectiveKind::AllToAll => "AllToAll",
        }
    }

    pub fn parse(s: &str) -> Option<CollectiveKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "allreduce" | "ar" | "allreduce-ring" | "ring" => CollectiveKind::AllReduceRing,
            "allreduce-tree" | "tree" => CollectiveKind::AllReduceTree,
            "allgather" | "ag" => CollectiveKind::AllGather,
            "reducescatter" | "rs" | "reduce-scatter" => CollectiveKind::ReduceScatter,
            "alltoall" | "aa" | "a2a" => CollectiveKind::AllToAll,
            _ => return None,
        })
    }

    /// Build the schedule for `rank` of `n` over `elems` elements.
    pub fn schedule(&self, rank: usize, n: usize, elems: usize) -> Vec<Step> {
        match self {
            CollectiveKind::AllReduceRing => ring_allreduce(rank, n, elems),
            CollectiveKind::AllReduceTree => tree_allreduce(rank, n, elems),
            CollectiveKind::AllGather => ring_allgather(rank, n, elems),
            CollectiveKind::ReduceScatter => ring_reduce_scatter(rank, n, elems),
            CollectiveKind::AllToAll => pairwise_alltoall(rank, n, elems),
        }
    }

    /// Steps that run sequentially (for per-phase timeout budgeting,
    /// §3.1.2: sequential phases get proportional timeout slices).
    pub fn phase_count(&self, n: usize) -> usize {
        match self {
            CollectiveKind::AllReduceRing => 2 * (n - 1),
            CollectiveKind::AllReduceTree => 2 * log2_ceil(n),
            CollectiveKind::AllGather | CollectiveKind::ReduceScatter => n - 1,
            CollectiveKind::AllToAll => n - 1,
        }
    }

    /// Estimated phase-boundary instants, as ns offsets from collective
    /// start, under an ideal bandwidth model: phase `p` ends once the
    /// largest chunk any rank sends in step `p` has crossed a link at
    /// `bytes_per_ns`, plus one `rtt_ns` of propagation slack. This is the
    /// choreography hook the scenario subsystem aims synchronized incast
    /// microbursts at (docs/SCENARIOS.md §Choreography model): a burst
    /// landing on a boundary hits the fabric exactly when every rank turns
    /// its traffic around at once.
    pub fn phase_boundaries(
        &self,
        n: usize,
        elems: usize,
        bytes_per_ns: f64,
        rtt_ns: u64,
    ) -> Vec<u64> {
        let scheds: Vec<Vec<Step>> = (0..n).map(|r| self.schedule(r, n, elems)).collect();
        let phases = self.phase_count(n);
        let mut t = 0u64;
        let mut out = Vec::with_capacity(phases);
        for p in 0..phases {
            // the phase lasts as long as its largest transfer; idle ranks
            // (tree schedules break early) contribute nothing
            let max_bytes = scheds
                .iter()
                .filter_map(|s| s.get(p))
                .filter_map(|s| s.send)
                .map(|(_, c)| c.len * 4)
                .max()
                .unwrap_or(elems * 4 / n.max(1));
            t += (max_bytes as f64 / bytes_per_ns).ceil().max(1.0) as u64 + rtt_ns;
            out.push(t);
        }
        out
    }
}

fn log2_ceil(n: usize) -> usize {
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

/// Bounds of chunk `i` when `elems` is split into `n` nearly-equal chunks.
pub fn chunk_bounds(i: usize, n: usize, elems: usize) -> Chunk {
    let base = elems / n;
    let rem = elems % n;
    let start = i * base + i.min(rem);
    let len = base + usize::from(i < rem);
    Chunk { start, len }
}

/// Ring ReduceScatter: after `n-1` steps, rank r owns the fully-reduced
/// chunk `(r+1) % n`.
pub fn ring_reduce_scatter(rank: usize, n: usize, elems: usize) -> Vec<Step> {
    assert!(n >= 2);
    let right = (rank + 1) % n;
    let left = (rank + n - 1) % n;
    (0..n - 1)
        .map(|s| {
            let send_chunk = (rank + n - s) % n;
            let recv_chunk = (rank + n - s - 1) % n;
            Step {
                send: Some((right, chunk_bounds(send_chunk, n, elems))),
                recv: Some((left, chunk_bounds(recv_chunk, n, elems), RecvOp::Reduce)),
            }
        })
        .collect()
}

/// Ring AllGather assuming rank r starts owning chunk `(r+1) % n` (the
/// ReduceScatter postcondition). For standalone AllGather over per-rank
/// shards use [`ring_allgather`].
pub fn ring_allgather_after_rs(rank: usize, n: usize, elems: usize) -> Vec<Step> {
    assert!(n >= 2);
    let right = (rank + 1) % n;
    let left = (rank + n - 1) % n;
    (0..n - 1)
        .map(|s| {
            let send_chunk = (rank + 1 + n - s) % n;
            let recv_chunk = (rank + n - s) % n;
            Step {
                send: Some((right, chunk_bounds(send_chunk, n, elems))),
                recv: Some((left, chunk_bounds(recv_chunk, n, elems), RecvOp::Place)),
            }
        })
        .collect()
}

/// Standalone ring AllGather: rank r starts owning chunk r.
pub fn ring_allgather(rank: usize, n: usize, elems: usize) -> Vec<Step> {
    assert!(n >= 2);
    let right = (rank + 1) % n;
    let left = (rank + n - 1) % n;
    (0..n - 1)
        .map(|s| {
            let send_chunk = (rank + n - s) % n;
            let recv_chunk = (rank + n - s - 1) % n;
            Step {
                send: Some((right, chunk_bounds(send_chunk, n, elems))),
                recv: Some((left, chunk_bounds(recv_chunk, n, elems), RecvOp::Place)),
            }
        })
        .collect()
}

/// Ring AllReduce = ReduceScatter + AllGather: 2(n-1) steps.
pub fn ring_allreduce(rank: usize, n: usize, elems: usize) -> Vec<Step> {
    let mut steps = ring_reduce_scatter(rank, n, elems);
    steps.extend(ring_allgather_after_rs(rank, n, elems));
    steps
}

/// Binomial-tree AllReduce (reduce to rank 0, then broadcast). Requires n
/// to be a power of two (the cluster sizes the paper evaluates: 4, 8).
/// Whole-buffer transfers at each level.
pub fn tree_allreduce(rank: usize, n: usize, elems: usize) -> Vec<Step> {
    assert!(n.is_power_of_two(), "tree allreduce requires power-of-two ranks");
    let whole = Chunk {
        start: 0,
        len: elems,
    };
    let mut steps = Vec::new();
    // reduce phase
    let mut mask = 1;
    while mask < n {
        if rank & mask != 0 {
            steps.push(Step {
                send: Some((rank ^ mask, whole)),
                recv: None,
            });
            // once sent up, this rank idles until the broadcast phase
            break;
        } else {
            steps.push(Step {
                send: None,
                recv: Some((rank ^ mask, whole, RecvOp::Reduce)),
            });
        }
        mask <<= 1;
    }
    // broadcast phase: mirror of the reduce participation
    let mut bcast = Vec::new();
    let mut mask = 1;
    while mask < n {
        if rank & mask != 0 {
            bcast.push(Step {
                send: None,
                recv: Some((rank ^ mask, whole, RecvOp::Place)),
            });
            break;
        } else {
            bcast.push(Step {
                send: Some((rank ^ mask, whole)),
                recv: None,
            });
        }
        mask <<= 1;
    }
    // broadcast runs top-down: reverse the mirrored steps
    bcast.reverse();
    steps.extend(bcast);
    steps
}

/// Hierarchical (topology-aware) AllReduce for multi-tier fabrics:
/// rack-local binomial reduce to the rack leader, ring AllReduce across
/// the leaders (the only phase that crosses spine/core links), then a
/// rack-local binomial broadcast. With `racks = n / rack` leaders, the
/// cross-fabric byte volume per rack drops from the flat ring's
/// `2·(n-1)/n · elems` per RANK to `2·(racks-1)/racks · elems` per
/// LEADER — the fat-tree scaling lever (docs/SCALE.md §Hierarchical
/// collectives).
///
/// `rack` = ranks per rack (use `hosts_per_leaf`), must be a power of
/// two (binomial phases) and divide `n`. Rank `base + 0` of each rack is
/// its leader.
pub fn hier_allreduce(rank: usize, n: usize, elems: usize, rack: usize) -> Vec<Step> {
    assert!(rack >= 1 && rack.is_power_of_two(), "rack size must be a power of two");
    assert!(n % rack == 0, "ranks ({n}) must divide into racks of {rack}");
    let racks = n / rack;
    let base = (rank / rack) * rack;
    let local = rank - base;
    let whole = Chunk { start: 0, len: elems };
    let mut steps = Vec::new();
    // phase 1: binomial reduce onto the rack leader (stays on edge links)
    let mut mask = 1;
    while mask < rack {
        if local & mask != 0 {
            steps.push(Step {
                send: Some((base + (local ^ mask), whole)),
                recv: None,
            });
            break;
        } else {
            steps.push(Step {
                send: None,
                recv: Some((base + (local ^ mask), whole, RecvOp::Reduce)),
            });
        }
        mask <<= 1;
    }
    // phase 2: leaders ring-AllReduce across racks (chunked over racks,
    // the only traffic that climbs to the spine/core tiers)
    if local == 0 && racks >= 2 {
        let leader = rank / rack;
        for s in ring_allreduce(leader, racks, elems) {
            steps.push(Step {
                send: s.send.map(|(to, c)| (to * rack, c)),
                recv: s.recv.map(|(from, c, op)| (from * rack, c, op)),
            });
        }
    }
    // phase 3: binomial broadcast back down the rack (mirror of phase 1)
    let mut bcast = Vec::new();
    let mut mask = 1;
    while mask < rack {
        if local & mask != 0 {
            bcast.push(Step {
                send: None,
                recv: Some((base + (local ^ mask), whole, RecvOp::Place)),
            });
            break;
        } else {
            bcast.push(Step {
                send: Some((base + (local ^ mask), whole)),
                recv: None,
            });
        }
        mask <<= 1;
    }
    bcast.reverse();
    steps.extend(bcast);
    steps
}

/// Pairwise-exchange AllToAll: step s exchanges with ranks (r±s) mod n.
/// Chunk j of the input buffer is destined for rank j; output chunk i comes
/// from rank i. (The self-chunk stays in place.)
pub fn pairwise_alltoall(rank: usize, n: usize, elems: usize) -> Vec<Step> {
    assert!(n >= 2);
    // uneven splits would mismatch sender/receiver chunk sizes (sender i's
    // chunk r vs receiver r's slot i); AllToAll callers must pad
    assert!(
        elems % n == 0,
        "AllToAll requires elems ({elems}) divisible by ranks ({n}) — pad upstream"
    );
    (1..n)
        .map(|s| {
            let to = (rank + s) % n;
            let from = (rank + n - s) % n;
            Step {
                send: Some((to, chunk_bounds(to, n, elems))),
                recv: Some((from, chunk_bounds(from, n, elems), RecvOp::Place)),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    /// Symbolically execute a schedule: each rank's buffer is, per chunk, a
    /// set of contributor ranks (for reductions) — lets us check that every
    /// algorithm produces exactly the right data without a simulator.
    ///
    /// Execution model matches the DES: sends are asynchronous (queued per
    /// directed pair), receives block, a step completes when both halves
    /// have executed. Ranks need not run in lockstep (tree schedules have
    /// different step counts per rank).
    fn simulate(n: usize, elems: usize, kind: CollectiveKind) -> Vec<Vec<BTreeSet<usize>>> {
        // AllToAll places into a separate output array (the run-time engine
        // uses a distinct output MR for exactly this reason: later sends
        // must read unclobbered input chunks).
        let separate_out = kind == CollectiveKind::AllToAll;
        let scheds: Vec<Vec<Step>> = (0..n).map(|r| kind.schedule(r, n, elems)).collect();
        simulate_scheds(scheds, n, elems, separate_out)
    }

    fn simulate_scheds(
        scheds: Vec<Vec<Step>>,
        n: usize,
        elems: usize,
        separate_out: bool,
    ) -> Vec<Vec<BTreeSet<usize>>> {
        use std::collections::{HashMap, VecDeque};
        // buffers[r][chunk] = set of ranks whose contribution is present.
        let mut bufs: Vec<Vec<BTreeSet<usize>>> = (0..n)
            .map(|r| (0..n).map(|_| BTreeSet::from([r])).collect())
            .collect();
        let mut outs: Vec<Vec<BTreeSet<usize>>> = bufs.clone();
        let mut cursor = vec![0usize; n];
        let mut sent = vec![false; n]; // current step's send already queued?
        let mut queues: HashMap<(usize, usize), VecDeque<Vec<BTreeSet<usize>>>> =
            HashMap::new();
        loop {
            let mut progressed = false;
            for r in 0..n {
                let Some(step) = scheds[r].get(cursor[r]) else { continue };
                if !sent[r] {
                    if let Some((to, chunk)) = step.send {
                        let idxs = chunks_covered(chunk, n, elems);
                        let payload: Vec<_> =
                            idxs.iter().map(|&i| bufs[r][i].clone()).collect();
                        queues.entry((r, to)).or_default().push_back(payload);
                    }
                    sent[r] = true;
                    progressed = true;
                }
                if let Some((from, chunk, op)) = step.recv {
                    let Some(payload) =
                        queues.entry((from, r)).or_default().pop_front()
                    else {
                        continue; // blocked on recv
                    };
                    let idxs = chunks_covered(chunk, n, elems);
                    assert_eq!(idxs.len(), payload.len(), "payload arity");
                    for (k, &i) in idxs.iter().enumerate() {
                        match op {
                            RecvOp::Reduce => {
                                let add = payload[k].clone();
                                bufs[r][i].extend(add);
                            }
                            RecvOp::Place if separate_out => {
                                outs[r][i] = payload[k].clone();
                            }
                            RecvOp::Place => {
                                bufs[r][i] = payload[k].clone();
                            }
                        }
                    }
                }
                cursor[r] += 1;
                sent[r] = false;
                progressed = true;
            }
            let done = (0..n).all(|r| cursor[r] >= scheds[r].len());
            if done {
                break;
            }
            assert!(progressed, "schedule deadlock: cursors {cursor:?}");
        }
        for q in queues.values() {
            assert!(q.is_empty(), "undelivered messages remain");
        }
        if separate_out {
            outs
        } else {
            bufs
        }
    }

    fn chunks_covered(c: Chunk, n: usize, elems: usize) -> Vec<usize> {
        (0..n)
            .filter(|&i| {
                let b = chunk_bounds(i, n, elems);
                b.len > 0 && b.start >= c.start && b.start + b.len <= c.start + c.len
            })
            .collect()
    }

    fn all_ranks(n: usize) -> BTreeSet<usize> {
        (0..n).collect()
    }

    #[test]
    fn chunk_bounds_partition() {
        for elems in [16, 17, 100, 7] {
            for n in [2, 3, 4, 8] {
                let mut covered = 0;
                for i in 0..n {
                    let c = chunk_bounds(i, n, elems);
                    assert_eq!(c.start, covered);
                    covered += c.len;
                }
                assert_eq!(covered, elems);
            }
        }
    }

    #[test]
    fn ring_allreduce_correct() {
        for n in [2, 3, 4, 8] {
            let bufs = simulate(n, n * 4, CollectiveKind::AllReduceRing);
            for r in 0..n {
                for c in 0..n {
                    assert_eq!(bufs[r][c], all_ranks(n), "rank {r} chunk {c} (n={n})");
                }
            }
        }
    }

    #[test]
    fn tree_allreduce_correct() {
        for n in [2, 4, 8, 16] {
            let bufs = simulate(n, n * 2, CollectiveKind::AllReduceTree);
            for r in 0..n {
                for c in 0..n {
                    assert_eq!(bufs[r][c], all_ranks(n), "rank {r} chunk {c} (n={n})");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_correct() {
        for n in [2, 4, 8] {
            let bufs = simulate(n, n * 4, CollectiveKind::ReduceScatter);
            for r in 0..n {
                let owned = (r + 1) % n;
                assert_eq!(bufs[r][owned], all_ranks(n), "rank {r} owns chunk {owned}");
            }
        }
    }

    #[test]
    fn allgather_correct() {
        for n in [2, 3, 4, 8] {
            let bufs = simulate(n, n * 4, CollectiveKind::AllGather);
            for r in 0..n {
                for c in 0..n {
                    assert_eq!(
                        bufs[r][c],
                        BTreeSet::from([c]),
                        "rank {r} chunk {c} should hold rank {c}'s shard"
                    );
                }
            }
        }
    }

    #[test]
    fn alltoall_correct() {
        for n in [2, 4, 8] {
            let bufs = simulate(n, n * 4, CollectiveKind::AllToAll);
            for r in 0..n {
                for c in 0..n {
                    if c == r {
                        // self-chunk stays local
                        assert_eq!(bufs[r][c], BTreeSet::from([r]));
                    } else {
                        assert_eq!(
                            bufs[r][c],
                            BTreeSet::from([c]),
                            "rank {r} output chunk {c} (n={n})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn phase_counts() {
        assert_eq!(CollectiveKind::AllReduceRing.phase_count(8), 14);
        assert_eq!(CollectiveKind::AllReduceTree.phase_count(8), 6);
        assert_eq!(CollectiveKind::AllGather.phase_count(8), 7);
        assert_eq!(CollectiveKind::AllToAll.phase_count(8), 7);
    }

    /// Boundary estimates: one per phase, strictly increasing, and the
    /// ring's total matches phases × (chunk time + RTT) exactly.
    #[test]
    fn phase_boundaries_cover_every_phase_monotonically() {
        for kind in CollectiveKind::ALL {
            let n = 8;
            let elems = 8 * 1024;
            let b = kind.phase_boundaries(n, elems, 3.125, 5_000);
            assert_eq!(b.len(), kind.phase_count(n), "{}", kind.name());
            for w in b.windows(2) {
                assert!(w[0] < w[1], "{}: boundaries must increase", kind.name());
            }
            assert!(b[0] > 0);
        }
        // ring: every phase moves one elems/n chunk
        let b = CollectiveKind::AllReduceRing.phase_boundaries(4, 4096, 4.0, 1_000);
        let per_phase = (4096.0 * 4.0 / 4.0 / 4.0).ceil() as u64 + 1_000;
        assert_eq!(b[0], per_phase);
        assert_eq!(*b.last().unwrap(), 6 * per_phase);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn tree_rejects_non_power_of_two() {
        tree_allreduce(0, 6, 12);
    }

    /// Every rank ends with every contribution in every chunk — including
    /// the degenerate single-rack case (pure binomial tree) and a
    /// non-power-of-two rack COUNT (the leader ring handles any count).
    #[test]
    fn hier_allreduce_correct() {
        for (n, rack) in [(8, 2), (8, 4), (16, 4), (12, 4), (4, 4), (8, 1)] {
            let elems = n * 4;
            let scheds: Vec<Vec<Step>> =
                (0..n).map(|r| hier_allreduce(r, n, elems, rack)).collect();
            let bufs = simulate_scheds(scheds, n, elems, false);
            for r in 0..n {
                for c in 0..n {
                    assert_eq!(
                        bufs[r][c],
                        all_ranks(n),
                        "rank {r} chunk {c} (n={n}, rack={rack})"
                    );
                }
            }
        }
    }

    /// The scaling lever: a leader's longest schedule is log2(rack) local
    /// steps each way plus the cross-fabric ring over racks — far shorter
    /// than the flat ring's 2(n-1) steps, and non-leaders never touch the
    /// spine/core tiers at all.
    #[test]
    fn hier_allreduce_shrinks_cross_fabric_work() {
        let (n, rack) = (16, 4);
        let leader = hier_allreduce(0, n, 64, rack);
        assert_eq!(leader.len(), 2 + 2 * (n / rack - 1) + 2); // 10 steps
        assert!(leader.len() < ring_allreduce(0, n, 64).len()); // 30 steps
        // non-leaders: reduce up + broadcast down only, all edge-local
        let member = hier_allreduce(3, n, 64, rack);
        assert!(member.len() <= 2 * rack.trailing_zeros() as usize);
        for s in &member {
            for peer in s.send.map(|(p, _)| p).into_iter().chain(s.recv.map(|(p, _, _)| p)) {
                assert_eq!(peer / rack, 3 / rack, "member traffic must stay in-rack");
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn hier_rejects_non_power_of_two_rack() {
        hier_allreduce(0, 12, 48, 3);
    }

    #[test]
    #[should_panic(expected = "racks of")]
    fn hier_rejects_undivisible_ranks() {
        hier_allreduce(0, 10, 40, 4);
    }
}
