//! Collective communication engine: schedules, per-rank execution, adaptive
//! timeouts, and the run driver.
//!
//! The driver owns a reusable [`Workspace`] (buffers + full-mesh QPs) so
//! repeated iterations on one cluster don't leak memory regions, and a
//! per-group [`AdaptiveTimeout`] estimator carried across invocations
//! exactly as §3.1.2 prescribes (warmup bootstrap → per-run proposals →
//! median + EWMA).

pub mod rank;
pub mod schedule;
pub mod timeout;

pub use rank::{CollectiveRank, RankBuffers, RankResult};
pub use schedule::{chunk_bounds, hier_allreduce, CollectiveKind, Step};
pub use timeout::{AdaptiveTimeout, TimeoutKey};

use crate::sim::cluster::Cluster;
use crate::sim::SimTime;
use crate::verbs::{QpHandle, QpType};

/// Parameters of one collective invocation.
#[derive(Clone, Debug)]
pub struct CollectiveSpec {
    pub kind: CollectiveKind,
    /// f32 elements per rank buffer.
    pub elems: usize,
    /// Bounded-completion timeouts on (OptiNIC) or off (reliable designs).
    pub use_timeouts: bool,
    /// Override the adaptive estimate with a fixed total budget.
    pub timeout_override: Option<SimTime>,
    /// Stride parameter placed on send WQEs (§3.2b); 1 = contiguous.
    pub stride: u16,
    /// Per-rank start delays (GPU compute jitter / stragglers).
    pub start_delays: Vec<SimTime>,
    /// Exchange timeout statistics over the ctrl channel after completion.
    pub exchange_stats: bool,
}

impl CollectiveSpec {
    pub fn new(kind: CollectiveKind, elems: usize) -> CollectiveSpec {
        CollectiveSpec {
            kind,
            elems,
            use_timeouts: true,
            timeout_override: None,
            stride: 1,
            start_delays: vec![],
            exchange_stats: false,
        }
    }

    pub fn reliable(mut self) -> Self {
        self.use_timeouts = false;
        self
    }

    pub fn msg_bytes(&self) -> usize {
        self.elems * 4
    }
}

/// Result of one collective run.
#[derive(Clone, Debug, Default)]
pub struct CollectiveResult {
    /// Collective completion time: last rank's finish − run start.
    pub cct_ns: SimTime,
    pub per_rank: Vec<RankResult>,
    pub completed: bool,
    /// Aggregate data-loss fraction observed at receivers.
    pub loss_fraction: f64,
    /// Timeout used for this run (if bounded).
    pub timeout_used: Option<SimTime>,
}

impl CollectiveResult {
    pub fn bytes_received(&self) -> usize {
        self.per_rank.iter().map(|r| r.bytes_received).sum()
    }
    pub fn bytes_expected(&self) -> usize {
        self.per_rank.iter().map(|r| r.bytes_expected).sum()
    }
    /// Steps that completed via bounded completion (loss-map holes or
    /// receive timeouts), summed across ranks.
    pub fn partial_steps(&self) -> usize {
        self.per_rank.iter().map(|r| r.partial_steps).sum()
    }
    /// Bytes the completion-event loss maps reported missing, summed
    /// across ranks (verbs v2 loss accounting).
    pub fn lost_bytes(&self) -> usize {
        self.per_rank.iter().map(|r| r.lost_bytes).sum()
    }
}

/// Reusable per-cluster buffers and full-mesh connections.
pub struct Workspace {
    pub n: usize,
    pub elems: usize,
    pub bufs: Vec<RankBuffers>,
    /// qp[from][to] — the handle `from` uses to reach `to` (the diagonal
    /// holds `QpHandle::null()` placeholders).
    pub qp: Vec<Vec<QpHandle>>,
}

impl Workspace {
    /// Register buffers and connect a full mesh. `tree_levels` > 0 sizes
    /// the staging slabs for tree reduces.
    pub fn new(cluster: &mut Cluster, elems: usize, tree_levels: usize) -> Workspace {
        let n = cluster.nodes();
        let stage_elems = elems * tree_levels.max(1);
        let bufs: Vec<RankBuffers> = (0..n)
            .map(|node| RankBuffers {
                buf: cluster.mem.register(node, elems * 4),
                stage: cluster.mem.register(node, stage_elems * 4),
                out: cluster.mem.register(node, elems * 4),
            })
            .collect();
        let mut qp = vec![vec![QpHandle::null(); n]; n];
        for a in 0..n {
            for b in a + 1..n {
                let (qa, qb) = cluster.connect(a, b, QpType::Xp);
                qp[a][b] = qa;
                qp[b][a] = qb;
            }
        }
        Workspace { n, elems, bufs, qp }
    }

    /// Load per-rank input data into the main buffers.
    pub fn load_inputs(&self, cluster: &mut Cluster, inputs: &[Vec<f32>]) {
        let slices: Vec<&[f32]> = inputs.iter().map(Vec::as_slice).collect();
        self.load_input_slices(cluster, &slices);
    }

    /// Borrowed-slice variant of [`Workspace::load_inputs`]: the sweep
    /// benches hoist one grid-wide input allocation and hand every cell
    /// read-only slices of it (see `util::bench::InputSet`).
    pub fn load_input_slices(&self, cluster: &mut Cluster, inputs: &[&[f32]]) {
        assert_eq!(inputs.len(), self.n);
        for (node, data) in inputs.iter().enumerate() {
            assert_eq!(data.len(), self.elems);
            cluster.mem.write_f32(self.bufs[node].buf, 0, data);
        }
    }

    /// Read back rank `r`'s result buffer (main buffer, or the AllToAll
    /// output region).
    pub fn read_output(&self, cluster: &Cluster, r: usize, kind: CollectiveKind) -> Vec<f32> {
        let mr = match kind {
            CollectiveKind::AllToAll => self.bufs[r].out,
            _ => self.bufs[r].buf,
        };
        cluster.mem.read_f32(mr, 0, self.elems)
    }
}

/// Collective driver: carries the adaptive-timeout estimator across runs.
pub struct Driver {
    pub estimator: AdaptiveTimeout,
    pub group_id: u32,
    runs: u64,
}

impl Driver {
    pub fn new(group_id: u32) -> Driver {
        Driver {
            estimator: AdaptiveTimeout::new(),
            group_id,
            runs: 0,
        }
    }

    /// Bandwidth-ideal completion time (pacing + RTT, no contention).
    fn ideal_ns(cluster: &Cluster, spec: &CollectiveSpec) -> SimTime {
        let bw = cluster.cfg.fabric.bytes_per_ns(); // bytes/ns
        let n = cluster.nodes() as f64;
        let phases = spec.kind.phase_count(cluster.nodes()) as f64;
        let per_phase_bytes = spec.msg_bytes() as f64 / n;
        (phases * (per_phase_bytes / bw + cluster.cfg.fabric.base_rtt_ns() as f64))
            as SimTime
    }

    /// Execute one collective on the cluster. Inputs must already be
    /// loaded via [`Workspace::load_inputs`].
    pub fn run(
        &mut self,
        cluster: &mut Cluster,
        ws: &Workspace,
        spec: &CollectiveSpec,
    ) -> CollectiveResult {
        let n = ws.n;
        self.runs += 1;
        let key = TimeoutKey::new(spec.kind, self.group_id, spec.msg_bytes());
        // First invocation with no history acts as the §3.1.2 warmup: it
        // runs under a deliberately generous bound (50× bandwidth-ideal, so
        // effectively full delivery) and its *measured* duration seeds
        // `T_init = (1+γ)·T_warmup + δ`.
        let mut warmup = false;
        let timeout = if spec.use_timeouts {
            Some(match spec.timeout_override {
                Some(o) => o,
                None => match self.estimator.current(key) {
                    Some(t) => t,
                    None => {
                        warmup = true;
                        50 * Self::ideal_ns(cluster, spec)
                    }
                },
            })
        } else {
            None
        };
        let bytes_before = cluster.metrics.data_bytes_sent;
        let delivered_before = cluster.metrics.data_bytes_delivered;

        let start = cluster.time;
        for r in 0..n {
            // spec-level jitter plus cluster-level straggler injection
            // (scenario choreography — see ClusterCfg::compute_delays)
            let delay = spec.start_delays.get(r).copied().unwrap_or(0)
                + cluster.cfg.compute_delays.get(r).copied().unwrap_or(0);
            let app = CollectiveRank::new(
                r,
                n,
                spec.kind,
                spec.elems,
                ws.bufs[r].clone(),
                ws.qp[r].clone(),
                timeout,
                spec.stride,
                delay,
                spec.exchange_stats,
            );
            cluster.set_app(r, Box::new(app));
        }
        cluster.start_apps();
        let completed = cluster.run();

        // extract per-rank results
        let mut per_rank = Vec::with_capacity(n);
        for r in 0..n {
            let mut app = cluster.take_app(r).expect("app");
            let rank = app
                .as_any()
                .downcast_mut::<CollectiveRank>()
                .expect("collective rank app");
            per_rank.push(rank.result().clone());
        }
        let cct = per_rank
            .iter()
            .filter_map(|r| r.finish_time)
            .max()
            .map(|t| t - start)
            .unwrap_or(0);

        // warmup bootstrap: seed the estimator from the measured duration
        if warmup && spec.timeout_override.is_none() {
            self.estimator.bootstrap(key, cct.max(1));
        }
        // adaptive-timeout update from the proposals exchanged in-run
        if spec.use_timeouts && spec.exchange_stats {
            if let Some(props) = per_rank
                .iter()
                .find(|r| r.proposals_heard.len() == n)
                .map(|r| r.proposals_heard.clone())
            {
                for p in props {
                    self.estimator.add_proposal(key, p);
                }
                self.estimator.finalize_round(key);
            }
        }

        let sent = cluster.metrics.data_bytes_sent - bytes_before;
        let delivered = cluster.metrics.data_bytes_delivered - delivered_before;
        let loss = if sent == 0 {
            0.0
        } else {
            1.0 - delivered as f64 / sent as f64
        };
        CollectiveResult {
            cct_ns: cct,
            per_rank,
            completed,
            loss_fraction: loss,
            timeout_used: timeout,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FabricCfg;
    use crate::sim::cluster::ClusterCfg;
    use crate::transport::TransportKind;

    fn run_once(
        transport: TransportKind,
        kind: CollectiveKind,
        n: usize,
        elems: usize,
        corrupt: f64,
    ) -> (CollectiveResult, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut fab = FabricCfg::cloudlab(n);
        fab.corrupt_prob = corrupt;
        let mut cluster = Cluster::new(ClusterCfg::new(fab, transport).with_seed(11));
        let levels = if kind == CollectiveKind::AllReduceTree {
            n.ilog2() as usize + 1
        } else {
            1
        };
        let ws = Workspace::new(&mut cluster, elems, levels);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|r| (0..elems).map(|i| (r * elems + i) as f32 * 0.001).collect())
            .collect();
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(kind, elems);
        if transport != TransportKind::Optinic && transport != TransportKind::OptinicHw {
            spec = spec.reliable();
        }
        let mut driver = Driver::new(1);
        let result = driver.run(&mut cluster, &ws, &spec);
        let outputs: Vec<Vec<f32>> = (0..n)
            .map(|r| ws.read_output(&cluster, r, kind))
            .collect();
        (result, inputs, outputs)
    }

    fn expected_allreduce(inputs: &[Vec<f32>]) -> Vec<f32> {
        let n = inputs.len();
        let e = inputs[0].len();
        (0..e)
            .map(|i| (0..n).map(|r| inputs[r][i]).sum())
            .collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + y.abs()),
                "elem {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn optinic_allreduce_lossless_exact() {
        let (res, inputs, outputs) = run_once(
            TransportKind::Optinic,
            CollectiveKind::AllReduceRing,
            4,
            1024,
            0.0,
        );
        assert!(res.completed, "did not complete");
        assert!(res.loss_fraction < 1e-9);
        let want = expected_allreduce(&inputs);
        for out in &outputs {
            assert_close(out, &want, 1e-5);
        }
    }

    #[test]
    fn roce_allreduce_lossless_exact() {
        let (res, inputs, outputs) = run_once(
            TransportKind::Roce,
            CollectiveKind::AllReduceRing,
            4,
            1024,
            0.0,
        );
        assert!(res.completed);
        let want = expected_allreduce(&inputs);
        for out in &outputs {
            assert_close(out, &want, 1e-5);
        }
    }

    #[test]
    fn roce_recovers_under_loss() {
        // reliable transport must still produce EXACT results under loss
        let (res, inputs, outputs) = run_once(
            TransportKind::Roce,
            CollectiveKind::AllReduceRing,
            4,
            4096,
            2e-3,
        );
        assert!(res.completed);
        let want = expected_allreduce(&inputs);
        for out in &outputs {
            assert_close(out, &want, 1e-5);
        }
    }

    #[test]
    fn optinic_bounded_under_loss() {
        // best-effort transport completes despite loss; result approximate
        let (res, inputs, outputs) = run_once(
            TransportKind::Optinic,
            CollectiveKind::AllReduceRing,
            4,
            16384,
            5e-3,
        );
        assert!(res.completed, "bounded completion must not hang");
        let want = expected_allreduce(&inputs);
        // most elements should match; a small fraction zeroed
        let mut bad = 0usize;
        for out in &outputs {
            for (x, y) in out.iter().zip(want.iter()) {
                if (x - y).abs() > 1e-3 * (1.0 + y.abs()) {
                    bad += 1;
                }
            }
        }
        let frac = bad as f64 / (outputs.len() * want.len()) as f64;
        assert!(frac < 0.2, "too much corruption: {frac}");
    }

    #[test]
    fn all_collectives_all_transports_smoke() {
        for kind in [
            CollectiveKind::AllReduceRing,
            CollectiveKind::AllReduceTree,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllToAll,
        ] {
            for transport in [TransportKind::Optinic, TransportKind::Irn] {
                let (res, _, _) = run_once(transport, kind, 4, 512, 0.0);
                assert!(
                    res.completed,
                    "{} over {:?} did not complete",
                    kind.name(),
                    transport
                );
            }
        }
    }

    #[test]
    fn allgather_places_every_shard() {
        let (res, inputs, outputs) =
            run_once(TransportKind::Optinic, CollectiveKind::AllGather, 4, 1024, 0.0);
        assert!(res.completed);
        // output chunk c on every rank == rank c's input chunk c
        for r in 0..4 {
            for c in 0..4 {
                let b = chunk_bounds(c, 4, 1024);
                let got = &outputs[r][b.start..b.start + b.len];
                let want = &inputs[c][b.start..b.start + b.len];
                assert_close(got, want, 1e-6);
            }
        }
    }

    #[test]
    fn alltoall_transposes_chunks() {
        let (res, inputs, outputs) =
            run_once(TransportKind::Optinic, CollectiveKind::AllToAll, 4, 1024, 0.0);
        assert!(res.completed);
        for r in 0..4 {
            for c in 0..4 {
                let b = chunk_bounds(c, 4, 1024);
                // output[r] chunk c == input[c] chunk r
                let want_b = chunk_bounds(r, 4, 1024);
                let got = &outputs[r][b.start..b.start + b.len];
                let want = &inputs[c][want_b.start..want_b.start + want_b.len];
                assert_close(got, want, 1e-6);
            }
        }
    }

    #[test]
    fn adaptive_timeout_converges_over_iterations() {
        let n = 4;
        let mut cluster = Cluster::new(
            ClusterCfg::new(FabricCfg::cloudlab(n), TransportKind::Optinic).with_seed(3),
        );
        let ws = Workspace::new(&mut cluster, 4096, 1);
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; 4096]).collect();
        let mut driver = Driver::new(9);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, 4096);
        spec.exchange_stats = true;
        let mut timeouts = vec![];
        for _ in 0..6 {
            ws.load_inputs(&mut cluster, &inputs);
            let res = driver.run(&mut cluster, &ws, &spec);
            assert!(res.completed);
            timeouts.push(res.timeout_used.unwrap());
        }
        // estimator adapts away from the bootstrap value
        assert_ne!(timeouts[0], timeouts[5]);
        // and the final estimate is within a sane multiple of measured CCT
        ws.load_inputs(&mut cluster, &inputs);
        let last_res = driver.run(&mut cluster, &ws, &spec);
        let t = last_res.timeout_used.unwrap() as f64;
        let cct = last_res.cct_ns.max(1) as f64;
        assert!(t / cct < 20.0, "timeout {t} vs cct {cct}");
    }

    /// The paper's core behavioral claim in miniature: a compute straggler
    /// stalls a *reliable* collective by its full delay, while OptiNIC's
    /// bounded completion caps the damage at the timeout (§1, §3.1.2).
    #[test]
    fn straggler_bounded_by_timeout_not_by_straggler() {
        let n = 4;
        let delay = 8_000_000u64; // 8 ms straggler
        let mk = |transport: TransportKind, delay: u64| {
            let mut cluster = Cluster::new(
                ClusterCfg::new(FabricCfg::cloudlab(n), transport).with_seed(5),
            );
            let ws = Workspace::new(&mut cluster, 2048, 1);
            let inputs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; 2048]).collect();
            ws.load_inputs(&mut cluster, &inputs);
            let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, 2048);
            if transport != TransportKind::Optinic {
                spec = spec.reliable();
            }
            spec.start_delays = vec![0, 0, delay, 0];
            let mut d = Driver::new(2);
            d.run(&mut cluster, &ws, &spec)
        };
        // the straggler itself is gated by its own compute either way; the
        // claim is about everyone ELSE: reliable ranks stall on it, OptiNIC
        // ranks proceed within the bound
        let others_max = |res: &CollectiveResult| {
            res.per_rank
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != 2)
                .filter_map(|(_, r)| r.finish_time)
                .max()
                .unwrap()
        };
        // reliable transport: peers absorb the whole straggler delay
        let irn = mk(TransportKind::Irn, delay);
        assert!(irn.completed);
        assert!(
            others_max(&irn) > delay,
            "reliable peers finished at {} — should stall past {delay}",
            others_max(&irn)
        );
        // OptiNIC: bounded completion fires first → peers beat the straggler,
        // at the cost of partial data
        let opt = mk(TransportKind::Optinic, delay);
        assert!(opt.completed);
        assert!(
            others_max(&opt) < delay,
            "bounded peers finished at {} — should beat {delay}",
            others_max(&opt)
        );
        let partials: usize = opt.per_rank.iter().map(|r| r.partial_steps).sum();
        assert!(partials > 0, "timeouts should have produced partial steps");
    }
}
