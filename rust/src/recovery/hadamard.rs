//! Native fast Walsh–Hadamard transform (orthonormal), the simulation
//! hot path. Bit-for-bit the same math as the L1 Pallas kernel
//! (`python/compile/kernels/hadamard.py`) and validated against the AOT'd
//! PJRT artifact in `rust/tests/pjrt_integration.rs`.
//!
//! The in-place butterfly runs in O(p log p). The §Perf pass restructures
//! it into a cache-blocked kernel: every stage whose butterfly span fits
//! an L1-resident tile ([`FWHT_TILE`]) runs tile-at-a-time (one memory
//! pass for log2(FWHT_TILE) stages instead of one pass per stage), and
//! all wide stages walk the disjoint butterfly halves in exact
//! [`FWHT_LANES`]-element chunks — fixed-size array views with no bounds
//! checks in the hot loop, which LLVM turns into plain SIMD adds/subs.
//! Butterflies within a stage are independent, and the ×1.0 writeback on
//! non-final stages is IEEE-exact, so the blocked kernel is BIT-IDENTICAL
//! to [`fwht_scalar_reference`] — property-pinned in the tests below and
//! A/B-timed in `benches/perf_hotpath.rs`.

/// L1 tile: 4096 f32 = 16 KiB, half a typical 32 KiB L1d so the tile and
/// its write stream coexist.
pub const FWHT_TILE: usize = 4096;

/// Inner-loop chunk width: 8 f32 = one AVX2 register (two NEON).
pub const FWHT_LANES: usize = 8;

/// In-place orthonormal FWHT of one power-of-two-length block
/// (cache-blocked + lane-chunked; see module docs).
pub fn fwht_inplace(x: &mut [f32]) {
    let p = x.len();
    assert!(p.is_power_of_two(), "block length {p} must be a power of two");
    if p == 1 {
        return; // H_1 = [1]
    }
    let scale = 1.0 / (p as f32).sqrt();
    if p <= FWHT_TILE {
        fwht_tile_stages(x, scale);
        return;
    }
    // Stages with step ≤ FWHT_TILE touch only one tile each: run ALL of
    // them per tile while it is hot instead of re-streaming the whole
    // buffer per stage. ×1.0 on every tile stage keeps the values
    // bit-identical to the monolithic stage order.
    for tile in x.chunks_exact_mut(FWHT_TILE) {
        fwht_tile_stages(tile, 1.0);
    }
    // Cross-tile stages h = FWHT_TILE .. p/2: half-zips in exact lanes.
    let mut h = FWHT_TILE;
    while h < p {
        let step = h * 2;
        let s = if step == p { scale } else { 1.0 };
        for blk in x.chunks_exact_mut(step) {
            let (lo, hi) = blk.split_at_mut(h);
            butterfly_lanes(lo, hi, s);
        }
        h = step;
    }
}

/// All butterfly stages internal to one tile (step = 2 .. tile length),
/// with `last_scale` fused into the final stage's writeback — `1/√p`
/// when the tile IS the whole transform, `1.0` (exact) otherwise.
fn fwht_tile_stages(tile: &mut [f32], last_scale: f32) {
    let n = tile.len();
    debug_assert!(n >= 2 && n.is_power_of_two());
    // stage h = 1: adjacent pairs (sequential access, pairs vectorize)
    {
        let s = if n == 2 { last_scale } else { 1.0 };
        for pair in tile.chunks_exact_mut(2) {
            let a = pair[0];
            let b = pair[1];
            pair[0] = (a + b) * s;
            pair[1] = (a - b) * s;
        }
    }
    let mut h = 2;
    while h < n {
        let step = h * 2;
        let s = if step == n { last_scale } else { 1.0 };
        for blk in tile.chunks_exact_mut(step) {
            let (lo, hi) = blk.split_at_mut(h);
            if h < FWHT_LANES {
                // narrow stages: plain zip (still unit-stride)
                for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                    let s0 = *a;
                    let s1 = *b;
                    *a = (s0 + s1) * s;
                    *b = (s0 - s1) * s;
                }
            } else {
                butterfly_lanes(lo, hi, s);
            }
        }
        h = step;
    }
}

/// One stage's butterflies over disjoint halves `lo`/`hi` (equal
/// power-of-two lengths ≥ [`FWHT_LANES`]), chunked into fixed-size
/// array views so the inner loop carries no bounds checks.
#[inline]
fn butterfly_lanes(lo: &mut [f32], hi: &mut [f32], s: f32) {
    debug_assert_eq!(lo.len(), hi.len());
    debug_assert_eq!(lo.len() % FWHT_LANES, 0);
    for (la, lb) in lo
        .chunks_exact_mut(FWHT_LANES)
        .zip(hi.chunks_exact_mut(FWHT_LANES))
    {
        let la: &mut [f32; FWHT_LANES] = la.try_into().unwrap();
        let lb: &mut [f32; FWHT_LANES] = lb.try_into().unwrap();
        for i in 0..FWHT_LANES {
            let a = la[i];
            let b = lb[i];
            la[i] = (a + b) * s;
            lb[i] = (a - b) * s;
        }
    }
}

/// Textbook scalar butterfly: the oracle the blocked kernel is
/// property-tested BIT-exact against, and the serial baseline for the
/// `perf_hotpath` A/B. Same stage order and the same ×1.0/×scale
/// writeback placement — only the loop structure differs.
pub fn fwht_scalar_reference(x: &mut [f32]) {
    let p = x.len();
    assert!(p.is_power_of_two(), "block length {p} must be a power of two");
    if p == 1 {
        return;
    }
    let scale = 1.0 / (p as f32).sqrt();
    let mut h = 1;
    while h < p {
        let step = h * 2;
        let s = if step == p { scale } else { 1.0 };
        for blk in x.chunks_exact_mut(step) {
            for i in 0..h {
                let a = blk[i];
                let b = blk[i + h];
                blk[i] = (a + b) * s;
                blk[i + h] = (a - b) * s;
            }
        }
        h = step;
    }
}

/// Block-wise FWHT over a flat buffer whose length is a multiple of `p`
/// (each block goes through the blocked kernel independently).
pub fn fwht_blocks(x: &mut [f32], p: usize) {
    assert!(x.len() % p == 0, "length {} not a multiple of {p}", x.len());
    for block in x.chunks_exact_mut(p) {
        fwht_inplace(block);
    }
}

/// Reference dense Hadamard matrix (for tests): H[i][j] = ±1/sqrt(p).
#[cfg(test)]
pub fn dense_hadamard(p: usize) -> Vec<Vec<f32>> {
    assert!(p.is_power_of_two());
    let scale = 1.0 / (p as f32).sqrt();
    (0..p)
        .map(|i| {
            (0..p)
                .map(|j| {
                    let bits = (i & j).count_ones();
                    if bits % 2 == 0 {
                        scale
                    } else {
                        -scale
                    }
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn matches_dense_matrix() {
        for p in [2, 4, 8, 16, 64] {
            let mut rng = Pcg64::seeded(p as u64);
            let x: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
            let h = dense_hadamard(p);
            let want: Vec<f32> = (0..p)
                .map(|i| (0..p).map(|j| h[i][j] * x[j]).sum())
                .collect();
            let mut got = x.clone();
            fwht_inplace(&mut got);
            for (a, b) in got.iter().zip(want.iter()) {
                assert!((a - b).abs() < 1e-4, "p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn self_inverse() {
        let mut rng = Pcg64::seeded(9);
        let orig: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let mut x = orig.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn preserves_norm() {
        let mut rng = Pcg64::seeded(10);
        let orig: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let mut x = orig;
        fwht_inplace(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
    }

    #[test]
    fn blocks_independent() {
        let mut rng = Pcg64::seeded(11);
        let a: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let mut joined: Vec<f32> = a.iter().chain(b.iter()).copied().collect();
        fwht_blocks(&mut joined, 64);
        let mut ea = a.clone();
        fwht_inplace(&mut ea);
        let mut eb = b.clone();
        fwht_inplace(&mut eb);
        assert_eq!(&joined[..64], &ea[..]);
        assert_eq!(&joined[64..], &eb[..]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        fwht_inplace(&mut [0.0; 12]);
    }

    #[test]
    fn blocked_kernel_is_bit_exact_vs_scalar_reference() {
        // property test across sizes straddling FWHT_TILE: narrow tail
        // stages, the tile-local fast path, and the cross-tile lane loop
        // must all reproduce the scalar oracle bit for bit
        for p in [2usize, 4, 8, 16, 128, 1024, FWHT_TILE, 4 * FWHT_TILE] {
            for trial in 0..4u64 {
                let mut rng = Pcg64::seeded(p as u64 * 31 + trial);
                let x: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
                let mut blocked = x.clone();
                fwht_inplace(&mut blocked);
                let mut scalar = x;
                fwht_scalar_reference(&mut scalar);
                for (i, (a, b)) in blocked.iter().zip(scalar.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "p={p} trial={trial} lane {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn blocks_go_through_blocked_kernel_bit_exact() {
        let p = 2 * FWHT_TILE;
        let mut rng = Pcg64::seeded(77);
        let mut joined: Vec<f32> = (0..2 * p).map(|_| rng.normal() as f32).collect();
        let mut want = joined.clone();
        for blk in want.chunks_exact_mut(p) {
            fwht_scalar_reference(blk);
        }
        fwht_blocks(&mut joined, p);
        assert!(joined
            .iter()
            .zip(&want)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn linearity() {
        // H(a + b) == H(a) + H(b): encoded tensors reduce without decoding
        let mut rng = Pcg64::seeded(12);
        let a: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let mut sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        fwht_inplace(&mut sum);
        let mut ea = a;
        fwht_inplace(&mut ea);
        let mut eb = b;
        fwht_inplace(&mut eb);
        for i in 0..128 {
            assert!((sum[i] - (ea[i] + eb[i])).abs() < 1e-4);
        }
    }
}
