//! Native fast Walsh–Hadamard transform (orthonormal), the simulation
//! hot path. Bit-for-bit the same math as the L1 Pallas kernel
//! (`python/compile/kernels/hadamard.py`) and validated against the AOT'd
//! PJRT artifact in `rust/tests/pjrt_integration.rs`.
//!
//! The in-place butterfly runs in O(p log p); the §Perf pass vectorizes the
//! inner loops via exact-chunk iteration the compiler auto-vectorizes.

/// In-place orthonormal FWHT of one power-of-two-length block.
///
/// §Perf: the butterfly is written as disjoint-half zips (`split_at_mut`)
/// so LLVM auto-vectorizes every stage with h ≥ SIMD width; the h=1 stage
/// is a special-cased pair pass, and the 1/√p scale is fused into the
/// final stage's writeback (saves one full pass over the buffer).
pub fn fwht_inplace(x: &mut [f32]) {
    let p = x.len();
    assert!(p.is_power_of_two(), "block length {p} must be a power of two");
    if p == 1 {
        return; // H_1 = [1]
    }
    let scale = 1.0 / (p as f32).sqrt();

    // stage h = 1: adjacent pairs (scalar but cheap, sequential access)
    {
        let last = p == 2;
        let s = if last { scale } else { 1.0 };
        for pair in x.chunks_exact_mut(2) {
            let a = pair[0];
            let b = pair[1];
            pair[0] = (a + b) * s;
            pair[1] = (a - b) * s;
        }
        if last {
            return;
        }
    }
    // stages h = 2 .. p/2: vectorized half-zips
    let mut h = 2;
    while h < p {
        let step = h * 2;
        let last = step == p;
        for blk in x.chunks_exact_mut(step) {
            let (lo, hi) = blk.split_at_mut(h);
            if last {
                for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                    let s0 = *a;
                    let s1 = *b;
                    *a = (s0 + s1) * scale;
                    *b = (s0 - s1) * scale;
                }
            } else {
                for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                    let s0 = *a;
                    let s1 = *b;
                    *a = s0 + s1;
                    *b = s0 - s1;
                }
            }
        }
        h = step;
    }
}

/// Block-wise FWHT over a flat buffer whose length is a multiple of `p`.
pub fn fwht_blocks(x: &mut [f32], p: usize) {
    assert!(x.len() % p == 0, "length {} not a multiple of {p}", x.len());
    for block in x.chunks_exact_mut(p) {
        fwht_inplace(block);
    }
}

/// Reference dense Hadamard matrix (for tests): H[i][j] = ±1/sqrt(p).
#[cfg(test)]
pub fn dense_hadamard(p: usize) -> Vec<Vec<f32>> {
    assert!(p.is_power_of_two());
    let scale = 1.0 / (p as f32).sqrt();
    (0..p)
        .map(|i| {
            (0..p)
                .map(|j| {
                    let bits = (i & j).count_ones();
                    if bits % 2 == 0 {
                        scale
                    } else {
                        -scale
                    }
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn matches_dense_matrix() {
        for p in [2, 4, 8, 16, 64] {
            let mut rng = Pcg64::seeded(p as u64);
            let x: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
            let h = dense_hadamard(p);
            let want: Vec<f32> = (0..p)
                .map(|i| (0..p).map(|j| h[i][j] * x[j]).sum())
                .collect();
            let mut got = x.clone();
            fwht_inplace(&mut got);
            for (a, b) in got.iter().zip(want.iter()) {
                assert!((a - b).abs() < 1e-4, "p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn self_inverse() {
        let mut rng = Pcg64::seeded(9);
        let orig: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let mut x = orig.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn preserves_norm() {
        let mut rng = Pcg64::seeded(10);
        let orig: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let mut x = orig;
        fwht_inplace(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
    }

    #[test]
    fn blocks_independent() {
        let mut rng = Pcg64::seeded(11);
        let a: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let mut joined: Vec<f32> = a.iter().chain(b.iter()).copied().collect();
        fwht_blocks(&mut joined, 64);
        let mut ea = a.clone();
        fwht_inplace(&mut ea);
        let mut eb = b.clone();
        fwht_inplace(&mut eb);
        assert_eq!(&joined[..64], &ea[..]);
        assert_eq!(&joined[64..], &eb[..]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        fwht_inplace(&mut [0.0; 12]);
    }

    #[test]
    fn linearity() {
        // H(a + b) == H(a) + H(b): encoded tensors reduce without decoding
        let mut rng = Pcg64::seeded(12);
        let a: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let mut sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        fwht_inplace(&mut sum);
        let mut ea = a;
        fwht_inplace(&mut ea);
        let mut eb = b;
        fwht_inplace(&mut eb);
        for i in 0..128 {
            assert!((sum[i] - (ea[i] + eb[i])).abs() < 1e-4);
        }
    }
}
