//! Lightweight data recovery & loss mitigation (§3.2).
//!
//! OptiNIC ships loss repair out of the transport and into the ML stack:
//! tensors are block-wise Hadamard-encoded (L1 Pallas kernel / the native
//! FWHT here), stride-interleaved across packets so one lost packet erases
//! only `p/S` coefficients per block, and inverse-transformed after the
//! collective — dispersing clustered loss into small, SGD-tolerable noise.
//!
//! Two implementations, cross-validated in tests:
//! * [`hadamard`] — vectorized native Rust FWHT for the simulation hot path
//!   (millions of blocks per experiment);
//! * [`runtime::Engine::hadamard`] — the AOT'd L1 Pallas kernel through
//!   PJRT, used by the Table 3 timing bench and the parity tests.

pub mod hadamard;
pub mod stride;

pub use hadamard::{fwht_blocks, fwht_inplace, fwht_scalar_reference};
pub use stride::{deinterleave, interleave};

use crate::verbs::{LossMap, MemPool, MrId};

/// Consume a completion event's [`LossMap`] directly (verbs v2): zero every
/// span the NIC reports missing in the landing region at `base` (byte
/// offset into `mr`), clamped to the region. Returns the bytes zeroed.
///
/// This is the app-side half of OptiNIC's placement contract — lost
/// fragments must read as zeros before the decode/reduce step (§3.2) — and
/// replaces inferring loss from buffer contents: the transport *tells* the
/// recovery layer exactly what never arrived.
pub fn scrub_missing(mem: &mut MemPool, mr: MrId, base: usize, loss: &LossMap) -> usize {
    let cap = mem.len(mr);
    let mut zeroed = 0;
    loss.for_each_missing(|off, len| {
        let start = (base + off).min(cap);
        let end = (base + off + len).min(cap);
        if end > start {
            mem.zero(mr, start, end - start);
            zeroed += end - start;
        }
    });
    zeroed
}

/// Codec configuration for a tensor's journey through the lossy fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// No coding: raw contiguous packets (clustered loss).
    Raw,
    /// Full-message Hadamard (one giant block): best dispersion, highest
    /// compute cost. Block size = message rounded up to a power of two.
    HadamardMsg,
    /// Block-wise Hadamard, contiguous packets (a lost packet kills whole
    /// blocks — the §3.2a failure mode).
    HadamardBlock { p: usize },
    /// Block-wise Hadamard + stride interleaving (the paper's design).
    HadamardBlockStride { p: usize, stride: usize },
}

impl Codec {
    pub fn name(&self) -> String {
        match self {
            Codec::Raw => "Raw".into(),
            Codec::HadamardMsg => "HD:Msg".into(),
            Codec::HadamardBlock { p } => format!("HD:Blk(p={p})"),
            Codec::HadamardBlockStride { p, stride } => {
                format!("HD:Blk+Str(p={p},S={stride})")
            }
        }
    }

    /// Stride value to advertise in packet headers (§3.3's 2-byte field).
    pub fn wire_stride(&self) -> u16 {
        match self {
            Codec::HadamardBlockStride { stride, .. } => (*stride).min(u16::MAX as usize) as u16,
            _ => 1,
        }
    }
}

fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Encode a tensor for transmission. Returns the wire-format vector
/// (possibly padded — `decode` trims back to `data.len()`).
pub fn encode(data: &[f32], codec: Codec) -> Vec<f32> {
    match codec {
        Codec::Raw => data.to_vec(),
        Codec::HadamardMsg => {
            let p = next_pow2(data.len().max(2));
            let mut buf = data.to_vec();
            buf.resize(p, 0.0);
            fwht_inplace(&mut buf);
            buf
        }
        Codec::HadamardBlock { p } => {
            let mut buf = data.to_vec();
            buf.resize(data.len().next_multiple_of(p), 0.0);
            fwht_blocks(&mut buf, p);
            buf
        }
        Codec::HadamardBlockStride { p, stride } => {
            assert!(p % stride == 0, "stride must divide p");
            let mut buf = data.to_vec();
            // pad so the block count is a multiple of the stride group
            let padded = data.len().next_multiple_of(p * stride);
            buf.resize(padded, 0.0);
            fwht_blocks(&mut buf, p);
            interleave(&buf, p, stride)
        }
    }
}

/// Decode a received wire-format vector (with lost spans zeroed by the
/// transport) back to `n` elements.
pub fn decode(wire: &[f32], codec: Codec, n: usize) -> Vec<f32> {
    match codec {
        Codec::Raw => wire[..n].to_vec(),
        Codec::HadamardMsg => {
            let mut buf = wire.to_vec();
            fwht_inplace(&mut buf);
            buf.truncate(n);
            buf
        }
        Codec::HadamardBlock { p } => {
            let mut buf = wire.to_vec();
            fwht_blocks(&mut buf, p);
            buf.truncate(n);
            buf
        }
        Codec::HadamardBlockStride { p, stride } => {
            let mut buf = deinterleave(wire, p, stride);
            fwht_blocks(&mut buf, p);
            buf.truncate(n);
            buf
        }
    }
}

/// Mean-squared error between a recovered tensor and the original —
/// the Fig 7 metric.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Drop whole `pkt_elems`-sized wire packets with probability `rate`
/// (zeroing their span — the transport's placement semantics), returning
/// the count dropped. Used by the Fig 7 bench and recovery tests.
pub fn drop_packets(
    wire: &mut [f32],
    pkt_elems: usize,
    rate: f64,
    rng: &mut crate::util::prng::Pcg64,
) -> usize {
    let mut dropped = 0;
    for chunk in wire.chunks_mut(pkt_elems) {
        if rng.chance(rate) {
            chunk.fill(0.0);
            dropped += 1;
        }
    }
    dropped
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn all_codecs_roundtrip_losslessly() {
        let x = data(1000, 1);
        for codec in [
            Codec::Raw,
            Codec::HadamardMsg,
            Codec::HadamardBlock { p: 64 },
            Codec::HadamardBlockStride { p: 64, stride: 16 },
        ] {
            let wire = encode(&x, codec);
            let back = decode(&wire, codec, x.len());
            let err = mse(&x, &back);
            assert!(err < 1e-9, "{}: mse {err}", codec.name());
        }
    }

    #[test]
    fn stride_disperses_loss_better_than_block() {
        let x = data(64 * 64, 2);
        let p = 64;
        let mut rng = Pcg64::seeded(3);
        let mut mse_block = 0.0;
        let mut mse_stride = 0.0;
        for trial in 0..20 {
            let block = Codec::HadamardBlock { p };
            let strided = Codec::HadamardBlockStride { p, stride: p };
            let mut w1 = encode(&x, block);
            let mut w2 = encode(&x, strided);
            let mut rng2 = Pcg64::new(100 + trial, 0);
            drop_packets(&mut w1, p, 0.05, &mut rng);
            drop_packets(&mut w2, p, 0.05, &mut rng2);
            mse_block += mse(&x, &decode(&w1, block, x.len()));
            mse_stride += mse(&x, &decode(&w2, strided, x.len()));
        }
        assert!(
            mse_stride < mse_block,
            "stride {mse_stride} !< block {mse_block}"
        );
    }

    #[test]
    fn stride_approaches_full_message_dispersion() {
        // Fig 7a: HD:Blk+Str(S=p) MSE ≈ HD:Msg MSE at a fraction of cost
        let x = data(32 * 256, 4);
        let p = 256;
        let drop = 0.04;
        let run = |codec: Codec, seed: u64| {
            let mut acc = 0.0;
            for t in 0..10 {
                let mut w = encode(&x, codec);
                let mut rng = Pcg64::new(seed + t, 1);
                drop_packets(&mut w, p, drop, &mut rng);
                acc += mse(&x, &decode(&w, codec, x.len()));
            }
            acc / 10.0
        };
        let msg = run(Codec::HadamardMsg, 10);
        let strided = run(Codec::HadamardBlockStride { p, stride: p }, 10);
        // within 2.5× of the ideal full-message transform
        assert!(
            strided < msg * 2.5 + 1e-12,
            "strided {strided} vs msg {msg}"
        );
    }

    #[test]
    fn raw_loss_is_clustered() {
        // Raw: a dropped packet wipes a contiguous span entirely
        let x = data(1024, 5);
        let mut w = encode(&x, Codec::Raw);
        w[128..256].fill(0.0); // one lost packet
        let back = decode(&w, Codec::Raw, x.len());
        // exactly that span is destroyed, the rest is exact
        assert_eq!(&back[..128], &x[..128]);
        assert!(back[128..256].iter().all(|&v| v == 0.0));
        assert_eq!(&back[256..], &x[256..]);
    }

    #[test]
    fn hadamard_spreads_single_packet_loss() {
        // With HD:Blk+Str, the same loss perturbs many elements slightly
        // instead of a few elements totally.
        let x = data(64 * 64, 6);
        let codec = Codec::HadamardBlockStride { p: 64, stride: 64 };
        let mut w = encode(&x, codec);
        w[0..64].fill(0.0);
        let back = decode(&w, codec, x.len());
        let worst = x
            .iter()
            .zip(back.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let max_val = x.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(
            worst < 0.8 * max_val,
            "loss not dispersed: worst {worst} vs max {max_val}"
        );
    }

    #[test]
    fn wire_stride_header_field() {
        assert_eq!(Codec::Raw.wire_stride(), 1);
        assert_eq!(
            Codec::HadamardBlockStride { p: 64, stride: 16 }.wire_stride(),
            16
        );
    }

    #[test]
    fn mse_zero_for_identical() {
        let x = data(100, 7);
        assert_eq!(mse(&x, &x), 0.0);
    }

    #[test]
    fn scrub_missing_zeroes_exactly_reported_spans() {
        let mut mem = MemPool::new();
        let mr = mem.register(0, 64);
        mem.write(mr, 0, &[0xFFu8; 64]);
        // message of 32 bytes landing at base 16; bytes [8, 24) of the
        // message never arrived
        let mut loss = LossMap::new(32);
        loss.record(0, 8);
        loss.record(24, 8);
        let zeroed = scrub_missing(&mut mem, mr, 16, &loss);
        assert_eq!(zeroed, 16);
        assert!(mem.read(mr, 0, 24).iter().all(|&b| b == 0xFF), "before base+8 intact");
        assert!(mem.read(mr, 24, 16).iter().all(|&b| b == 0), "missing span zeroed");
        assert!(mem.read(mr, 40, 24).iter().all(|&b| b == 0xFF), "tail intact");
    }

    #[test]
    fn scrub_missing_clamps_to_region() {
        let mut mem = MemPool::new();
        let mr = mem.register(0, 16);
        mem.write(mr, 0, &[7u8; 16]);
        // loss map larger than the region: must not panic, must clamp
        let loss = LossMap::new(64); // wholly lost
        let zeroed = scrub_missing(&mut mem, mr, 8, &loss);
        assert_eq!(zeroed, 8);
        assert!(mem.read(mr, 8, 8).iter().all(|&b| b == 0));
        assert!(mem.read(mr, 0, 8).iter().all(|&b| b == 7));
    }

    #[test]
    fn scrub_missing_noop_when_complete() {
        let mut mem = MemPool::new();
        let mr = mem.register(0, 32);
        mem.write(mr, 0, &[3u8; 32]);
        let loss = LossMap::complete(32);
        assert_eq!(scrub_missing(&mut mem, mr, 0, &loss), 0);
        assert!(mem.read(mr, 0, 32).iter().all(|&b| b == 3));
    }
}
