//! Stride-based packet interleaving (§3.2b).
//!
//! Identical permutation to `python/compile/kernels/ref.py::interleave_ref`
//! (pinned by the golden-vector test in both languages): blocks are grouped
//! `S` at a time; wire packet `j` of a group carries, at slot `m`,
//!
//! ```text
//! block = g·S + (m mod S),   coeff = j·(p/S) + (m div S)
//! ```
//!
//! so each p-element packet holds p/S coefficients from each of S blocks.
//! Losing one packet erases p/S coefficients per block, which the inverse
//! Hadamard then disperses across the whole block.

/// Interleave `encoded` (length multiple of p·stride) into wire order.
pub fn interleave(encoded: &[f32], p: usize, stride: usize) -> Vec<f32> {
    validate(encoded.len(), p, stride);
    let s = stride;
    let per = p / s;
    let nblocks = encoded.len() / p;
    let groups = nblocks / s;
    // §Perf: iterate (j, t, i) natural wire order with sequential writes
    // and stride-p reads — no per-element div/mod (3.5× over the naive
    // gather; see EXPERIMENTS.md §Perf)
    let mut wire = vec![0.0f32; encoded.len()];
    for g in 0..groups {
        let gbase = g * s * p;
        let src = &encoded[gbase..gbase + s * p];
        let dst = &mut wire[gbase..gbase + s * p];
        for j in 0..s {
            let row = &mut dst[j * p..(j + 1) * p];
            for t in 0..per {
                let coeff = j * per + t;
                let out = &mut row[t * s..(t + 1) * s];
                for (i, o) in out.iter_mut().enumerate() {
                    *o = src[i * p + coeff];
                }
            }
        }
    }
    wire
}

/// Inverse of [`interleave`].
pub fn deinterleave(wire: &[f32], p: usize, stride: usize) -> Vec<f32> {
    validate(wire.len(), p, stride);
    let s = stride;
    let per = p / s;
    let nblocks = wire.len() / p;
    let groups = nblocks / s;
    // §Perf: iterate output blocks so writes are sequential (write-scatter
    // is costlier than read-gather on x86); reads stride by s within the
    // group's wire rows
    let mut out = vec![0.0f32; wire.len()];
    for g in 0..groups {
        let gbase = g * s * p;
        let src = &wire[gbase..gbase + s * p];
        let dst = &mut out[gbase..gbase + s * p];
        for i in 0..s {
            let block = &mut dst[i * p..(i + 1) * p];
            for j in 0..s {
                let row = &src[j * p..(j + 1) * p];
                let seg = &mut block[j * per..(j + 1) * per];
                for (t, o) in seg.iter_mut().enumerate() {
                    *o = row[t * s + i];
                }
            }
        }
    }
    out
}

fn validate(len: usize, p: usize, stride: usize) {
    assert!(stride >= 1 && stride <= p, "stride {stride} out of range");
    assert!(p % stride == 0, "stride {stride} must divide p {p}");
    assert!(len % p == 0, "length {len} not a multiple of p {p}");
    let nblocks = len / p;
    assert!(
        nblocks % stride == 0,
        "block count {nblocks} not a multiple of stride {stride}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn golden_vector_matches_python() {
        // pinned against python/tests/test_hadamard.py::test_golden_vector
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect(); // 2 blocks of 4
        let w = interleave(&x, 4, 2);
        assert_eq!(w, vec![0.0, 4.0, 1.0, 5.0, 2.0, 6.0, 3.0, 7.0]);
    }

    #[test]
    fn roundtrip_all_strides() {
        let mut rng = Pcg64::seeded(1);
        for (p, blocks) in [(8usize, 8usize), (64, 16), (256, 256)] {
            let x: Vec<f32> = (0..p * blocks).map(|_| rng.normal() as f32).collect();
            let mut s = 1;
            while s <= p {
                if blocks % s == 0 {
                    let w = interleave(&x, p, s);
                    assert_eq!(deinterleave(&w, p, s), x, "p={p} s={s}");
                }
                s *= 2;
            }
        }
    }

    #[test]
    fn stride_one_is_identity() {
        let x: Vec<f32> = (0..32).map(|i| i as f32).collect();
        assert_eq!(interleave(&x, 8, 1), x);
    }

    #[test]
    fn packet_loss_touches_exactly_s_blocks() {
        // the §3.2b dispersion property: drop wire packet 0 and count
        // affected coefficients per block
        let p = 16;
        let blocks = 16;
        for s in [1usize, 2, 4, 8, 16] {
            let x: Vec<f32> = (1..=(p * blocks) as u32).map(|v| v as f32).collect();
            let mut w = interleave(&x, p, s);
            w[..p].fill(0.0);
            let back = deinterleave(&w, p, s);
            let mut affected_blocks = 0;
            for b in 0..blocks {
                let zeros = back[b * p..(b + 1) * p].iter().filter(|&&v| v == 0.0).count();
                if zeros > 0 {
                    affected_blocks += 1;
                    assert_eq!(zeros, p / s, "s={s} block={b}");
                }
            }
            assert_eq!(affected_blocks, s, "s={s}");
        }
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn rejects_non_dividing_stride() {
        interleave(&[0.0; 24], 8, 3);
    }

    #[test]
    fn property_permutation_is_bijective() {
        use crate::util::proptest_mini::*;
        quickcheck(
            "stride-permutation-bijective",
            &IntRange { lo: 0, hi: 3 },
            |&log_s: &u64| {
                let p = 8;
                let s = 1usize << log_s;
                let blocks = 8;
                let x: Vec<f32> = (0..(p * blocks) as u32).map(|v| v as f32).collect();
                let w = interleave(&x, p, s);
                // every element appears exactly once
                let mut seen = vec![false; x.len()];
                for v in &w {
                    let idx = *v as usize;
                    crate::prop_assert!(!seen[idx], "duplicate {idx}");
                    seen[idx] = true;
                }
                crate::prop_assert!(seen.iter().all(|&b| b), "missing elements");
                Ok(())
            },
        );
    }
}
