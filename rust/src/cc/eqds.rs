//! EQDS (Olteanu et al., NSDI'22): receiver-driven, credit-based transport.
//!
//! The receiver ("edge queue") grants credits at its downlink rate; senders
//! transmit only against credit. This gives near-zero in-network queueing.
//! In our model the receiving transport issues `Credit` packets for active
//! QPs (see `transport::*`); this sender-side object tracks the credit
//! balance and exposes a pull-paced rate. Credits are just CC signals —
//! they never imply reliable delivery, which is why OptiNIC composes with
//! EQDS cleanly (§3.1.3; the paper's software prototype uses EQDS, §4).

use crate::cc::{AckFeedback, CongestionControl};
use crate::sim::SimTime;

#[derive(Debug)]
pub struct Eqds {
    line_rate: f64,
    /// Credit balance in bytes.
    credit: i64,
    /// Initial speculative window (EQDS allows one BDP unsolicited so the
    /// first RTT isn't wasted).
    speculative: i64,
    granted_total: u64,
    consumed_total: u64,
}

impl Eqds {
    pub fn new(line_rate: f64, base_rtt: u64) -> Eqds {
        let bdp = (line_rate * base_rtt as f64) as i64;
        Eqds {
            line_rate,
            credit: 0,
            speculative: bdp.max(4096),
            granted_total: 0,
            consumed_total: 0,
        }
    }

    pub fn credit_bytes(&self) -> i64 {
        self.credit + self.speculative
    }
}

impl CongestionControl for Eqds {
    fn name(&self) -> &'static str {
        "EQDS"
    }

    /// Credit-based senders burst at line rate when they hold credit.
    fn rate(&self) -> f64 {
        self.line_rate
    }

    fn on_ack(&mut self, _fb: AckFeedback) {}

    fn on_cnp(&mut self, _now: SimTime) {}

    fn on_credit(&mut self, bytes: usize) {
        self.credit += bytes as i64;
        self.granted_total += bytes as u64;
    }

    fn try_send(&mut self, bytes: usize) -> bool {
        if self.speculative >= bytes as i64 {
            self.speculative -= bytes as i64;
            self.consumed_total += bytes as u64;
            return true;
        }
        if self.credit >= bytes as i64 {
            self.credit -= bytes as i64;
            self.consumed_total += bytes as u64;
            true
        } else {
            false
        }
    }

    fn on_timeout(&mut self, _now: SimTime) {
        // lost credits are re-granted by the receiver's pull pacer; a small
        // speculative refill prevents deadlock if a grant batch vanished
        self.speculative = self.speculative.max(4096);
    }

    fn state_bytes(&self) -> usize {
        // credit balance + speculative window + pull-queue pointer
        16
    }
}

/// Receiver-side pull pacer: grants credits round-robin across QPs that
/// have announced demand, at the downlink rate. Lives in the receiving
/// transport; kept here so both sides of the protocol sit together.
#[derive(Debug, Default)]
pub struct PullPacer {
    /// (qpn, remaining bytes to grant)
    demands: Vec<(u32, usize)>,
    cursor: usize,
}

impl PullPacer {
    pub fn announce(&mut self, qpn: u32, bytes: usize) {
        if let Some(d) = self.demands.iter_mut().find(|d| d.0 == qpn) {
            d.1 += bytes;
        } else {
            self.demands.push((qpn, bytes));
        }
    }

    /// Next grant of up to `chunk` bytes: returns (qpn, bytes).
    pub fn next_grant(&mut self, chunk: usize) -> Option<(u32, usize)> {
        if self.demands.is_empty() {
            return None;
        }
        self.cursor %= self.demands.len();
        let (qpn, remaining) = &mut self.demands[self.cursor];
        let qpn = *qpn;
        let grant = chunk.min(*remaining);
        *remaining -= grant;
        if *remaining == 0 {
            self.demands.remove(self.cursor);
        } else {
            self.cursor += 1;
        }
        Some((qpn, grant))
    }

    pub fn pending(&self) -> usize {
        self.demands.iter().map(|d| d.1).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speculative_window_allows_first_bdp() {
        let mut cc = Eqds::new(3.125, 10_000); // BDP = 31250
        assert!(cc.try_send(10_000));
        assert!(cc.try_send(10_000));
        assert!(cc.try_send(10_000));
        // speculative exhausted, no credit
        assert!(!cc.try_send(10_000));
    }

    #[test]
    fn credits_unblock_sending() {
        let mut cc = Eqds::new(3.125, 0);
        cc.speculative = 0;
        assert!(!cc.try_send(1500));
        cc.on_credit(3000);
        assert!(cc.try_send(1500));
        assert!(cc.try_send(1500));
        assert!(!cc.try_send(1500));
    }

    #[test]
    fn pull_pacer_round_robin() {
        let mut p = PullPacer::default();
        p.announce(1, 3000);
        p.announce(2, 1500);
        let g1 = p.next_grant(1500).unwrap();
        let g2 = p.next_grant(1500).unwrap();
        let g3 = p.next_grant(1500).unwrap();
        assert_eq!(g1, (1, 1500));
        assert_eq!(g2, (2, 1500)); // 2 drained and removed
        assert_eq!(g3, (1, 1500));
        assert!(p.next_grant(1500).is_none());
        assert_eq!(p.pending(), 0);
    }

    #[test]
    fn announce_merges_same_qp() {
        let mut p = PullPacer::default();
        p.announce(7, 100);
        p.announce(7, 200);
        assert_eq!(p.pending(), 300);
        assert_eq!(p.next_grant(1000), Some((7, 300)));
    }
}
