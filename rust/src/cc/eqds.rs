//! EQDS (Olteanu et al., NSDI'22): receiver-driven, credit-based transport.
//!
//! The receiver ("edge queue") grants credits at its downlink rate; senders
//! transmit only against credit. This gives near-zero in-network queueing.
//! Credits are just CC signals — they never imply reliable delivery, which
//! is why OptiNIC composes with EQDS cleanly (§3.1.3; the paper's software
//! prototype uses EQDS, §4).
//!
//! CC v2 moved the whole protocol behind [`CongestionControl`]: one
//! [`Eqds`] instance per QP holds BOTH roles, so no transport carries
//! EQDS-specific state anymore (the grant loop used to live inside
//! `transport/optinic.rs`).
//!
//! * **Sender side** — `CreditGrant` signals top up the credit balance;
//!   `try_send` consumes it (speculative window first, so the first BDP
//!   isn't wasted waiting for grants); `announces_demand` tells the
//!   transport to emit a pull request per admitted WQE. `LossHint
//!   { timeout: true }` refills a minimal speculative window so a lost
//!   grant batch cannot deadlock a sender.
//! * **Receiver side** — `on_demand` books announced demand,
//!   `next_grant` paces credit grants at the adaptive pull rate, and
//!   `on_delivery` runs AIMD on that rate from observed CE marks so pull
//!   traffic backs off around non-EQDS (background) load — the
//!   edge-queue behavior of EQDS.

use crate::cc::{CcCtx, CcSignal, CongestionControl};
use crate::net::NetHints;
use crate::sim::SimTime;

#[derive(Debug)]
pub struct Eqds {
    line_rate: f64,
    // ---- sender side ----
    /// Credit balance in bytes.
    credit: i64,
    /// Initial speculative window (EQDS allows one BDP unsolicited so the
    /// first RTT isn't wasted).
    speculative: i64,
    granted_total: u64,
    consumed_total: u64,
    // ---- receiver side (pull pacer) ----
    /// Announced-but-ungranted peer demand, bytes.
    demand: usize,
    /// Credits this endpoint has issued to its peer, bytes.
    issued_total: u64,
    /// Receiver-driven grant rate (bytes/ns): AIMD on observed CE marks.
    grant_rate: f64,
}

impl Eqds {
    pub fn new(line_rate: f64, base_rtt: u64) -> Eqds {
        let bdp = (line_rate * base_rtt as f64) as i64;
        Eqds {
            line_rate,
            credit: 0,
            speculative: bdp.max(4096),
            granted_total: 0,
            consumed_total: 0,
            demand: 0,
            issued_total: 0,
            grant_rate: 0.9 * line_rate,
        }
    }

    pub fn credit_bytes(&self) -> i64 {
        self.credit + self.speculative
    }

    /// Remaining speculative (unsolicited) window, bytes.
    pub fn speculative_bytes(&self) -> i64 {
        self.speculative
    }

    /// Granted-credit balance only (excludes the speculative window).
    pub fn credit_balance(&self) -> i64 {
        self.credit
    }

    /// Total credit bytes ever granted to this sender.
    pub fn granted_bytes(&self) -> u64 {
        self.granted_total
    }

    /// Total bytes this sender has admitted against credit/speculation.
    pub fn consumed_bytes(&self) -> u64 {
        self.consumed_total
    }

    /// Total credit bytes this endpoint's pull pacer has issued.
    pub fn issued_bytes(&self) -> u64 {
        self.issued_total
    }

    /// Current receiver-side grant pacing rate, bytes/ns.
    pub fn grant_rate(&self) -> f64 {
        self.grant_rate
    }
}

impl CongestionControl for Eqds {
    fn name(&self) -> &'static str {
        "EQDS"
    }

    /// Credit-based senders burst at line rate when they hold credit.
    fn rate(&self) -> f64 {
        self.line_rate
    }

    /// The window IS the credit balance.
    fn cwnd(&self) -> usize {
        self.credit_bytes().max(0) as usize
    }

    fn on_signal(&mut self, sig: CcSignal, _ctx: &CcCtx) {
        match sig {
            CcSignal::CreditGrant { bytes } => {
                self.credit += bytes as i64;
                self.granted_total += bytes as u64;
            }
            CcSignal::LossHint { .. } => {
                // any detected loss leaves a credit deficit: the original
                // transmission consumed credit the receiver granted once,
                // and the retransmission must be paid for again. A minimal
                // speculative refill keeps fast retransmit moving (NACK /
                // SACK-gap hints) and prevents deadlock if a grant batch
                // vanished (RTO).
                self.speculative = self.speculative.max(4096);
            }
            _ => {}
        }
    }

    fn try_send(&mut self, bytes: usize) -> bool {
        if self.speculative >= bytes as i64 {
            self.speculative -= bytes as i64;
            self.consumed_total += bytes as u64;
            return true;
        }
        if self.credit >= bytes as i64 {
            self.credit -= bytes as i64;
            self.consumed_total += bytes as u64;
            true
        } else {
            false
        }
    }

    fn announces_demand(&self) -> bool {
        true
    }

    fn on_demand(&mut self, bytes: usize) {
        self.demand += bytes;
    }

    fn demand_pending(&self) -> usize {
        self.demand
    }

    fn next_grant(&mut self, chunk: usize) -> Option<(usize, SimTime)> {
        if self.demand == 0 || chunk == 0 {
            return None;
        }
        let grant = chunk.min(self.demand);
        self.demand -= grant;
        self.issued_total += grant as u64;
        // pace grants at the receiver's adaptive pull rate
        let gap = (grant as f64 / self.grant_rate).ceil() as SimTime;
        Some((grant, gap.max(1)))
    }

    fn on_delivery(&mut self, _bytes: usize, hints: &NetHints, _ctx: &CcCtx) {
        // receiver-driven grant-rate AIMD (EQDS edge queue): CE marks mean
        // the downlink is contended with non-EQDS traffic — back off grants
        if hints.ecn {
            self.grant_rate = (self.grant_rate * 0.95).max(0.2 * self.line_rate);
        } else {
            self.grant_rate = (self.grant_rate * 1.0005).min(0.95 * self.line_rate);
        }
    }

    fn state_bytes(&self) -> usize {
        // credit balance + speculative window + demand counter + grant rate
        16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> CcCtx {
        CcCtx {
            now: 0,
            qpn: 1,
            bytes: 0,
            hops: 2,
        }
    }

    #[test]
    fn speculative_window_allows_first_bdp() {
        let mut cc = Eqds::new(3.125, 10_000); // BDP = 31250
        assert!(cc.try_send(10_000));
        assert!(cc.try_send(10_000));
        assert!(cc.try_send(10_000));
        // speculative exhausted, no credit
        assert!(!cc.try_send(10_000));
    }

    #[test]
    fn credits_unblock_sending() {
        let mut cc = Eqds::new(3.125, 0);
        cc.speculative = 0;
        assert!(!cc.try_send(1500));
        cc.on_signal(CcSignal::CreditGrant { bytes: 3000 }, &ctx());
        assert!(cc.try_send(1500));
        assert!(cc.try_send(1500));
        assert!(!cc.try_send(1500));
    }

    #[test]
    fn grant_loop_drains_demand() {
        let mut cc = Eqds::new(3.125, 0);
        cc.on_demand(3000);
        cc.on_demand(1500);
        assert_eq!(cc.demand_pending(), 4500);
        let (g1, gap1) = cc.next_grant(1500).unwrap();
        assert_eq!(g1, 1500);
        assert!(gap1 >= 1);
        let (g2, _) = cc.next_grant(4000).unwrap();
        assert_eq!(g2, 3000);
        assert_eq!(cc.demand_pending(), 0);
        assert!(cc.next_grant(1500).is_none());
        assert_eq!(cc.issued_bytes(), 4500);
    }

    #[test]
    fn grant_rate_aimd_reacts_to_marks() {
        let mut cc = Eqds::new(3.125, 0);
        let r0 = cc.grant_rate();
        cc.on_delivery(
            1500,
            &NetHints {
                ecn: true,
                ..NetHints::default()
            },
            &ctx(),
        );
        assert!(cc.grant_rate() < r0, "mark must back the pull rate off");
        for _ in 0..10_000 {
            cc.on_delivery(1500, &NetHints::default(), &ctx());
        }
        assert!(cc.grant_rate() <= 0.95 * 3.125 + 1e-9);
        assert!(cc.grant_rate() > r0 * 0.9);
    }

    #[test]
    fn conservation_identity_holds() {
        let mut cc = Eqds::new(3.125, 10_000);
        let spec0 = cc.speculative_bytes();
        cc.on_signal(CcSignal::CreditGrant { bytes: 9000 }, &ctx());
        assert!(cc.try_send(30_000)); // speculative
        assert!(cc.try_send(5_000)); // credit (speculative only 1250 left)
        // consumed == granted − credit_left + speculative spent
        let spent_spec = spec0 - cc.speculative_bytes();
        assert_eq!(
            cc.consumed_bytes() as i64,
            cc.granted_bytes() as i64 - cc.credit_balance() + spent_spec
        );
        assert!(cc.credit_balance() >= 0);
        assert!(cc.speculative_bytes() >= 0);
    }

    #[test]
    fn epoch_cadence_grant_loop_drains_a_starved_sender() {
        // the fluid plane runs the receiver's pull pacer once per base-RTT
        // epoch: each tick books announced demand and converts it into
        // CreditGrant signals (the driver's epoch_tick). A sender whose
        // speculative window is spent must still push its whole message
        // through grants alone — the loop closes within one instance
        // because our Eqds holds both roles.
        let mut cc = Eqds::new(3.125, 0); // no speculative BDP
        cc.speculative = 0;
        let msg = 64 * 1024usize;
        cc.on_demand(msg);
        assert!(!cc.try_send(1500), "starved sender must be gated");
        let mut sent = 0usize;
        let mut epochs = 0u32;
        while sent < msg {
            epochs += 1;
            assert!(epochs < 100, "grant loop failed to drain in time");
            // one epoch tick: pace out up to one chunk of grants
            let Some((grant, gap)) = cc.next_grant(4096) else {
                break;
            };
            assert!(gap >= 1, "grants are paced, never instantaneous");
            cc.on_signal(CcSignal::CreditGrant { bytes: grant }, &ctx());
            // the sender spends exactly what was granted
            while sent < msg && cc.try_send(1500.min(msg - sent)) {
                sent += 1500.min(msg - sent);
            }
        }
        assert_eq!(sent, msg, "epoch-paced grants must drain the message");
        assert_eq!(cc.demand_pending(), 0);
        assert_eq!(cc.granted_bytes(), cc.issued_bytes());
    }

    #[test]
    fn loss_hints_refill_minimal_speculation() {
        let mut cc = Eqds::new(3.125, 0);
        cc.speculative = 0;
        // mild (NACK/SACK-gap) hint: the retransmission must be payable
        cc.on_signal(CcSignal::LossHint { timeout: false }, &ctx());
        assert!(cc.speculative_bytes() >= 4096);
        // the refill is a floor, not additive — repeated hints don't mint
        cc.on_signal(CcSignal::LossHint { timeout: true }, &ctx());
        assert_eq!(cc.speculative_bytes(), 4096);
    }
}
