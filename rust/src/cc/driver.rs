//! The congestion-control rate authority: the one object that owns
//! per-endpoint [`CongestionControl`] instances and answers "how fast may
//! this endpoint send right now?" for BOTH engine families (CC v2, PR 10).
//!
//! [`RateAuthority`] holds the per-endpoint CC state plus the pacing
//! state that used to be scattered across transport QP structs (pacer
//! horizon, pace-timer armed flag, grant-timer armed flag). Consumers:
//!
//! * **Packet engines** wrap it in a [`CcDriver`] and keep the
//!   per-fragment admission path: decompose raw feedback through
//!   [`CcDriver::on_ack`] / [`CcDriver::on_cnp`] / [`CcDriver::on_credit`]
//!   / [`CcDriver::on_loss`] — the ONLY place transport wire formats meet
//!   [`CcSignal`]s — and gate every fragment through an [`AdmitGate`]
//!   (resolved once per pump via [`CcDriver::gate`]), which folds pacing,
//!   software-datapath throughput caps, and credit consumption into one
//!   verdict. The receiver-side credit-grant loop runs through
//!   [`CcDriver::on_pull_req`] / [`CcDriver::grant_fired`], and
//!   [`CcDriver::on_delivery`] answers whether a CE-marked delivery should
//!   produce a CNP (the DCQCN notification-point policy, behind the trait).
//! * **The fluid engine** (`net/flowsim.rs`) registers one endpoint per
//!   bulk flow, feeds the SAME decomposition path with *synthesized*
//!   signals derived from solved fluid link state, and reads
//!   [`RateAuthority::rate_cap`] — `min(rate(), cwnd()/base_rtt)` — as the
//!   per-flow cap folded into the max-min water-fill. Epoch-cadence
//!   machinery that per-packet engines get for free (EQDS grant ticks,
//!   DBLP idle-gap phase detection) runs through
//!   [`RateAuthority::epoch_tick`].
//!
//! Neither consumer branches on [`CcKind`]: policies see signals only, so
//! the fluid engine honors all seven algorithms through one seam.
//!
//! The authority never touches the event queue: it records which logical
//! timers are outstanding and tells the caller when to arm one (the
//! transport owns timer ids and the PR-2 lazy-cancellation machinery).
//!
//! Exported counters (PR-2 `&'static str` key scheme, surfaced through
//! `Metrics::to_json`): `cc_cnp_rx`, `cc_rtt_samples`, `cc_credits_granted`,
//! `cc_pacing_stalls`.

use std::collections::BTreeMap;

use crate::cc::{CcCtx, CcKind, CcSignal, CongestionControl};
use crate::net::NetHints;
use crate::sim::{Metrics, SimTime};
use crate::transport::{Pacer, TransportCfg};
use crate::verbs::Qpn;

// (The fixed TOR_HOPS constant died with the single-switch assumption:
// the authority now carries the fabric's path length and prefers the hop
// count actually stamped into the feedback's NetHints.)

/// Budgeted per-endpoint footprint of a live CC instance (boxed policy
/// state + pacer + armed flags + map node), used by memory planners
/// (`est_cluster_bytes`) that cannot call `state_bytes()` on instances
/// that do not exist yet. Generous upper bound across all seven kinds.
pub const CC_ENDPOINT_BYTES: usize = 256;

/// Verdict for one fragment offered to [`CcDriver::admit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Transmit now; the pacing horizon and any credit were reserved.
    Go,
    /// The pacer refuses until absolute time `at`. When `arm` is true the
    /// caller must schedule a pace timer for `at` (the driver recorded it
    /// as outstanding; duplicates return `arm: false`).
    Pace { at: SimTime, arm: bool },
    /// Credit-gated scheme out of credit: stop pumping; a later
    /// `CreditGrant` re-pumps.
    NoCredit,
}

/// Per-endpoint congestion state owned by the authority. An endpoint is a
/// QP for packet engines and a bulk flow for the fluid engine — both key
/// by [`Qpn`].
struct EndpointCc {
    cc: Box<dyn CongestionControl>,
    pacer: Pacer,
    pace_armed: bool,
    grant_armed: bool,
}

/// The single rate-decision seam shared by the packet and fluid engines.
///
/// Owns per-endpoint CC instances keyed by [`Qpn`]; every rate question —
/// per-fragment admission (packet side, via [`CcDriver`]) or per-epoch
/// rate caps (fluid side) — resolves against the same state through the
/// same signal vocabulary.
pub struct RateAuthority {
    kind: CcKind,
    line_rate: f64,
    base_rtt: u64,
    /// Fabric path length (links, one way) — the `CcCtx::hops` fallback
    /// when feedback carries no stamped hop count.
    path_hops: u32,
    eps: BTreeMap<Qpn, EndpointCc>,
}

/// One endpoint's admission gate, resolved once per pump via
/// [`CcDriver::gate`]. Folds pacing, the software-datapath throughput
/// cap, and credit consumption into one verdict per fragment.
pub struct AdmitGate<'a> {
    q: &'a mut EndpointCc,
}

impl AdmitGate<'_> {
    /// Gate one fragment of `bytes`. `sw_cost` is the per-packet host
    /// cost of software datapaths (caps the effective rate). On `Go` the
    /// pacing horizon advances and credit is consumed.
    pub fn admit(
        &mut self,
        m: &mut Metrics,
        now: SimTime,
        bytes: usize,
        sw_cost: SimTime,
    ) -> Admit {
        let q = &mut *self.q;
        if q.pacer.next_tx > now {
            m.bump("cc_pacing_stalls");
            let arm = !q.pace_armed;
            q.pace_armed = true;
            return Admit::Pace {
                at: q.pacer.next_tx,
                arm,
            };
        }
        if !q.cc.try_send(bytes) {
            return Admit::NoCredit;
        }
        let rate = q.cc.rate();
        let eff_rate = if sw_cost > 0 {
            rate.min(bytes.max(1) as f64 / sw_cost as f64)
        } else {
            rate
        };
        q.pacer.reserve(now, bytes, eff_rate);
        Admit::Go
    }
}

impl RateAuthority {
    pub fn new(cfg: &TransportCfg) -> RateAuthority {
        RateAuthority {
            kind: cfg.cc,
            line_rate: cfg.link_bytes_per_ns,
            base_rtt: cfg.base_rtt_ns,
            path_hops: cfg.path_hops,
            eps: BTreeMap::new(),
        }
    }

    /// The algorithm this authority instantiates per endpoint.
    pub fn kind(&self) -> CcKind {
        self.kind
    }

    /// Install CC state for a new endpoint.
    pub fn register(&mut self, ep: Qpn) {
        self.eps.insert(
            ep,
            EndpointCc {
                cc: self.kind.build(self.line_rate, self.base_rtt),
                pacer: Pacer::new(),
                pace_armed: false,
                grant_armed: false,
            },
        );
    }

    /// Drop an endpoint's CC state (fluid flows finish; QPs rarely do).
    /// Live footprint tracks ACTIVE endpoints, not total ever created.
    pub fn unregister(&mut self, ep: Qpn) {
        self.eps.remove(&ep);
    }

    /// Number of live endpoints (memory accounting / tests).
    pub fn endpoints(&self) -> usize {
        self.eps.len()
    }

    fn ctx(&self, ep: Qpn, now: SimTime, bytes: usize) -> CcCtx {
        CcCtx {
            now,
            qpn: ep,
            bytes,
            hops: self.path_hops,
        }
    }

    // ---- feedback decomposition (sender side) -------------------------------

    /// Decompose one delivered-ACK's feedback into signals, in a fixed
    /// order (RTT → INT → mark → ack batch) so algorithm updates stay
    /// deterministic across transports AND across engine families (the
    /// fluid engine synthesizes the same `NetHints` shape from link state).
    pub fn on_ack(
        &mut self,
        m: &mut Metrics,
        ep: Qpn,
        now: SimTime,
        rtt_ns: Option<u64>,
        acked_bytes: usize,
        hints: &NetHints,
    ) {
        let line_rate = self.line_rate;
        // multi-hop telemetry: the stamped hop count (plus the host
        // uplink) and the BOTTLENECK link's rate ride the hints; un-
        // stamped feedback falls back to the fabric path / edge rate
        let hops = if hints.hops > 0 {
            hints.hops as u32 + 1
        } else {
            self.path_hops
        };
        let link_rate = if hints.link_mbps > 0 {
            hints.link_mbps as f64 / 8000.0 // Mbps → bytes/ns
        } else {
            line_rate
        };
        let Some(q) = self.eps.get_mut(&ep) else { return };
        let ctx = CcCtx {
            now,
            qpn: ep,
            bytes: acked_bytes,
            hops,
        };
        if let Some(rtt) = rtt_ns {
            m.bump("cc_rtt_samples");
            q.cc.on_signal(CcSignal::RttSample { rtt_ns: rtt }, &ctx);
        }
        q.cc.on_signal(
            CcSignal::IntTelemetry {
                qdepth: hints.qdepth,
                tx_bytes: hints.tx_bytes,
                link_rate,
            },
            &ctx,
        );
        if hints.ecn {
            q.cc.on_signal(CcSignal::EcnMark, &ctx);
        }
        q.cc.on_signal(
            CcSignal::AckBatch {
                acked_bytes,
                marked: hints.ecn,
            },
            &ctx,
        );
    }

    /// A standalone congestion-notification packet arrived. (Counted only
    /// when a registered endpoint actually processes it, matching
    /// `cc_rtt_samples` semantics.)
    pub fn on_cnp(&mut self, m: &mut Metrics, ep: Qpn, now: SimTime) {
        let ctx = self.ctx(ep, now, 0);
        if let Some(q) = self.eps.get_mut(&ep) {
            m.bump("cc_cnp_rx");
            q.cc.on_signal(CcSignal::EcnMark, &ctx);
        }
    }

    /// A credit grant arrived. (Counted only when a registered endpoint
    /// books it.)
    pub fn on_credit(&mut self, m: &mut Metrics, ep: Qpn, now: SimTime, bytes: usize) {
        let ctx = self.ctx(ep, now, bytes);
        if let Some(q) = self.eps.get_mut(&ep) {
            m.add("cc_credits_granted", bytes as u64);
            q.cc.on_signal(CcSignal::CreditGrant { bytes }, &ctx);
        }
    }

    /// A loss event: `timeout` for an RTO (severe), false for a NACK-grade
    /// gap hint (mild).
    pub fn on_loss(&mut self, ep: Qpn, now: SimTime, timeout: bool) {
        let ctx = self.ctx(ep, now, 0);
        if let Some(q) = self.eps.get_mut(&ep) {
            q.cc.on_signal(CcSignal::LossHint { timeout }, &ctx);
        }
    }

    // ---- fluid-engine queries (rate-cap consumer) ---------------------------

    /// The authoritative rate ceiling for an endpoint, bytes/ns:
    /// `min(rate(), cwnd() / base_rtt)`. Rate-based schemes report
    /// `cwnd = rate × base_rtt` so the min collapses to `rate()`;
    /// credit-based schemes (EQDS) are bounded by their credit balance
    /// spread over one RTT. Unknown endpoints are uncapped (`INFINITY`) —
    /// the fair-share solver's own cap still applies.
    pub fn rate_cap(&self, ep: Qpn) -> f64 {
        match self.eps.get(&ep) {
            Some(q) => {
                let win_rate = q.cc.cwnd() as f64 / self.base_rtt.max(1) as f64;
                q.cc.rate().min(win_rate)
            }
            None => f64::INFINITY,
        }
    }

    /// Charge `bytes` of solved fluid progress against the endpoint's
    /// credit, in `chunk`-sized fragments (mirrors the packet engine's
    /// per-fragment `try_send`, so credit-gated schemes burn credit at
    /// the same granularity in both engine families). Stops at the first
    /// refusal; rate-based schemes never refuse.
    pub fn consume(&mut self, ep: Qpn, bytes: usize, chunk: usize) {
        let Some(q) = self.eps.get_mut(&ep) else { return };
        let chunk = chunk.max(1);
        let mut left = bytes;
        while left > 0 {
            let frag = left.min(chunk);
            if !q.cc.try_send(frag) {
                break;
            }
            left -= frag;
        }
    }

    /// Sender side: announce `bytes` of new demand for an endpoint whose
    /// scheme is receiver-driven (no-op otherwise). The fluid engine calls
    /// this at flow arrival — the pull-request the packet engine would
    /// have sent on the wire.
    pub fn announce(&mut self, ep: Qpn, bytes: usize) {
        let Some(q) = self.eps.get_mut(&ep) else { return };
        if q.cc.announces_demand() {
            q.cc.on_demand(bytes);
        }
    }

    /// Epoch-cadence tick for engines without per-packet events (the
    /// fluid solver calls this once per endpoint per epoch). Two jobs:
    ///
    /// 1. `on_epoch` lets time-driven policy machinery (DBLP's idle-gap
    ///    phase detection) advance without waiting for a packet event.
    /// 2. Receiver-driven schemes run one epoch's worth of the credit
    ///    grant loop: grants of up to `chunk` bytes are issued at the
    ///    scheme's own pacing gaps until the epoch's time budget
    ///    (`base_rtt`) is spent or demand drains. Each grant feeds
    ///    straight back as a `CreditGrant` — in the fluid model the
    ///    receiver and sender endpoint are the same instance, so the
    ///    credit loop closes without wire round-trips (the propagation
    ///    delay is already inside the epoch cadence).
    pub fn epoch_tick(&mut self, m: &mut Metrics, ep: Qpn, now: SimTime, chunk: usize) {
        let path_hops = self.path_hops;
        let base_rtt = self.base_rtt.max(1);
        let Some(q) = self.eps.get_mut(&ep) else { return };
        let ctx = CcCtx {
            now,
            qpn: ep,
            bytes: 0,
            hops: path_hops,
        };
        q.cc.on_epoch(&ctx);
        if !q.cc.announces_demand() {
            return;
        }
        let mut budget = base_rtt;
        while q.cc.demand_pending() > 0 {
            let Some((bytes, gap)) = q.cc.next_grant(chunk) else {
                break;
            };
            m.add("cc_credits_granted", bytes as u64);
            q.cc.on_signal(CcSignal::CreditGrant { bytes }, &ctx);
            let gap = gap.max(1);
            if gap >= budget {
                break;
            }
            budget -= gap;
        }
    }

    // ---- pacing (sender side) -----------------------------------------------

    /// Charge the host doorbell cost (MMIO + WQE fetch) to the endpoint's
    /// pacing horizon; one charge per doorbell ring.
    pub fn charge_doorbell(&mut self, ep: Qpn, now: SimTime, cost: SimTime) {
        if let Some(q) = self.eps.get_mut(&ep) {
            q.pacer.next_tx = q.pacer.next_tx.max(now) + cost;
        }
    }

    /// Resolve one endpoint's admission gate. Engines call this ONCE per
    /// pump and then gate every fragment through [`AdmitGate::admit`] —
    /// the send loop must not pay a per-fragment map lookup on the hottest
    /// path (§Perf).
    pub fn gate(&mut self, ep: Qpn) -> Option<AdmitGate<'_>> {
        self.eps.get_mut(&ep).map(|q| AdmitGate { q })
    }

    /// One-shot convenience over [`RateAuthority::gate`] (tests, cold
    /// paths).
    pub fn admit(
        &mut self,
        m: &mut Metrics,
        ep: Qpn,
        now: SimTime,
        bytes: usize,
        sw_cost: SimTime,
    ) -> Admit {
        match self.gate(ep) {
            Some(mut g) => g.admit(m, now, bytes, sw_cost),
            None => Admit::NoCredit,
        }
    }

    /// The pace timer armed by an [`Admit::Pace`] verdict fired.
    pub fn pace_fired(&mut self, ep: Qpn) {
        if let Some(q) = self.eps.get_mut(&ep) {
            q.pace_armed = false;
        }
    }

    // ---- demand / credit grants (receiver-driven schemes) -------------------

    /// Sender side: should a pull request announcing new demand on this
    /// endpoint be sent to the peer?
    pub fn announces_demand(&self, ep: Qpn) -> bool {
        self.eps
            .get(&ep)
            .map(|q| q.cc.announces_demand())
            .unwrap_or(false)
    }

    /// Receiver side: the peer announced `bytes` of demand. Returns true
    /// when the caller should arm a grant timer now (the authority records
    /// it as outstanding).
    pub fn on_pull_req(&mut self, ep: Qpn, bytes: usize) -> bool {
        let Some(q) = self.eps.get_mut(&ep) else {
            return false;
        };
        q.cc.on_demand(bytes);
        if !q.grant_armed && q.cc.demand_pending() > 0 {
            q.grant_armed = true;
            true
        } else {
            false
        }
    }

    /// Receiver side: the grant timer fired. Returns the credit to grant
    /// (≤ `chunk` bytes) and, when more demand is pending, the pacing gap
    /// before the next tick (the caller re-arms; the authority tracks the
    /// armed flag either way).
    pub fn grant_fired(&mut self, ep: Qpn, chunk: usize) -> Option<(usize, Option<SimTime>)> {
        let q = self.eps.get_mut(&ep)?;
        q.grant_armed = false;
        let (bytes, gap) = q.cc.next_grant(chunk)?;
        let again = q.cc.demand_pending() > 0;
        if again {
            q.grant_armed = true;
        }
        Some((bytes, again.then_some(gap.max(1))))
    }

    /// Receiver side: `bytes` of data were delivered on this endpoint with
    /// `hints` telemetry. Drives receiver-side CC state (EQDS grant-rate
    /// AIMD) and answers whether a CNP should go back to the sender (the
    /// DCQCN notification-point policy — one code path for every scheme).
    pub fn on_delivery(&mut self, ep: Qpn, now: SimTime, bytes: usize, hints: &NetHints) -> bool {
        let ctx = self.ctx(ep, now, bytes);
        let Some(q) = self.eps.get_mut(&ep) else {
            return false;
        };
        q.cc.on_delivery(bytes, hints, &ctx);
        hints.ecn && q.cc.wants_cnp()
    }

    // ---- fault injection ----------------------------------------------------

    /// SEU model: zero the endpoint's pacing-horizon register (recovers
    /// through normal CC dynamics on subsequent feedback). Returns false
    /// for an unknown endpoint.
    pub fn corrupt_pacer(&mut self, ep: Qpn) -> bool {
        match self.eps.get_mut(&ep) {
            Some(q) => {
                q.pacer.next_tx = 0;
                true
            }
            None => false,
        }
    }
}

/// One packet-transport engine's handle on the CC plane: a thin
/// QP-flavored wrapper over [`RateAuthority`] that keeps the historical
/// per-QP method names. Packet engines own a `CcDriver`; the fluid engine
/// owns a bare `RateAuthority` — same state machine, same signal
/// vocabulary, different admission surface (per-fragment `admit()` vs
/// per-epoch `rate_cap()`).
pub struct CcDriver {
    ra: RateAuthority,
}

impl CcDriver {
    pub fn new(cfg: &TransportCfg) -> CcDriver {
        CcDriver {
            ra: RateAuthority::new(cfg),
        }
    }

    /// The algorithm this driver instantiates per QP.
    pub fn kind(&self) -> CcKind {
        self.ra.kind()
    }

    /// Install CC state for a new QP.
    pub fn register_qp(&mut self, qpn: Qpn) {
        self.ra.register(qpn);
    }

    /// The shared rate-decision seam (fluid consumers; tests).
    pub fn authority(&mut self) -> &mut RateAuthority {
        &mut self.ra
    }

    /// See [`RateAuthority::on_ack`].
    pub fn on_ack(
        &mut self,
        m: &mut Metrics,
        qpn: Qpn,
        now: SimTime,
        rtt_ns: Option<u64>,
        acked_bytes: usize,
        hints: &NetHints,
    ) {
        self.ra.on_ack(m, qpn, now, rtt_ns, acked_bytes, hints);
    }

    /// See [`RateAuthority::on_cnp`].
    pub fn on_cnp(&mut self, m: &mut Metrics, qpn: Qpn, now: SimTime) {
        self.ra.on_cnp(m, qpn, now);
    }

    /// See [`RateAuthority::on_credit`].
    pub fn on_credit(&mut self, m: &mut Metrics, qpn: Qpn, now: SimTime, bytes: usize) {
        self.ra.on_credit(m, qpn, now, bytes);
    }

    /// See [`RateAuthority::on_loss`].
    pub fn on_loss(&mut self, qpn: Qpn, now: SimTime, timeout: bool) {
        self.ra.on_loss(qpn, now, timeout);
    }

    /// See [`RateAuthority::charge_doorbell`].
    pub fn charge_doorbell(&mut self, qpn: Qpn, now: SimTime, cost: SimTime) {
        self.ra.charge_doorbell(qpn, now, cost);
    }

    /// See [`RateAuthority::gate`].
    pub fn gate(&mut self, qpn: Qpn) -> Option<AdmitGate<'_>> {
        self.ra.gate(qpn)
    }

    /// See [`RateAuthority::admit`].
    pub fn admit(
        &mut self,
        m: &mut Metrics,
        qpn: Qpn,
        now: SimTime,
        bytes: usize,
        sw_cost: SimTime,
    ) -> Admit {
        self.ra.admit(m, qpn, now, bytes, sw_cost)
    }

    /// See [`RateAuthority::pace_fired`].
    pub fn pace_fired(&mut self, qpn: Qpn) {
        self.ra.pace_fired(qpn);
    }

    /// See [`RateAuthority::announces_demand`].
    pub fn announces_demand(&self, qpn: Qpn) -> bool {
        self.ra.announces_demand(qpn)
    }

    /// See [`RateAuthority::on_pull_req`].
    pub fn on_pull_req(&mut self, qpn: Qpn, bytes: usize) -> bool {
        self.ra.on_pull_req(qpn, bytes)
    }

    /// See [`RateAuthority::grant_fired`].
    pub fn grant_fired(&mut self, qpn: Qpn, chunk: usize) -> Option<(usize, Option<SimTime>)> {
        self.ra.grant_fired(qpn, chunk)
    }

    /// See [`RateAuthority::on_delivery`].
    pub fn on_delivery(&mut self, qpn: Qpn, now: SimTime, bytes: usize, hints: &NetHints) -> bool {
        self.ra.on_delivery(qpn, now, bytes, hints)
    }

    /// See [`RateAuthority::corrupt_pacer`].
    pub fn corrupt_pacer(&mut self, qpn: Qpn) -> bool {
        self.ra.corrupt_pacer(qpn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FabricCfg;

    fn driver(kind: CcKind) -> CcDriver {
        let fab = FabricCfg::cloudlab(2);
        let mut cfg = TransportCfg::from_fabric(&fab);
        cfg.cc = kind;
        let mut d = CcDriver::new(&cfg);
        d.register_qp(7);
        d
    }

    fn authority(kind: CcKind) -> RateAuthority {
        let fab = FabricCfg::cloudlab(2);
        let cfg = TransportCfg::from_fabric(&fab).with_cc(kind);
        let mut ra = RateAuthority::new(&cfg);
        ra.register(7);
        ra
    }

    #[test]
    fn admit_paces_at_current_rate() {
        let mut d = driver(CcKind::None);
        let mut m = Metrics::new();
        assert_eq!(d.admit(&mut m, 7, 0, 3125, 0), Admit::Go);
        // line rate 3.125 B/ns ⇒ 3125 bytes occupy 1000 ns
        match d.admit(&mut m, 7, 0, 3125, 0) {
            Admit::Pace { at, arm } => {
                assert_eq!(at, 1000);
                assert!(arm, "first stall must arm the pace timer");
            }
            other => panic!("expected Pace, got {other:?}"),
        }
        // second stall: timer already armed
        match d.admit(&mut m, 7, 0, 3125, 0) {
            Admit::Pace { arm, .. } => assert!(!arm),
            other => panic!("expected Pace, got {other:?}"),
        }
        assert_eq!(m.counter("cc_pacing_stalls"), 2);
        d.pace_fired(7);
        assert_eq!(d.admit(&mut m, 7, 1000, 3125, 0), Admit::Go);
    }

    #[test]
    fn unknown_qp_is_refused() {
        let mut d = driver(CcKind::Dcqcn);
        let mut m = Metrics::new();
        assert_eq!(d.admit(&mut m, 99, 0, 100, 0), Admit::NoCredit);
        assert!(!d.on_pull_req(99, 100));
        assert!(d.grant_fired(99, 100).is_none());
    }

    #[test]
    fn doorbell_charge_delays_transmission() {
        let mut d = driver(CcKind::None);
        let mut m = Metrics::new();
        d.charge_doorbell(7, 0, 100);
        match d.admit(&mut m, 7, 0, 64, 0) {
            Admit::Pace { at, .. } => assert_eq!(at, 100),
            other => panic!("expected Pace, got {other:?}"),
        }
    }

    #[test]
    fn eqds_demand_and_grant_cycle() {
        let mut d = driver(CcKind::Eqds);
        let mut m = Metrics::new();
        assert!(d.announces_demand(7));
        // first demand arms the grant timer; more demand does not re-arm
        assert!(d.on_pull_req(7, 10_000));
        assert!(!d.on_pull_req(7, 2_000));
        let mut granted = 0;
        let mut ticks = 0;
        loop {
            ticks += 1;
            let (bytes, next) = d.grant_fired(7, 4_000).expect("grant");
            granted += bytes;
            if next.is_none() {
                break;
            }
            assert!(next.unwrap() >= 1, "grant pacing gap must be positive");
            assert!(ticks < 100, "grant loop did not drain");
        }
        assert_eq!(granted, 12_000, "grants must cover announced demand");
        // drained: nothing more to grant until new demand arrives
        assert!(d.grant_fired(7, 4_000).is_none());
        assert!(d.on_pull_req(7, 500), "new demand re-arms");
        // the sender side books received credits
        d.on_credit(&mut m, 7, 0, 4_000);
        assert_eq!(m.counter("cc_credits_granted"), 4_000);
    }

    #[test]
    fn cnp_policy_is_dcqcn_only() {
        let hints_marked = NetHints {
            qdepth: 1000,
            ecn: true,
            ..NetHints::default()
        };
        for kind in CcKind::ALL {
            let mut d = driver(kind);
            let wants = d.on_delivery(7, 0, 1500, &hints_marked);
            assert_eq!(
                wants,
                kind == CcKind::Dcqcn,
                "{kind:?}: CNP policy must come from the algorithm"
            );
        }
        // unmarked delivery never produces a CNP
        let mut d = driver(CcKind::Dcqcn);
        assert!(!d.on_delivery(7, 0, 1500, &NetHints::default()));
    }

    /// Multi-hop telemetry: HPCC must see the BOTTLENECK link's rate (a
    /// slow leaf–host edge behind fast spines), not blindly the sender's
    /// line rate — utilization normalizes against the wrong BDP otherwise.
    #[test]
    fn on_ack_feeds_bottleneck_link_rate_to_int() {
        let fab = FabricCfg::cloudlab(2);
        let mut cfg = TransportCfg::from_fabric(&fab);
        cfg.cc = CcKind::Hpcc;
        let mut d = CcDriver::new(&cfg);
        d.register_qp(7);
        let mut m = Metrics::new();
        // bottleneck stamped at 10 Gbps (1.25 B/ns) with a deep queue;
        // walk the INT counter at that slower rate: HPCC should read the
        // stamped rate and see U ≈ 1 → back off well below line rate
        let step = 10_000u64;
        let mut tx = 0u64;
        for i in 1..200u64 {
            tx += (step as f64 * 1.25) as u64;
            let hints = NetHints {
                qdepth: 40_000,
                ecn: false,
                tx_bytes: tx,
                link_mbps: 10_000,
                hops: 3,
            };
            d.on_ack(&mut m, 7, i * step, None, 1500, &hints);
        }
        let rate = d.ra.eps.get(&7).unwrap().cc.rate();
        assert!(
            rate < 0.8 * cfg.link_bytes_per_ns,
            "saturated 10 G bottleneck must pull HPCC below the 25 G line: {rate}"
        );
        // and the same backoff is visible through the seam's rate_cap
        assert!(d.ra.rate_cap(7) < 0.8 * cfg.link_bytes_per_ns);
    }

    /// Unstamped feedback (hops = 0) falls back to the fabric's path
    /// length and the edge line rate — and a stamped hop count reaches
    /// the algorithm as links traversed (stamps + host uplink).
    #[test]
    fn hops_prefer_stamped_count_with_path_fallback() {
        let fab = FabricCfg::cloudlab(2).with_leaf_spine(1, 1);
        let cfg = TransportCfg::from_fabric(&fab);
        assert_eq!(cfg.path_hops, 4);
        let d = CcDriver::new(&cfg);
        assert_eq!(d.ra.ctx(7, 0, 0).hops, 4);
        // single-switch keeps the seed value
        let cfg1 = TransportCfg::from_fabric(&FabricCfg::cloudlab(2));
        assert_eq!(CcDriver::new(&cfg1).ra.ctx(7, 0, 0).hops, 2);
        // fat-tree worst case is the 6-link cross-pod path — HPCC's
        // per-hop normalization must budget for all of them when the ACK
        // carries no stamped count
        let ft = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
        let cfg2 = TransportCfg::from_fabric(&ft);
        assert_eq!(cfg2.path_hops, 6);
        assert!(cfg2.multipath, "fat-tree must enable spraying");
        assert_eq!(CcDriver::new(&cfg2).ra.ctx(7, 0, 0).hops, 6);
    }

    #[test]
    fn counters_flow_through_metrics() {
        let mut d = driver(CcKind::Swift);
        let mut m = Metrics::new();
        d.on_ack(&mut m, 7, 1_000, Some(5_000), 1500, &NetHints::default());
        d.on_ack(&mut m, 7, 2_000, None, 1500, &NetHints::default());
        d.on_cnp(&mut m, 7, 3_000);
        assert_eq!(m.counter("cc_rtt_samples"), 1);
        assert_eq!(m.counter("cc_cnp_rx"), 1);
        let j = m.to_json();
        assert!(
            j.get("counters").unwrap().get("cc_rtt_samples").is_some(),
            "cc counters must surface in Metrics::to_json"
        );
    }

    /// `rate_cap` is the uniform fluid-side ceiling: rate-based schemes
    /// collapse to `rate()` (cwnd = rate × base_rtt), credit-based EQDS is
    /// bounded by its credit balance over one RTT, and unknown endpoints
    /// are uncapped so the fair-share solver stays in charge.
    #[test]
    fn rate_cap_is_min_of_rate_and_window() {
        for kind in CcKind::ALL {
            let ra = authority(kind);
            let cap = ra.rate_cap(7);
            assert!(
                cap.is_finite() && cap > 0.0,
                "{kind:?}: fresh endpoint must have a finite positive cap, got {cap}"
            );
        }
        let ra = authority(CcKind::Dcqcn);
        assert_eq!(ra.rate_cap(999), f64::INFINITY, "unknown ep is uncapped");
        // consuming EQDS credit pulls the window term below rate()
        let mut ra = authority(CcKind::Eqds);
        let fresh = ra.rate_cap(7);
        ra.consume(7, 1 << 20, 4096);
        assert!(
            ra.rate_cap(7) < fresh,
            "burning credit must shrink EQDS's windowed cap"
        );
    }

    /// Unregister drops live state: the fluid engine registers an endpoint
    /// per bulk flow and must not leak instances across millions of flows.
    #[test]
    fn unregister_drops_endpoint_state() {
        let mut ra = authority(CcKind::Dcqcn);
        assert_eq!(ra.endpoints(), 1);
        ra.unregister(7);
        assert_eq!(ra.endpoints(), 0);
        assert_eq!(ra.rate_cap(7), f64::INFINITY);
    }

    /// Satellite 6 (no-deadlock pin): a credit-starved EQDS endpoint with
    /// no packet events must be refilled by `epoch_tick` — the explicit
    /// epoch-cadence entry for the receiver-side grant loop — so a fluid
    /// flow can never stall forever waiting for credit that only
    /// per-packet machinery would have granted.
    #[test]
    fn epoch_tick_refills_credit_starved_eqds() {
        let mut ra = authority(CcKind::Eqds);
        let mut m = Metrics::new();
        // announce a big flow, then burn all initial + speculative credit
        ra.announce(7, 1 << 20);
        ra.consume(7, 1 << 20, 4096);
        assert_eq!(
            ra.admit(&mut m, 7, u64::MAX >> 1, 4096, 0),
            Admit::NoCredit,
            "setup: endpoint must actually be credit-starved"
        );
        let starved_cap = ra.rate_cap(7);
        // epoch ticks stand in for the per-packet grant timer: each one
        // runs the receiver grant loop for one epoch's budget
        let mut refilled = false;
        for tick in 1..=64u64 {
            ra.epoch_tick(&mut m, 7, tick * 5_000, 4096);
            if ra.rate_cap(7) > starved_cap {
                refilled = true;
                break;
            }
        }
        assert!(refilled, "epoch ticks must refill a credit-starved EQDS endpoint");
        assert!(
            m.counter("cc_credits_granted") > 0,
            "grants must be booked through the shared counter"
        );
    }

    /// `epoch_tick` respects the scheme's own grant pacing: one epoch
    /// grants roughly grant_rate × base_rtt bytes, not the whole backlog.
    #[test]
    fn epoch_tick_grants_are_pacing_bounded() {
        let mut ra = authority(CcKind::Eqds);
        let mut m = Metrics::new();
        ra.announce(7, 100 << 20); // 100 MB backlog
        ra.epoch_tick(&mut m, 7, 5_000, 4096);
        let granted = m.counter("cc_credits_granted");
        assert!(granted > 0, "one tick must grant something");
        // grant rate ≤ line rate ⇒ one base_rtt grants ≤ line_rate × rtt
        // (3.125 B/ns × 5000 ns ≈ 15.6 KB) plus one chunk of slack
        let bdp = (3.125 * 5_000.0) as u64;
        assert!(
            granted <= bdp + 4096,
            "one epoch must not grant more than ~one BDP: {granted} > {bdp}"
        );
        // rate-based schemes: epoch_tick is signal-free and must not move
        // the rate
        let mut ra2 = authority(CcKind::Dcqcn);
        let before = ra2.rate_cap(7);
        ra2.epoch_tick(&mut m, 7, 5_000, 4096);
        assert_eq!(ra2.rate_cap(7), before);
    }
}
