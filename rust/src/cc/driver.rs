//! The congestion-control driver: the one object a transport engine owns
//! to talk to the CC plane (CC v2).
//!
//! The driver owns per-QP [`CongestionControl`] instances plus the pacing
//! state that used to be scattered across transport QP structs (pacer
//! horizon, pace-timer armed flag, grant-timer armed flag). Transports:
//!
//! * decompose raw feedback through [`CcDriver::on_ack`] /
//!   [`CcDriver::on_cnp`] / [`CcDriver::on_credit`] / [`CcDriver::on_loss`]
//!   — the ONLY place transport wire formats meet [`CcSignal`]s;
//! * gate every fragment through an [`AdmitGate`] (resolved once per
//!   pump via [`CcDriver::gate`]), which folds pacing, software-datapath
//!   throughput caps, and credit consumption into one verdict;
//! * run the receiver-side credit-grant loop through
//!   [`CcDriver::on_pull_req`] / [`CcDriver::grant_fired`] — the machinery
//!   that used to be hard-coded for EQDS inside `transport/optinic.rs`;
//! * ask [`CcDriver::on_delivery`] whether a CE-marked delivery should
//!   produce a CNP (the DCQCN notification-point policy, behind the trait).
//!
//! The driver never touches the event queue: it records which logical
//! timers are outstanding and tells the caller when to arm one (the
//! transport owns timer ids and the PR-2 lazy-cancellation machinery).
//!
//! Exported counters (PR-2 `&'static str` key scheme, surfaced through
//! `Metrics::to_json`): `cc_cnp_rx`, `cc_rtt_samples`, `cc_credits_granted`,
//! `cc_pacing_stalls`.

use std::collections::BTreeMap;

use crate::cc::{CcCtx, CcKind, CcSignal, CongestionControl};
use crate::net::NetHints;
use crate::sim::{Metrics, SimTime};
use crate::transport::{Pacer, TransportCfg};
use crate::verbs::Qpn;

// (The fixed TOR_HOPS constant died with the single-switch assumption:
// the driver now carries the fabric's path length and prefers the hop
// count actually stamped into the feedback's NetHints.)

/// Verdict for one fragment offered to [`CcDriver::admit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Transmit now; the pacing horizon and any credit were reserved.
    Go,
    /// The pacer refuses until absolute time `at`. When `arm` is true the
    /// caller must schedule a pace timer for `at` (the driver recorded it
    /// as outstanding; duplicates return `arm: false`).
    Pace { at: SimTime, arm: bool },
    /// Credit-gated scheme out of credit: stop pumping; a later
    /// `CreditGrant` re-pumps.
    NoCredit,
}

/// Per-QP congestion state owned by the driver.
struct QpCc {
    cc: Box<dyn CongestionControl>,
    pacer: Pacer,
    pace_armed: bool,
    grant_armed: bool,
}

/// One transport engine's handle on the CC plane.
pub struct CcDriver {
    kind: CcKind,
    line_rate: f64,
    base_rtt: u64,
    /// Fabric path length (links, one way) — the `CcCtx::hops` fallback
    /// when feedback carries no stamped hop count.
    path_hops: u32,
    qps: BTreeMap<Qpn, QpCc>,
}

/// One QP's admission gate, resolved once per pump via
/// [`CcDriver::gate`]. Folds pacing, the software-datapath throughput
/// cap, and credit consumption into one verdict per fragment.
pub struct AdmitGate<'a> {
    q: &'a mut QpCc,
}

impl AdmitGate<'_> {
    /// Gate one fragment of `bytes`. `sw_cost` is the per-packet host
    /// cost of software datapaths (caps the effective rate). On `Go` the
    /// pacing horizon advances and credit is consumed.
    pub fn admit(
        &mut self,
        m: &mut Metrics,
        now: SimTime,
        bytes: usize,
        sw_cost: SimTime,
    ) -> Admit {
        let q = &mut *self.q;
        if q.pacer.next_tx > now {
            m.bump("cc_pacing_stalls");
            let arm = !q.pace_armed;
            q.pace_armed = true;
            return Admit::Pace {
                at: q.pacer.next_tx,
                arm,
            };
        }
        if !q.cc.try_send(bytes) {
            return Admit::NoCredit;
        }
        let rate = q.cc.rate();
        let eff_rate = if sw_cost > 0 {
            rate.min(bytes.max(1) as f64 / sw_cost as f64)
        } else {
            rate
        };
        q.pacer.reserve(now, bytes, eff_rate);
        Admit::Go
    }
}

impl CcDriver {
    pub fn new(cfg: &TransportCfg) -> CcDriver {
        CcDriver {
            kind: cfg.cc,
            line_rate: cfg.link_bytes_per_ns,
            base_rtt: cfg.base_rtt_ns,
            path_hops: cfg.path_hops,
            qps: BTreeMap::new(),
        }
    }

    /// The algorithm this driver instantiates per QP.
    pub fn kind(&self) -> CcKind {
        self.kind
    }

    /// Install CC state for a new QP.
    pub fn register_qp(&mut self, qpn: Qpn) {
        self.qps.insert(
            qpn,
            QpCc {
                cc: self.kind.build(self.line_rate, self.base_rtt),
                pacer: Pacer::new(),
                pace_armed: false,
                grant_armed: false,
            },
        );
    }

    fn ctx(&self, qpn: Qpn, now: SimTime, bytes: usize) -> CcCtx {
        CcCtx {
            now,
            qpn,
            bytes,
            hops: self.path_hops,
        }
    }

    // ---- feedback decomposition (sender side) -------------------------------

    /// Decompose one delivered-ACK's feedback into signals, in a fixed
    /// order (RTT → INT → mark → ack batch) so algorithm updates stay
    /// deterministic across transports.
    pub fn on_ack(
        &mut self,
        m: &mut Metrics,
        qpn: Qpn,
        now: SimTime,
        rtt_ns: Option<u64>,
        acked_bytes: usize,
        hints: &NetHints,
    ) {
        let line_rate = self.line_rate;
        // multi-hop telemetry: the stamped hop count (plus the host
        // uplink) and the BOTTLENECK link's rate ride the hints; un-
        // stamped feedback falls back to the fabric path / edge rate
        let hops = if hints.hops > 0 {
            hints.hops as u32 + 1
        } else {
            self.path_hops
        };
        let link_rate = if hints.link_mbps > 0 {
            hints.link_mbps as f64 / 8000.0 // Mbps → bytes/ns
        } else {
            line_rate
        };
        let Some(q) = self.qps.get_mut(&qpn) else { return };
        let ctx = CcCtx {
            now,
            qpn,
            bytes: acked_bytes,
            hops,
        };
        if let Some(rtt) = rtt_ns {
            m.bump("cc_rtt_samples");
            q.cc.on_signal(CcSignal::RttSample { rtt_ns: rtt }, &ctx);
        }
        q.cc.on_signal(
            CcSignal::IntTelemetry {
                qdepth: hints.qdepth,
                tx_bytes: hints.tx_bytes,
                link_rate,
            },
            &ctx,
        );
        if hints.ecn {
            q.cc.on_signal(CcSignal::EcnMark, &ctx);
        }
        q.cc.on_signal(
            CcSignal::AckBatch {
                acked_bytes,
                marked: hints.ecn,
            },
            &ctx,
        );
    }

    /// A standalone congestion-notification packet arrived. (Counted only
    /// when a registered QP actually processes it, matching
    /// `cc_rtt_samples` semantics.)
    pub fn on_cnp(&mut self, m: &mut Metrics, qpn: Qpn, now: SimTime) {
        let ctx = self.ctx(qpn, now, 0);
        if let Some(q) = self.qps.get_mut(&qpn) {
            m.bump("cc_cnp_rx");
            q.cc.on_signal(CcSignal::EcnMark, &ctx);
        }
    }

    /// A credit grant arrived. (Counted only when a registered QP books it.)
    pub fn on_credit(&mut self, m: &mut Metrics, qpn: Qpn, now: SimTime, bytes: usize) {
        let ctx = self.ctx(qpn, now, bytes);
        if let Some(q) = self.qps.get_mut(&qpn) {
            m.add("cc_credits_granted", bytes as u64);
            q.cc.on_signal(CcSignal::CreditGrant { bytes }, &ctx);
        }
    }

    /// A loss event: `timeout` for an RTO (severe), false for a NACK-grade
    /// gap hint (mild).
    pub fn on_loss(&mut self, qpn: Qpn, now: SimTime, timeout: bool) {
        let ctx = self.ctx(qpn, now, 0);
        if let Some(q) = self.qps.get_mut(&qpn) {
            q.cc.on_signal(CcSignal::LossHint { timeout }, &ctx);
        }
    }

    // ---- pacing (sender side) -----------------------------------------------

    /// Charge the host doorbell cost (MMIO + WQE fetch) to the QP's
    /// pacing horizon; one charge per doorbell ring.
    pub fn charge_doorbell(&mut self, qpn: Qpn, now: SimTime, cost: SimTime) {
        if let Some(q) = self.qps.get_mut(&qpn) {
            q.pacer.next_tx = q.pacer.next_tx.max(now) + cost;
        }
    }

    /// Resolve one QP's admission gate. Engines call this ONCE per pump
    /// and then gate every fragment through [`AdmitGate::admit`] — the
    /// send loop must not pay a per-fragment QP-map lookup on the hottest
    /// path (§Perf).
    pub fn gate(&mut self, qpn: Qpn) -> Option<AdmitGate<'_>> {
        self.qps.get_mut(&qpn).map(|q| AdmitGate { q })
    }

    /// One-shot convenience over [`CcDriver::gate`] (tests, cold paths).
    pub fn admit(
        &mut self,
        m: &mut Metrics,
        qpn: Qpn,
        now: SimTime,
        bytes: usize,
        sw_cost: SimTime,
    ) -> Admit {
        match self.gate(qpn) {
            Some(mut g) => g.admit(m, now, bytes, sw_cost),
            None => Admit::NoCredit,
        }
    }

    /// The pace timer armed by an [`Admit::Pace`] verdict fired.
    pub fn pace_fired(&mut self, qpn: Qpn) {
        if let Some(q) = self.qps.get_mut(&qpn) {
            q.pace_armed = false;
        }
    }

    // ---- demand / credit grants (receiver-driven schemes) -------------------

    /// Sender side: should a pull request announcing new demand on this QP
    /// be sent to the peer?
    pub fn announces_demand(&self, qpn: Qpn) -> bool {
        self.qps
            .get(&qpn)
            .map(|q| q.cc.announces_demand())
            .unwrap_or(false)
    }

    /// Receiver side: the peer announced `bytes` of demand. Returns true
    /// when the caller should arm a grant timer now (the driver records it
    /// as outstanding).
    pub fn on_pull_req(&mut self, qpn: Qpn, bytes: usize) -> bool {
        let Some(q) = self.qps.get_mut(&qpn) else {
            return false;
        };
        q.cc.on_demand(bytes);
        if !q.grant_armed && q.cc.demand_pending() > 0 {
            q.grant_armed = true;
            true
        } else {
            false
        }
    }

    /// Receiver side: the grant timer fired. Returns the credit to grant
    /// (≤ `chunk` bytes) and, when more demand is pending, the pacing gap
    /// before the next tick (the caller re-arms; the driver tracks the
    /// armed flag either way).
    pub fn grant_fired(&mut self, qpn: Qpn, chunk: usize) -> Option<(usize, Option<SimTime>)> {
        let q = self.qps.get_mut(&qpn)?;
        q.grant_armed = false;
        let (bytes, gap) = q.cc.next_grant(chunk)?;
        let again = q.cc.demand_pending() > 0;
        if again {
            q.grant_armed = true;
        }
        Some((bytes, again.then_some(gap.max(1))))
    }

    /// Receiver side: `bytes` of data were delivered on this QP with
    /// `hints` telemetry. Drives receiver-side CC state (EQDS grant-rate
    /// AIMD) and answers whether a CNP should go back to the sender (the
    /// DCQCN notification-point policy — one code path for every scheme).
    pub fn on_delivery(&mut self, qpn: Qpn, now: SimTime, bytes: usize, hints: &NetHints) -> bool {
        let ctx = self.ctx(qpn, now, bytes);
        let Some(q) = self.qps.get_mut(&qpn) else {
            return false;
        };
        q.cc.on_delivery(bytes, hints, &ctx);
        hints.ecn && q.cc.wants_cnp()
    }

    // ---- fault injection ----------------------------------------------------

    /// SEU model: zero the QP's pacing-horizon register (recovers through
    /// normal CC dynamics on subsequent feedback). Returns false for an
    /// unknown QP.
    pub fn corrupt_pacer(&mut self, qpn: Qpn) -> bool {
        match self.qps.get_mut(&qpn) {
            Some(q) => {
                q.pacer.next_tx = 0;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FabricCfg;

    fn driver(kind: CcKind) -> CcDriver {
        let fab = FabricCfg::cloudlab(2);
        let mut cfg = TransportCfg::from_fabric(&fab);
        cfg.cc = kind;
        let mut d = CcDriver::new(&cfg);
        d.register_qp(7);
        d
    }

    #[test]
    fn admit_paces_at_current_rate() {
        let mut d = driver(CcKind::None);
        let mut m = Metrics::new();
        assert_eq!(d.admit(&mut m, 7, 0, 3125, 0), Admit::Go);
        // line rate 3.125 B/ns ⇒ 3125 bytes occupy 1000 ns
        match d.admit(&mut m, 7, 0, 3125, 0) {
            Admit::Pace { at, arm } => {
                assert_eq!(at, 1000);
                assert!(arm, "first stall must arm the pace timer");
            }
            other => panic!("expected Pace, got {other:?}"),
        }
        // second stall: timer already armed
        match d.admit(&mut m, 7, 0, 3125, 0) {
            Admit::Pace { arm, .. } => assert!(!arm),
            other => panic!("expected Pace, got {other:?}"),
        }
        assert_eq!(m.counter("cc_pacing_stalls"), 2);
        d.pace_fired(7);
        assert_eq!(d.admit(&mut m, 7, 1000, 3125, 0), Admit::Go);
    }

    #[test]
    fn unknown_qp_is_refused() {
        let mut d = driver(CcKind::Dcqcn);
        let mut m = Metrics::new();
        assert_eq!(d.admit(&mut m, 99, 0, 100, 0), Admit::NoCredit);
        assert!(!d.on_pull_req(99, 100));
        assert!(d.grant_fired(99, 100).is_none());
    }

    #[test]
    fn doorbell_charge_delays_transmission() {
        let mut d = driver(CcKind::None);
        let mut m = Metrics::new();
        d.charge_doorbell(7, 0, 100);
        match d.admit(&mut m, 7, 0, 64, 0) {
            Admit::Pace { at, .. } => assert_eq!(at, 100),
            other => panic!("expected Pace, got {other:?}"),
        }
    }

    #[test]
    fn eqds_demand_and_grant_cycle() {
        let mut d = driver(CcKind::Eqds);
        let mut m = Metrics::new();
        assert!(d.announces_demand(7));
        // first demand arms the grant timer; more demand does not re-arm
        assert!(d.on_pull_req(7, 10_000));
        assert!(!d.on_pull_req(7, 2_000));
        let mut granted = 0;
        let mut ticks = 0;
        loop {
            ticks += 1;
            let (bytes, next) = d.grant_fired(7, 4_000).expect("grant");
            granted += bytes;
            if next.is_none() {
                break;
            }
            assert!(next.unwrap() >= 1, "grant pacing gap must be positive");
            assert!(ticks < 100, "grant loop did not drain");
        }
        assert_eq!(granted, 12_000, "grants must cover announced demand");
        // drained: nothing more to grant until new demand arrives
        assert!(d.grant_fired(7, 4_000).is_none());
        assert!(d.on_pull_req(7, 500), "new demand re-arms");
        // the sender side books received credits
        d.on_credit(&mut m, 7, 0, 4_000);
        assert_eq!(m.counter("cc_credits_granted"), 4_000);
    }

    #[test]
    fn cnp_policy_is_dcqcn_only() {
        let hints_marked = NetHints {
            qdepth: 1000,
            ecn: true,
            ..NetHints::default()
        };
        for kind in CcKind::ALL {
            let mut d = driver(kind);
            let wants = d.on_delivery(7, 0, 1500, &hints_marked);
            assert_eq!(
                wants,
                kind == CcKind::Dcqcn,
                "{kind:?}: CNP policy must come from the algorithm"
            );
        }
        // unmarked delivery never produces a CNP
        let mut d = driver(CcKind::Dcqcn);
        assert!(!d.on_delivery(7, 0, 1500, &NetHints::default()));
    }

    /// Multi-hop telemetry: HPCC must see the BOTTLENECK link's rate (a
    /// slow leaf–host edge behind fast spines), not blindly the sender's
    /// line rate — utilization normalizes against the wrong BDP otherwise.
    #[test]
    fn on_ack_feeds_bottleneck_link_rate_to_int() {
        let fab = FabricCfg::cloudlab(2);
        let mut cfg = TransportCfg::from_fabric(&fab);
        cfg.cc = CcKind::Hpcc;
        let mut d = CcDriver::new(&cfg);
        d.register_qp(7);
        let mut m = Metrics::new();
        // bottleneck stamped at 10 Gbps (1.25 B/ns) with a deep queue;
        // walk the INT counter at that slower rate: HPCC should read the
        // stamped rate and see U ≈ 1 → back off well below line rate
        let step = 10_000u64;
        let mut tx = 0u64;
        for i in 1..200u64 {
            tx += (step as f64 * 1.25) as u64;
            let hints = NetHints {
                qdepth: 40_000,
                ecn: false,
                tx_bytes: tx,
                link_mbps: 10_000,
                hops: 3,
            };
            d.on_ack(&mut m, 7, i * step, None, 1500, &hints);
        }
        let rate = d.qps.get(&7).unwrap().cc.rate();
        assert!(
            rate < 0.8 * cfg.link_bytes_per_ns,
            "saturated 10 G bottleneck must pull HPCC below the 25 G line: {rate}"
        );
    }

    /// Unstamped feedback (hops = 0) falls back to the fabric's path
    /// length and the edge line rate — and a stamped hop count reaches
    /// the algorithm as links traversed (stamps + host uplink).
    #[test]
    fn hops_prefer_stamped_count_with_path_fallback() {
        let fab = FabricCfg::cloudlab(2).with_leaf_spine(1, 1);
        let cfg = TransportCfg::from_fabric(&fab);
        assert_eq!(cfg.path_hops, 4);
        let d = CcDriver::new(&cfg);
        assert_eq!(d.ctx(7, 0, 0).hops, 4);
        // single-switch keeps the seed value
        let cfg1 = TransportCfg::from_fabric(&FabricCfg::cloudlab(2));
        assert_eq!(CcDriver::new(&cfg1).ctx(7, 0, 0).hops, 2);
        // fat-tree worst case is the 6-link cross-pod path — HPCC's
        // per-hop normalization must budget for all of them when the ACK
        // carries no stamped count
        let ft = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
        let cfg2 = TransportCfg::from_fabric(&ft);
        assert_eq!(cfg2.path_hops, 6);
        assert!(cfg2.multipath, "fat-tree must enable spraying");
        assert_eq!(CcDriver::new(&cfg2).ctx(7, 0, 0).hops, 6);
    }

    #[test]
    fn counters_flow_through_metrics() {
        let mut d = driver(CcKind::Swift);
        let mut m = Metrics::new();
        d.on_ack(&mut m, 7, 1_000, Some(5_000), 1500, &NetHints::default());
        d.on_ack(&mut m, 7, 2_000, None, 1500, &NetHints::default());
        d.on_cnp(&mut m, 7, 3_000);
        assert_eq!(m.counter("cc_rtt_samples"), 1);
        assert_eq!(m.counter("cc_cnp_rx"), 1);
        let j = m.to_json();
        assert!(
            j.get("counters").unwrap().get("cc_rtt_samples").is_some(),
            "cc counters must surface in Metrics::to_json"
        );
    }
}
