//! DCQCN (Zhu et al., SIGCOMM'15): ECN-mark driven rate control.
//!
//! Receiver turns CE marks into CNPs; the sender's reaction point cuts rate
//! multiplicatively on CNP and recovers through fast-recovery then
//! additive/hyper increase stages. We implement the byte-counter variant:
//! increase stages advance as acknowledged bytes accumulate, which avoids
//! extra timers on the DES hot path while preserving the control law.

use crate::cc::{AckFeedback, CongestionControl};
use crate::sim::SimTime;

#[derive(Debug)]
pub struct Dcqcn {
    line_rate: f64,
    /// Current rate RC, bytes/ns.
    rc: f64,
    /// Target rate RT.
    rt: f64,
    /// Rate-reduction factor α.
    alpha: f64,
    /// g parameter for α update.
    g: f64,
    /// Byte counter toward the next increase stage.
    byte_counter: usize,
    /// Bytes per increase stage.
    byte_counter_threshold: usize,
    /// Consecutive increase stages since last CNP.
    stage: u32,
    /// Additive increase step, bytes/ns.
    rai: f64,
    /// Last CNP time (rate cuts are clocked at ≥ one per 50 µs like the
    /// NP-side CNP pacing in deployments).
    last_cut: SimTime,
    min_cnp_gap: SimTime,
    /// Timer-based recovery clock (the spec's T = 55 µs stage timer) —
    /// without it a sender cut to the floor can never climb back, because
    /// the byte counter barely advances at low rate.
    last_stage_time: SimTime,
    stage_period: SimTime,
}

impl Dcqcn {
    pub fn new(line_rate: f64) -> Dcqcn {
        Dcqcn {
            line_rate,
            rc: line_rate,
            rt: line_rate,
            alpha: 1.0,
            g: 1.0 / 16.0,
            byte_counter: 0,
            byte_counter_threshold: 64 * 1024,
            stage: 0,
            rai: line_rate / 25.0, // ~4% of line rate per additive step
            last_cut: 0,
            min_cnp_gap: 50_000,
            last_stage_time: 0,
            stage_period: 55_000,
        }
    }

    fn advance_stage(&mut self) {
        self.stage += 1;
        if self.stage <= 5 {
            // fast recovery: move halfway back to target
            self.rc = (self.rc + self.rt) / 2.0;
        } else {
            // additive increase: raise target, then close half the gap
            self.rt = (self.rt + self.rai).min(self.line_rate);
            self.rc = (self.rc + self.rt) / 2.0;
        }
        self.rc = self.rc.min(self.line_rate);
    }
}

impl CongestionControl for Dcqcn {
    fn name(&self) -> &'static str {
        "DCQCN"
    }

    fn rate(&self) -> f64 {
        self.rc
    }

    fn on_ack(&mut self, fb: AckFeedback) {
        if fb.ecn_echo {
            // receiver piggybacked congestion notification
            self.on_cnp(fb.now);
            return;
        }
        // α decays when no marks arrive
        self.alpha *= 1.0 - self.g;
        // byte-counter stages
        self.byte_counter += fb.acked_bytes;
        while self.byte_counter >= self.byte_counter_threshold {
            self.byte_counter -= self.byte_counter_threshold;
            self.advance_stage();
        }
        // timer-based stages (bounded catch-up)
        if self.last_stage_time == 0 {
            self.last_stage_time = fb.now;
        }
        let mut guard = 0;
        while fb.now.saturating_sub(self.last_stage_time) >= self.stage_period
            && guard < 64
        {
            self.last_stage_time += self.stage_period;
            self.advance_stage();
            guard += 1;
        }
        if guard == 64 {
            self.last_stage_time = fb.now; // long idle gap: resync
        }
    }

    fn on_cnp(&mut self, now: SimTime) {
        if now.saturating_sub(self.last_cut) < self.min_cnp_gap {
            return; // cuts are rate-limited
        }
        self.last_cut = now;
        self.rt = self.rc;
        self.alpha = (1.0 - self.g) * self.alpha + self.g;
        self.rc *= 1.0 - self.alpha / 2.0;
        self.rc = self.rc.max(self.line_rate / 100.0);
        self.stage = 0;
        self.byte_counter = 0;
        self.last_stage_time = now;
    }

    fn on_timeout(&mut self, now: SimTime) {
        // RTO: treat as severe congestion
        self.on_cnp(now);
        self.rc = (self.rc / 2.0).max(self.line_rate / 1000.0);
    }

    fn state_bytes(&self) -> usize {
        // RC, RT, α (4 B each as fixed point), byte counter (4 B), stage (1),
        // timestamps (6) ≈ matches the ~20 B CC metadata the paper cites.
        20
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::AckFeedback;

    fn ack(bytes: usize) -> AckFeedback {
        AckFeedback {
            now: 1_000_000,
            rtt_ns: None,
            ecn_echo: false,
            acked_bytes: bytes,
            tele_qlen: 0,
        }
    }

    #[test]
    fn starts_at_line_rate() {
        let cc = Dcqcn::new(3.125);
        assert_eq!(cc.rate(), 3.125);
    }

    #[test]
    fn cnp_cuts_rate() {
        let mut cc = Dcqcn::new(3.125);
        cc.on_cnp(100_000);
        assert!(cc.rate() < 3.125);
        assert!(cc.rate() > 0.0);
    }

    #[test]
    fn cnp_cuts_are_rate_limited() {
        let mut cc = Dcqcn::new(3.125);
        cc.on_cnp(100_000);
        let r1 = cc.rate();
        cc.on_cnp(100_001); // within the 50 µs guard
        assert_eq!(cc.rate(), r1);
        cc.on_cnp(100_000 + 60_000);
        assert!(cc.rate() < r1);
    }

    #[test]
    fn recovers_after_cut() {
        let mut cc = Dcqcn::new(3.125);
        cc.on_cnp(100_000);
        let cut = cc.rate();
        for _ in 0..200 {
            cc.on_ack(ack(64 * 1024));
        }
        assert!(cc.rate() > cut);
        assert!(cc.rate() <= 3.125 + 1e-9);
    }

    #[test]
    fn repeated_marks_drive_rate_down_harder() {
        let mut one = Dcqcn::new(3.125);
        one.on_cnp(1_000_000);
        let mut many = Dcqcn::new(3.125);
        for i in 0..5 {
            many.on_cnp(1_000_000 + i * 60_000);
        }
        assert!(many.rate() < one.rate());
    }

    #[test]
    fn never_exceeds_line_rate() {
        let mut cc = Dcqcn::new(3.125);
        for _ in 0..10_000 {
            cc.on_ack(ack(64 * 1024));
        }
        assert!(cc.rate() <= 3.125 + 1e-9);
    }
}
