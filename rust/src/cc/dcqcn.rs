//! DCQCN (Zhu et al., SIGCOMM'15): ECN-mark driven rate control.
//!
//! Receiver turns CE marks into CNPs; the sender's reaction point cuts rate
//! multiplicatively on CNP and recovers through fast-recovery then
//! additive/hyper increase stages. We implement the byte-counter variant:
//! increase stages advance as acknowledged bytes accumulate, which avoids
//! extra timers on the DES hot path while preserving the control law.
//!
//! CC v2 signal subscription: `EcnMark` (cut), `AckBatch` (recovery
//! stages; skipped when the batch itself was marked), `LossHint`
//! (timeout ⇒ additional halving). `wants_cnp` is true — DCQCN is the one
//! scheme whose notification point emits CNPs for CE-marked deliveries.

use crate::cc::{CcCtx, CcSignal, CongestionControl};
use crate::sim::SimTime;

#[derive(Debug)]
pub struct Dcqcn {
    line_rate: f64,
    base_rtt: u64,
    /// Current rate RC, bytes/ns.
    rc: f64,
    /// Target rate RT.
    rt: f64,
    /// Rate-reduction factor α.
    alpha: f64,
    /// g parameter for α update.
    g: f64,
    /// Byte counter toward the next increase stage.
    byte_counter: usize,
    /// Bytes per increase stage.
    byte_counter_threshold: usize,
    /// Consecutive increase stages since last CNP.
    stage: u32,
    /// Additive increase step, bytes/ns.
    rai: f64,
    /// Last CNP time (rate cuts are clocked at ≥ one per 50 µs like the
    /// NP-side CNP pacing in deployments).
    last_cut: SimTime,
    min_cnp_gap: SimTime,
    /// Timer-based recovery clock (the spec's T = 55 µs stage timer) —
    /// without it a sender cut to the floor can never climb back, because
    /// the byte counter barely advances at low rate.
    last_stage_time: SimTime,
    stage_period: SimTime,
}

impl Dcqcn {
    pub fn new(line_rate: f64, base_rtt: u64) -> Dcqcn {
        Dcqcn {
            line_rate,
            base_rtt,
            rc: line_rate,
            rt: line_rate,
            alpha: 1.0,
            g: 1.0 / 16.0,
            byte_counter: 0,
            byte_counter_threshold: 64 * 1024,
            stage: 0,
            rai: line_rate / 25.0, // ~4% of line rate per additive step
            last_cut: 0,
            min_cnp_gap: 50_000,
            last_stage_time: 0,
            stage_period: 55_000,
        }
    }

    fn advance_stage(&mut self) {
        self.stage += 1;
        if self.stage <= 5 {
            // fast recovery: move halfway back to target
            self.rc = (self.rc + self.rt) / 2.0;
        } else {
            // additive increase: raise target, then close half the gap
            self.rt = (self.rt + self.rai).min(self.line_rate);
            self.rc = (self.rc + self.rt) / 2.0;
        }
        self.rc = self.rc.min(self.line_rate);
    }

    /// The reaction-point cut: multiplicative decrease scaled by α.
    fn cut(&mut self, now: SimTime) {
        if now.saturating_sub(self.last_cut) < self.min_cnp_gap {
            return; // cuts are rate-limited
        }
        self.last_cut = now;
        self.rt = self.rc;
        self.alpha = (1.0 - self.g) * self.alpha + self.g;
        self.rc *= 1.0 - self.alpha / 2.0;
        self.rc = self.rc.max(self.line_rate / 100.0);
        self.stage = 0;
        self.byte_counter = 0;
        self.last_stage_time = now;
    }

    /// Clean (unmarked) acknowledged bytes advance the recovery machinery.
    fn recover(&mut self, now: SimTime, acked_bytes: usize) {
        // α decays when no marks arrive
        self.alpha *= 1.0 - self.g;
        // byte-counter stages
        self.byte_counter += acked_bytes;
        while self.byte_counter >= self.byte_counter_threshold {
            self.byte_counter -= self.byte_counter_threshold;
            self.advance_stage();
        }
        // timer-based stages (bounded catch-up)
        if self.last_stage_time == 0 {
            self.last_stage_time = now;
        }
        let mut guard = 0;
        while now.saturating_sub(self.last_stage_time) >= self.stage_period && guard < 64 {
            self.last_stage_time += self.stage_period;
            self.advance_stage();
            guard += 1;
        }
        if guard == 64 {
            self.last_stage_time = now; // long idle gap: resync
        }
    }
}

impl CongestionControl for Dcqcn {
    fn name(&self) -> &'static str {
        "DCQCN"
    }

    fn rate(&self) -> f64 {
        self.rc
    }

    fn cwnd(&self) -> usize {
        (self.rc * self.base_rtt.max(1) as f64) as usize
    }

    fn wants_cnp(&self) -> bool {
        true
    }

    fn on_signal(&mut self, sig: CcSignal, ctx: &CcCtx) {
        match sig {
            CcSignal::EcnMark => self.cut(ctx.now),
            CcSignal::AckBatch {
                acked_bytes,
                marked,
            } => {
                // a marked batch already produced its EcnMark cut; the
                // recovery stages only advance on clean feedback
                if !marked {
                    self.recover(ctx.now, acked_bytes);
                }
            }
            CcSignal::LossHint { timeout } => {
                self.cut(ctx.now);
                if timeout {
                    // RTO: treat as severe congestion
                    self.rc = (self.rc / 2.0).max(self.line_rate / 1000.0);
                }
            }
            _ => {}
        }
    }

    fn state_bytes(&self) -> usize {
        // RC, RT, α (4 B each as fixed point), byte counter (4 B), stage (1),
        // timestamps (6) ≈ matches the ~20 B CC metadata the paper cites.
        20
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::CcCtx;

    fn ctx(now: SimTime) -> CcCtx {
        CcCtx {
            now,
            qpn: 1,
            bytes: 0,
            hops: 2,
        }
    }

    fn ack(cc: &mut Dcqcn, now: SimTime, bytes: usize) {
        cc.on_signal(
            CcSignal::AckBatch {
                acked_bytes: bytes,
                marked: false,
            },
            &ctx(now),
        );
    }

    fn mark(cc: &mut Dcqcn, now: SimTime) {
        cc.on_signal(CcSignal::EcnMark, &ctx(now));
    }

    #[test]
    fn starts_at_line_rate() {
        let cc = Dcqcn::new(3.125, 5_000);
        assert_eq!(cc.rate(), 3.125);
        assert!(cc.cwnd() > 0);
    }

    #[test]
    fn mark_cuts_rate() {
        let mut cc = Dcqcn::new(3.125, 5_000);
        mark(&mut cc, 100_000);
        assert!(cc.rate() < 3.125);
        assert!(cc.rate() > 0.0);
    }

    #[test]
    fn cuts_are_rate_limited() {
        let mut cc = Dcqcn::new(3.125, 5_000);
        mark(&mut cc, 100_000);
        let r1 = cc.rate();
        mark(&mut cc, 100_001); // within the 50 µs guard
        assert_eq!(cc.rate(), r1);
        mark(&mut cc, 100_000 + 60_000);
        assert!(cc.rate() < r1);
    }

    #[test]
    fn recovers_after_cut() {
        let mut cc = Dcqcn::new(3.125, 5_000);
        mark(&mut cc, 100_000);
        let cut = cc.rate();
        for _ in 0..200 {
            ack(&mut cc, 1_000_000, 64 * 1024);
        }
        assert!(cc.rate() > cut);
        assert!(cc.rate() <= 3.125 + 1e-9);
    }

    #[test]
    fn marked_batches_do_not_advance_recovery() {
        let mut cc = Dcqcn::new(3.125, 5_000);
        mark(&mut cc, 100_000);
        let cut = cc.rate();
        for _ in 0..50 {
            cc.on_signal(
                CcSignal::AckBatch {
                    acked_bytes: 64 * 1024,
                    marked: true,
                },
                &ctx(100_500),
            );
        }
        assert_eq!(cc.rate(), cut, "marked feedback must not trigger recovery");
    }

    #[test]
    fn repeated_marks_drive_rate_down_harder() {
        let mut one = Dcqcn::new(3.125, 5_000);
        mark(&mut one, 1_000_000);
        let mut many = Dcqcn::new(3.125, 5_000);
        for i in 0..5 {
            mark(&mut many, 1_000_000 + i * 60_000);
        }
        assert!(many.rate() < one.rate());
    }

    #[test]
    fn never_exceeds_line_rate() {
        let mut cc = Dcqcn::new(3.125, 5_000);
        for _ in 0..10_000 {
            ack(&mut cc, 1_000_000, 64 * 1024);
        }
        assert!(cc.rate() <= 3.125 + 1e-9);
    }

    #[test]
    fn timeout_halves_below_mark_cut() {
        let mut a = Dcqcn::new(3.125, 5_000);
        mark(&mut a, 1_000_000);
        let mut b = Dcqcn::new(3.125, 5_000);
        b.on_signal(CcSignal::LossHint { timeout: true }, &ctx(1_000_000));
        assert!(b.rate() < a.rate());
    }

    #[test]
    fn epoch_cadence_signals_cut_then_recover() {
        // the fluid plane synthesizes signals once per base RTT, not per
        // packet — the control law must close the loop at that cadence:
        // marked epochs cut (rate-limited by the CNP guard), clean
        // epochs climb back via the timer stages even though per-epoch
        // acked bytes are far below the 64 KiB byte-counter stage
        let mut cc = Dcqcn::new(3.125, 5_000);
        let mut t = 0u64;
        for _ in 0..12 {
            t += 5_000;
            mark(&mut cc, t);
            cc.on_signal(
                CcSignal::AckBatch { acked_bytes: 16 * 1024, marked: true },
                &ctx(t),
            );
        }
        let cut = cc.rate();
        assert!(cut < 3.125, "sustained marked epochs must cut");
        assert!(cut >= 3.125 / 100.0, "never below the DCQCN floor");
        for _ in 0..200 {
            t += 5_000;
            ack(&mut cc, t, 2 * 1024);
        }
        assert!(cc.rate() > cut, "epoch-cadence recovery must climb");
        // on_epoch itself is a no-op for rate-based schemes: the tick's
        // work (grant pacing) only applies to credit-based CC
        let r = cc.rate();
        cc.on_epoch(&ctx(t + 5_000));
        assert_eq!(cc.rate(), r);
    }
}
