//! Delay-based congestion control: TIMELY (SIGCOMM'15) and Swift
//! (SIGCOMM'20), both parameterizations of one engine.
//!
//! TIMELY reacts to the RTT *gradient*; Swift tracks a *target delay* with
//! multiplicative decrease proportional to the overshoot. Both need only
//! timestamped feedback packets — which OptiNIC keeps generating for
//! packets that arrive (§3.1.3) — so they run unchanged over best effort.
//!
//! CC v2 signal subscription: `RttSample` (the control law), `EcnMark`
//! (explicit marks also honored, mild decrease), `LossHint` (forced
//! decrease; halve on timeout). `AckBatch`/`IntTelemetry` are ignored —
//! delay-based schemes need nothing beyond timestamps.

use crate::cc::{CcCtx, CcSignal, CongestionControl};
use crate::sim::SimTime;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    Timely,
    Swift,
}

#[derive(Debug)]
pub struct DelayBased {
    flavor: Flavor,
    line_rate: f64,
    rate: f64,
    base_rtt: f64,
    /// Swift: target delay (ns). TIMELY: Thigh.
    target_delay: f64,
    /// TIMELY: Tlow — below this, additive increase regardless of gradient.
    t_low: f64,
    /// EWMA'd RTT and previous RTT for gradient computation.
    rtt_ewma: Option<f64>,
    prev_rtt: Option<f64>,
    /// Additive increase, bytes/ns per update.
    ai: f64,
    /// Multiplicative decrease factor.
    beta: f64,
    /// Swift: max fractional decrease per RTT.
    max_mdf: f64,
    last_decrease: SimTime,
    /// last feedback time — additive increase is time-proportional so a
    /// rate-starved sender (few ACKs) still recovers at ai per RTT
    last_seen: SimTime,
}

impl DelayBased {
    pub fn timely(line_rate: f64, base_rtt: u64) -> DelayBased {
        DelayBased {
            flavor: Flavor::Timely,
            line_rate,
            rate: line_rate,
            base_rtt: base_rtt as f64,
            target_delay: 3.0 * base_rtt as f64,
            t_low: 1.2 * base_rtt as f64,
            rtt_ewma: None,
            prev_rtt: None,
            ai: line_rate / 50.0,
            beta: 0.8,
            max_mdf: 0.5,
            last_decrease: 0,
            last_seen: 0,
        }
    }

    pub fn swift(line_rate: f64, base_rtt: u64) -> DelayBased {
        DelayBased {
            flavor: Flavor::Swift,
            line_rate,
            rate: line_rate,
            base_rtt: base_rtt as f64,
            // Swift's target: base + per-hop budget
            target_delay: 1.5 * base_rtt as f64 + 10_000.0,
            t_low: 0.0,
            rtt_ewma: None,
            prev_rtt: None,
            ai: line_rate / 50.0,
            beta: 0.8,
            max_mdf: 0.5,
            last_decrease: 0,
            last_seen: 0,
        }
    }

    /// Additive increase, scaled by elapsed RTTs since the last feedback so
    /// recovery speed does not depend on the (rate-proportional) ACK rate.
    fn increase(&mut self, now: SimTime) {
        let dt = (now.saturating_sub(self.last_seen)) as f64 / self.base_rtt;
        let steps = dt.clamp(0.05, 8.0);
        self.rate = (self.rate + self.ai * steps).min(self.line_rate);
    }

    fn decrease(&mut self, factor: f64, now: SimTime, force: bool) {
        // at most one multiplicative decrease per RTT; a forced cut (RTO)
        // bypasses the limiter — the old "last_decrease = 0" reset trick
        // silently skipped timeouts landing inside the first base_rtt
        if !force && (now as f64 - self.last_decrease as f64) < self.base_rtt {
            return;
        }
        self.last_decrease = now;
        let f = factor.clamp(1.0 - self.max_mdf, 1.0);
        self.rate = (self.rate * f).max(self.line_rate / 1000.0);
    }

    /// The delay control law: one RTT sample.
    fn on_rtt(&mut self, now: SimTime, rtt_ns: u64) {
        let rtt = rtt_ns as f64;
        let ewma = match self.rtt_ewma {
            None => rtt,
            Some(e) => 0.3 * rtt + 0.7 * e,
        };
        let prev = self.prev_rtt.replace(ewma);
        self.rtt_ewma = Some(ewma);

        match self.flavor {
            Flavor::Swift => {
                if ewma <= self.target_delay {
                    self.increase(now);
                } else {
                    // decrease proportional to overshoot
                    let over = (ewma - self.target_delay) / ewma;
                    self.decrease(1.0 - self.beta * over, now, false);
                }
            }
            Flavor::Timely => {
                if ewma < self.t_low {
                    self.increase(now);
                    self.last_seen = now;
                    return;
                }
                if ewma > self.target_delay {
                    self.decrease(1.0 - self.beta * (1.0 - self.target_delay / ewma), now, false);
                    return;
                }
                // gradient-based region
                if let Some(p) = prev {
                    let grad = (ewma - p) / self.base_rtt;
                    if grad <= 0.0 {
                        self.increase(now);
                    } else {
                        self.decrease(1.0 - self.beta * grad.min(1.0), now, false);
                    }
                } else {
                    self.increase(now);
                }
            }
        }
        self.last_seen = now;
    }
}

impl CongestionControl for DelayBased {
    fn name(&self) -> &'static str {
        match self.flavor {
            Flavor::Timely => "TIMELY",
            Flavor::Swift => "Swift",
        }
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn cwnd(&self) -> usize {
        (self.rate * self.base_rtt.max(1.0)) as usize
    }

    fn on_signal(&mut self, sig: CcSignal, ctx: &CcCtx) {
        match sig {
            CcSignal::RttSample { rtt_ns } => self.on_rtt(ctx.now, rtt_ns),
            // delay-based senders also honor explicit marks if present
            CcSignal::EcnMark => self.decrease(0.8, ctx.now, false),
            CcSignal::LossHint { timeout } => {
                if timeout {
                    self.decrease(0.5, ctx.now, true);
                } else {
                    self.decrease(0.8, ctx.now, false);
                }
            }
            _ => {}
        }
    }

    fn state_bytes(&self) -> usize {
        // rate, rtt_ewma, prev_rtt, last_decrease: 4×6 B fixed-point
        24
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::CcCtx;

    fn rtt(cc: &mut DelayBased, now: SimTime, rtt_ns: u64) {
        cc.on_signal(
            CcSignal::RttSample { rtt_ns },
            &CcCtx {
                now,
                qpn: 1,
                bytes: 1500,
                hops: 2,
            },
        );
    }

    #[test]
    fn swift_increases_under_target() {
        let mut cc = DelayBased::swift(3.125, 5_000);
        cc.rate = 1.0;
        for i in 0..50 {
            rtt(&mut cc, i * 10_000, 5_000);
        }
        assert!(cc.rate() > 1.0);
    }

    #[test]
    fn swift_decreases_over_target() {
        let mut cc = DelayBased::swift(3.125, 5_000);
        let r0 = cc.rate();
        for i in 0..20 {
            rtt(&mut cc, i * 20_000, 200_000); // huge RTT
        }
        assert!(cc.rate() < r0);
    }

    #[test]
    fn timely_low_rtt_always_increases() {
        let mut cc = DelayBased::timely(3.125, 5_000);
        cc.rate = 0.5;
        for i in 0..30 {
            rtt(&mut cc, i * 10_000, 5_000); // below t_low = 6000
        }
        assert!(cc.rate() > 0.5);
    }

    #[test]
    fn timely_positive_gradient_decreases() {
        let mut cc = DelayBased::timely(3.125, 5_000);
        let mut r = 8_000u64; // inside the gradient band (t_low..3*rtt)
        let r0 = cc.rate();
        for i in 0..30 {
            r += 300; // rising RTT
            rtt(&mut cc, i * 20_000, r);
        }
        assert!(cc.rate() < r0, "rate={} r0={r0}", cc.rate());
    }

    #[test]
    fn decrease_rate_limited_per_rtt() {
        let mut cc = DelayBased::swift(3.125, 100_000);
        rtt(&mut cc, 10, 10_000_000);
        let r1 = cc.rate();
        rtt(&mut cc, 20, 10_000_000); // same RTT window
        assert_eq!(cc.rate(), r1);
    }

    #[test]
    fn rate_floor_positive() {
        let mut cc = DelayBased::swift(3.125, 1_000);
        for i in 0..500 {
            rtt(&mut cc, i * 10_000, 50_000_000);
        }
        assert!(cc.rate() > 0.0);
    }

    /// An RTO landing inside the first base_rtt of sim time must still
    /// brake: the forced cut bypasses the per-RTT limiter.
    #[test]
    fn timeout_brakes_even_before_one_rtt() {
        let mut cc = DelayBased::swift(3.125, 100_000);
        let r0 = cc.rate();
        cc.on_signal(
            CcSignal::LossHint { timeout: true },
            &CcCtx {
                now: 50,
                qpn: 1,
                bytes: 0,
                hops: 2,
            },
        );
        assert!(cc.rate() < r0, "RTO brake must bypass the per-RTT limiter");
    }

    #[test]
    fn explicit_mark_decreases() {
        let mut cc = DelayBased::swift(3.125, 1_000);
        let r0 = cc.rate();
        cc.on_signal(
            CcSignal::EcnMark,
            &CcCtx {
                now: 10_000,
                qpn: 1,
                bytes: 0,
                hops: 2,
            },
        );
        assert!(cc.rate() < r0);
    }

    #[test]
    fn epoch_cadence_rtt_samples_close_the_loop() {
        // the fluid plane synthesizes one RTT sample per base-RTT epoch:
        // base path latency plus the summed virtual-queue drain times.
        // Swift must converge through that cadence alone — congested
        // epochs (RTT over target) brake, clean epochs recover.
        let mut cc = DelayBased::swift(3.125, 5_000);
        let mut t = 0u64;
        for _ in 0..40 {
            t += 5_000;
            rtt(&mut cc, t, 60_000); // queue-inflated: over target (17.5 µs)
        }
        let braked = cc.rate();
        assert!(braked < 3.125, "over-target epochs must brake");
        for _ in 0..400 {
            t += 5_000;
            rtt(&mut cc, t, 5_000); // queues drained: base RTT again
        }
        assert!(cc.rate() > braked, "clean epochs must recover");
        // the epoch tick itself is signal-free for delay-based schemes
        let r = cc.rate();
        cc.on_epoch(&CcCtx { now: t + 5_000, qpn: 1, bytes: 0, hops: 2 });
        assert_eq!(cc.rate(), r);
    }
}
