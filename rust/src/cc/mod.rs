//! Congestion control, decoupled from reliability (§3.1.3) — CC v2.
//!
//! OptiNIC's claim is that the dominant RDMA CC schemes keep working over a
//! best-effort substrate because none of them require reliable delivery of
//! every data packet: DCQCN's CNPs are generated for packets that *do*
//! arrive; TIMELY/Swift compute RTT from feedback that *does* come back;
//! HPCC reads in-band telemetry off delivered packets; EQDS grants credits
//! from the receiver. Lost packets simply yield no feedback.
//!
//! CC v2 makes that claim structural rather than asserted. The transports
//! never name an algorithm: every engine owns a [`CcDriver`] that holds the
//! per-QP [`CongestionControl`] instances, decomposes raw feedback (ACKs,
//! CNPs, credits, losses) into the normalized [`CcSignal`] vocabulary in a
//! fixed order, and gates transmission through one pacing/credit API
//! ([`CcDriver::admit`]). Algorithms subscribe to the signals they care
//! about and ignore the rest — so a transport × CC grid needs zero engine
//! changes per algorithm. `state_bytes()` reports the per-QP CC metadata
//! footprint for the Table 4/5 hardware accounting.

pub mod dblp;
pub mod dcqcn;
pub mod driver;
pub mod eqds;
pub mod hpcc;
pub mod swift;

pub use driver::{Admit, CcDriver, RateAuthority, CC_ENDPOINT_BYTES};

use crate::net::NetHints;
use crate::sim::SimTime;
use crate::verbs::Qpn;

/// Selector for the CC algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CcKind {
    Dcqcn,
    Timely,
    Swift,
    Eqds,
    Hpcc,
    /// DBLP: phase-aware bounded-loss policy (PAPERS.md; docs/SCENARIOS.md
    /// §DBLP) — the burst-scenario baseline.
    Dblp,
    /// Fixed-rate (line rate) — used by microbenchmarks that isolate
    /// reliability machinery from CC dynamics.
    None,
}

impl CcKind {
    /// Every algorithm, in sweep order (mirrors
    /// `TransportKind::ALL_WITH_VARIANTS` for the CC × transport grid).
    pub const ALL: [CcKind; 7] = [
        CcKind::Dcqcn,
        CcKind::Timely,
        CcKind::Swift,
        CcKind::Eqds,
        CcKind::Hpcc,
        CcKind::Dblp,
        CcKind::None,
    ];

    pub fn parse(s: &str) -> Option<CcKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "dcqcn" => CcKind::Dcqcn,
            "timely" => CcKind::Timely,
            "swift" => CcKind::Swift,
            "eqds" => CcKind::Eqds,
            "hpcc" => CcKind::Hpcc,
            "dblp" => CcKind::Dblp,
            "none" | "line" => CcKind::None,
            _ => return None,
        })
    }

    /// Canonical lower-case spelling, the inverse of [`CcKind::parse`].
    pub fn canonical_name(&self) -> &'static str {
        match self {
            CcKind::Dcqcn => "dcqcn",
            CcKind::Timely => "timely",
            CcKind::Swift => "swift",
            CcKind::Eqds => "eqds",
            CcKind::Hpcc => "hpcc",
            CcKind::Dblp => "dblp",
            CcKind::None => "none",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CcKind::Dcqcn => "DCQCN",
            CcKind::Timely => "TIMELY",
            CcKind::Swift => "Swift",
            CcKind::Eqds => "EQDS",
            CcKind::Hpcc => "HPCC",
            CcKind::Dblp => "DBLP",
            CcKind::None => "none",
        }
    }

    /// Build a per-QP CC instance. `line_rate` in bytes/ns; `base_rtt` ns.
    pub fn build(&self, line_rate: f64, base_rtt: u64) -> Box<dyn CongestionControl> {
        match self {
            CcKind::Dcqcn => Box::new(dcqcn::Dcqcn::new(line_rate, base_rtt)),
            CcKind::Timely => Box::new(swift::DelayBased::timely(line_rate, base_rtt)),
            CcKind::Swift => Box::new(swift::DelayBased::swift(line_rate, base_rtt)),
            CcKind::Eqds => Box::new(eqds::Eqds::new(line_rate, base_rtt)),
            CcKind::Hpcc => Box::new(hpcc::Hpcc::new(line_rate, base_rtt)),
            CcKind::Dblp => Box::new(dblp::Dblp::new(line_rate, base_rtt)),
            CcKind::None => Box::new(FixedRate::new(line_rate, base_rtt)),
        }
    }
}

/// One normalized congestion-control feedback event. The [`CcDriver`] is
/// the only producer; every transport's raw feedback (ACK, CNP, credit,
/// NACK, RTO) is decomposed into this vocabulary, so algorithms never see
/// transport-specific packet formats.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CcSignal {
    /// Explicit congestion notification: a CE mark echoed on feedback, or
    /// a standalone CNP. (DCQCN's reaction-point input.)
    EcnMark,
    /// An RTT measurement from an echoed transmit timestamp.
    /// (TIMELY/Swift's input.)
    RttSample { rtt_ns: u64 },
    /// In-band telemetry echoed off a delivered packet: egress queue depth,
    /// the stamping port's cumulative tx bytes (busy-time proxy), and the
    /// link rate in bytes/ns. (HPCC's input.)
    IntTelemetry {
        qdepth: u32,
        tx_bytes: u64,
        link_rate: f64,
    },
    /// Receiver-driven credit grant (EQDS's input).
    CreditGrant { bytes: usize },
    /// Loss indication. `timeout` distinguishes a retransmission timeout
    /// (severe — the pipe may be dead) from a NACK/gap hint (mild).
    LossHint { timeout: bool },
    /// Coalesced acknowledgment: bytes newly delivered. `marked` is set
    /// when the same feedback also carried a CE echo, so mark-driven laws
    /// can skip their increase stage for this batch.
    AckBatch { acked_bytes: usize, marked: bool },
}

/// Ambient context delivered alongside every signal: when, which QP,
/// how many bytes the signal speaks for, and the path length.
#[derive(Clone, Copy, Debug)]
pub struct CcCtx {
    /// Simulation time the signal was observed at the sender.
    pub now: SimTime,
    /// QP the signal belongs to.
    pub qpn: Qpn,
    /// Bytes associated with the signal (acked / granted / delivered);
    /// 0 when the signal carries no byte count.
    pub bytes: usize,
    /// Network links the feedback traversed: the hop count stamped into
    /// its `NetHints` (plus the host uplink) when present, else the
    /// fabric's worst-case path — 2 for the single ToR, 4 for leaf–spine.
    pub hops: u32,
}

/// Per-QP congestion-control state machine (CC v2).
///
/// Sender side: the driver feeds [`CongestionControl::on_signal`] and reads
/// `rate()` / `cwnd()` / `pacing_delay()` / `try_send()` to pace. Receiver
/// side: the optional demand/grant hooks let receiver-driven schemes (EQDS)
/// run their credit loop behind the same trait, and `wants_cnp()` is the
/// notification-point policy (does a CE-marked delivery produce a CNP?).
pub trait CongestionControl {
    fn name(&self) -> &'static str;

    /// One normalized feedback signal. Algorithms handle the variants they
    /// subscribe to and ignore the rest.
    fn on_signal(&mut self, sig: CcSignal, ctx: &CcCtx);

    /// Current allowed sending rate, bytes/ns.
    fn rate(&self) -> f64;

    /// Current congestion window in bytes: the credit balance for
    /// credit-based schemes, rate × base-RTT for rate-based ones.
    fn cwnd(&self) -> usize;

    /// Delay before `bytes` may leave at the current rate (the pacing API
    /// transports schedule their pace timers from).
    fn pacing_delay(&self, bytes: usize) -> SimTime {
        (bytes as f64 / self.rate()).ceil() as SimTime
    }

    /// Sender asks to transmit `bytes`: credit-based schemes consume
    /// credit and may refuse; rate-based schemes always allow (pacing is
    /// enforced via `rate()`).
    fn try_send(&mut self, bytes: usize) -> bool {
        let _ = bytes;
        true
    }

    /// Sender-side policy: should the transport announce new demand to the
    /// peer (pull-request packets)? True for receiver-driven schemes.
    fn announces_demand(&self) -> bool {
        false
    }

    /// Receiver-side policy: should a CE-marked delivery produce a CNP
    /// back to the sender? (DCQCN's notification point.)
    fn wants_cnp(&self) -> bool {
        false
    }

    /// Receiver side: the peer announced `bytes` of pending demand.
    fn on_demand(&mut self, bytes: usize) {
        let _ = bytes;
    }

    /// Receiver side: announced demand not yet covered by grants.
    fn demand_pending(&self) -> usize {
        0
    }

    /// Receiver side: produce the next credit grant of up to `chunk`
    /// bytes, plus the pacing gap before the next grant tick.
    fn next_grant(&mut self, chunk: usize) -> Option<(usize, SimTime)> {
        let _ = chunk;
        None
    }

    /// Receiver side: `bytes` of data were delivered locally with `hints`
    /// telemetry (EQDS grant-rate AIMD reads the CE marks here).
    fn on_delivery(&mut self, bytes: usize, hints: &NetHints, ctx: &CcCtx) {
        let _ = (bytes, hints, ctx);
    }

    /// Epoch-cadence tick for engines without per-packet events (the
    /// fluid solver, via [`RateAuthority::epoch_tick`]). Time-driven
    /// policy machinery that per-packet schemes piggyback on packet
    /// arrivals — DBLP's idle-gap phase detection — advances here
    /// instead. Default: nothing is time-driven.
    fn on_epoch(&mut self, ctx: &CcCtx) {
        let _ = ctx;
    }

    /// Per-QP CC metadata kept in NIC SRAM, bytes (hardware model input).
    fn state_bytes(&self) -> usize;
}

/// Line-rate (no CC).
#[derive(Debug)]
pub struct FixedRate {
    rate: f64,
    base_rtt: u64,
}

impl FixedRate {
    pub fn new(rate: f64, base_rtt: u64) -> FixedRate {
        FixedRate { rate, base_rtt }
    }
}

impl CongestionControl for FixedRate {
    fn name(&self) -> &'static str {
        "none"
    }
    fn rate(&self) -> f64 {
        self.rate
    }
    fn cwnd(&self) -> usize {
        // no windowing — one BDP reported for the hardware accounting
        (self.rate * self.base_rtt.max(1) as f64) as usize
    }
    fn on_signal(&mut self, _sig: CcSignal, _ctx: &CcCtx) {}
    fn state_bytes(&self) -> usize {
        8 // just the rate register
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(now: SimTime) -> CcCtx {
        CcCtx {
            now,
            qpn: 1,
            bytes: 0,
            hops: 2,
        }
    }

    #[test]
    fn kind_parse() {
        assert_eq!(CcKind::parse("dcqcn"), Some(CcKind::Dcqcn));
        assert_eq!(CcKind::parse("SWIFT"), Some(CcKind::Swift));
        assert_eq!(CcKind::parse("nope"), None);
    }

    /// `ALL` covers every variant, and both the canonical and the display
    /// spelling round-trip through `parse`.
    #[test]
    fn kind_roundtrip_every_variant() {
        assert_eq!(CcKind::ALL.len(), 7);
        for k in CcKind::ALL {
            assert_eq!(
                CcKind::parse(k.canonical_name()),
                Some(k),
                "canonical spelling '{}' must parse back",
                k.canonical_name()
            );
            assert_eq!(
                CcKind::parse(k.name()),
                Some(k),
                "display name '{}' must parse back",
                k.name()
            );
        }
        // no duplicates
        for i in 0..CcKind::ALL.len() {
            for j in i + 1..CcKind::ALL.len() {
                assert_ne!(CcKind::ALL[i], CcKind::ALL[j]);
            }
        }
    }

    #[test]
    fn all_kinds_build() {
        for k in CcKind::ALL {
            let cc = k.build(3.125, 5_000);
            assert!(cc.rate() > 0.0, "{}", cc.name());
            assert!(cc.state_bytes() > 0);
            assert!(cc.cwnd() > 0, "{}: cwnd must be positive", cc.name());
            // pacing: 1 MB at a positive rate takes positive time
            assert!(cc.pacing_delay(1 << 20) > 0);
        }
    }

    #[test]
    fn fixed_rate_is_inert() {
        let mut cc = FixedRate::new(12.5, 5_000);
        for sig in [
            CcSignal::EcnMark,
            CcSignal::RttSample { rtt_ns: 100 },
            CcSignal::IntTelemetry {
                qdepth: 1 << 20,
                tx_bytes: 1 << 30,
                link_rate: 12.5,
            },
            CcSignal::CreditGrant { bytes: 1000 },
            CcSignal::LossHint { timeout: true },
            CcSignal::AckBatch {
                acked_bytes: 1000,
                marked: true,
            },
        ] {
            cc.on_signal(sig, &ctx(0));
        }
        assert_eq!(cc.rate(), 12.5);
        assert!(cc.try_send(usize::MAX / 2));
    }
}
