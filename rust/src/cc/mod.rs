//! Congestion control, decoupled from reliability (§3.1.3).
//!
//! OptiNIC's claim is that the dominant RDMA CC schemes keep working over a
//! best-effort substrate because none of them require reliable delivery of
//! every data packet: DCQCN's CNPs are generated for packets that *do*
//! arrive; TIMELY/Swift compute RTT from feedback that *does* come back;
//! HPCC reads in-band telemetry off delivered packets; EQDS grants credits
//! from the receiver. Lost packets simply yield no feedback.
//!
//! Every algorithm implements [`CongestionControl`]: transports ask for the
//! current `rate()` to pace, and forward feedback events. `state_bytes()`
//! reports the per-QP CC metadata footprint for the Table 4/5 hardware
//! accounting.

pub mod dcqcn;
pub mod eqds;
pub mod hpcc;
pub mod swift;

use crate::sim::SimTime;

/// Selector for the CC algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CcKind {
    Dcqcn,
    Timely,
    Swift,
    Eqds,
    Hpcc,
    /// Fixed-rate (line rate) — used by microbenchmarks that isolate
    /// reliability machinery from CC dynamics.
    None,
}

impl CcKind {
    pub fn parse(s: &str) -> Option<CcKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "dcqcn" => CcKind::Dcqcn,
            "timely" => CcKind::Timely,
            "swift" => CcKind::Swift,
            "eqds" => CcKind::Eqds,
            "hpcc" => CcKind::Hpcc,
            "none" | "line" => CcKind::None,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CcKind::Dcqcn => "DCQCN",
            CcKind::Timely => "TIMELY",
            CcKind::Swift => "Swift",
            CcKind::Eqds => "EQDS",
            CcKind::Hpcc => "HPCC",
            CcKind::None => "none",
        }
    }

    /// Build a per-QP CC instance. `line_rate` in bytes/ns; `base_rtt` ns.
    pub fn build(&self, line_rate: f64, base_rtt: u64) -> Box<dyn CongestionControl> {
        match self {
            CcKind::Dcqcn => Box::new(dcqcn::Dcqcn::new(line_rate)),
            CcKind::Timely => Box::new(swift::DelayBased::timely(line_rate, base_rtt)),
            CcKind::Swift => Box::new(swift::DelayBased::swift(line_rate, base_rtt)),
            CcKind::Eqds => Box::new(eqds::Eqds::new(line_rate, base_rtt)),
            CcKind::Hpcc => Box::new(hpcc::Hpcc::new(line_rate, base_rtt)),
            CcKind::None => Box::new(FixedRate { rate: line_rate }),
        }
    }
}

/// Feedback from one delivered-data acknowledgment.
#[derive(Clone, Copy, Debug)]
pub struct AckFeedback {
    pub now: SimTime,
    /// Measured RTT if the feedback echoes a tx timestamp.
    pub rtt_ns: Option<u64>,
    /// Receiver saw the CE mark on the data packet.
    pub ecn_echo: bool,
    /// Bytes newly acknowledged.
    pub acked_bytes: usize,
    /// Echoed in-band telemetry: switch egress queue depth in bytes.
    pub tele_qlen: u32,
}

/// Per-QP congestion-control state machine.
pub trait CongestionControl {
    fn name(&self) -> &'static str;

    /// Current allowed sending rate, bytes/ns.
    fn rate(&self) -> f64;

    /// ACK/feedback packet processed.
    fn on_ack(&mut self, fb: AckFeedback);

    /// Explicit congestion notification packet (DCQCN).
    fn on_cnp(&mut self, now: SimTime);

    /// Credit grant received (EQDS).
    fn on_credit(&mut self, bytes: usize) {
        let _ = bytes;
    }

    /// Sender asks to transmit `bytes`: credit-based schemes consume
    /// credit and may refuse; rate-based schemes always allow (pacing is
    /// enforced via `rate()`).
    fn try_send(&mut self, bytes: usize) -> bool {
        let _ = bytes;
        true
    }

    /// Retransmission-timeout-style loss signal (reliable transports).
    fn on_timeout(&mut self, now: SimTime);

    /// Per-QP CC metadata kept in NIC SRAM, bytes (hardware model input).
    fn state_bytes(&self) -> usize;
}

/// Line-rate (no CC).
#[derive(Debug)]
pub struct FixedRate {
    rate: f64,
}

impl CongestionControl for FixedRate {
    fn name(&self) -> &'static str {
        "none"
    }
    fn rate(&self) -> f64 {
        self.rate
    }
    fn on_ack(&mut self, _fb: AckFeedback) {}
    fn on_cnp(&mut self, _now: SimTime) {}
    fn on_timeout(&mut self, _now: SimTime) {}
    fn state_bytes(&self) -> usize {
        8 // just the rate register
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse() {
        assert_eq!(CcKind::parse("dcqcn"), Some(CcKind::Dcqcn));
        assert_eq!(CcKind::parse("SWIFT"), Some(CcKind::Swift));
        assert_eq!(CcKind::parse("nope"), None);
    }

    #[test]
    fn all_kinds_build() {
        for k in [
            CcKind::Dcqcn,
            CcKind::Timely,
            CcKind::Swift,
            CcKind::Eqds,
            CcKind::Hpcc,
            CcKind::None,
        ] {
            let cc = k.build(3.125, 5_000);
            assert!(cc.rate() > 0.0, "{}", cc.name());
            assert!(cc.state_bytes() > 0);
        }
    }

    #[test]
    fn fixed_rate_is_inert() {
        let mut cc = FixedRate { rate: 12.5 };
        cc.on_cnp(0);
        cc.on_timeout(0);
        cc.on_ack(AckFeedback {
            now: 0,
            rtt_ns: Some(100),
            ecn_echo: true,
            acked_bytes: 1000,
            tele_qlen: 0,
        });
        assert_eq!(cc.rate(), 12.5);
    }
}
