//! DBLP — Phase-Aware Bounded-Loss Transport (PAPERS.md), as a CC policy.
//!
//! DBLP's thesis: distributed-ML traffic is *phased* (compute silence, then
//! a synchronized communication burst per collective phase), and a bounded
//! amount of loss per phase is harmless — gradient scrubbing absorbs it —
//! so the sender should NOT pay the tail cost of backing off for every
//! loss. The policy has three stages:
//!
//! 1. **Phase detector** — a communication phase starts when feedback
//!    resumes after an idle gap longer than `idle_gap` (a few base RTTs);
//!    each detected boundary rolls the loss ledger.
//! 2. **Per-phase loss budget** — losses inside a phase are tallied
//!    against `budget_frac` of the bytes the phase has moved so far
//!    (plus a small floor so the first packets of a phase are covered).
//! 3. **Bounded-loss admission** — while the phase is within budget,
//!    loss hints do NOT cut the rate (bounded loss is accepted and the
//!    sender stays near line rate); once the budget is exhausted the
//!    policy brakes multiplicatively and holds a conservative rate until
//!    the next phase boundary resets the ledger. RTOs always brake: a
//!    dead pipe is never "within budget".
//!
//! Implemented purely against the CC v2 trait — the policy subscribes to
//! `AckBatch` (phase detection + budget denominator + additive recovery),
//! `LossHint` (the ledger), and `EcnMark` (mild brake, so incast bursts
//! still see *some* pushback) and ignores the rest. No transport knows it
//! exists: a seventh `CcKind` slots into every engine unchanged, which is
//! exactly the transport-agnosticism proof the CC v2 plane claims.

use crate::cc::{CcCtx, CcSignal, CongestionControl};
use crate::sim::SimTime;

#[derive(Debug)]
pub struct Dblp {
    line_rate: f64,
    rate: f64,
    base_rtt: f64,
    /// Feedback silence longer than this opens a new phase (ns).
    idle_gap: f64,
    /// Loss budget as a fraction of bytes the current phase has delivered.
    budget_frac: f64,
    /// Budget floor (bytes): early-phase losses are judged against this
    /// before enough bytes have moved to make the fraction meaningful.
    budget_floor: usize,
    /// Estimated bytes charged per NACK-grade loss hint (one MTU).
    loss_quantum: usize,
    /// Multiplicative brake once the phase budget is exhausted.
    brake: f64,
    /// Phase ledger.
    phase_id: u64,
    phase_acked: usize,
    phase_lost: usize,
    last_feedback: SimTime,
    /// `None` until the first cut — so the limiter can never suppress a
    /// signal that arrives during the first `base_rtt` ns of sim time.
    last_decrease: Option<SimTime>,
}

impl Dblp {
    pub fn new(line_rate: f64, base_rtt: u64) -> Dblp {
        Dblp {
            line_rate,
            rate: line_rate,
            base_rtt: base_rtt.max(1) as f64,
            idle_gap: 4.0 * base_rtt.max(1) as f64,
            budget_frac: 0.02,
            budget_floor: 16 * 1024,
            loss_quantum: 1500,
            brake: 0.5,
            phase_id: 0,
            phase_acked: 0,
            phase_lost: 0,
            last_feedback: 0,
            last_decrease: None,
        }
    }

    /// Current phase's loss allowance in bytes.
    fn budget(&self) -> usize {
        self.budget_floor + (self.budget_frac * self.phase_acked as f64) as usize
    }

    /// Is the current phase still inside its loss budget?
    pub fn within_budget(&self) -> bool {
        self.phase_lost <= self.budget()
    }

    /// Phases detected so far (boundary = feedback after an idle gap).
    pub fn phases_seen(&self) -> u64 {
        self.phase_id
    }

    /// Roll the ledger at a detected phase boundary and release the brake:
    /// a fresh phase starts with a clean budget at full rate.
    fn roll_phase(&mut self) {
        self.phase_id += 1;
        self.phase_acked = 0;
        self.phase_lost = 0;
        self.rate = self.line_rate;
    }

    fn maybe_phase_boundary(&mut self, now: SimTime) {
        if self.phase_id == 0
            || (now.saturating_sub(self.last_feedback)) as f64 > self.idle_gap
        {
            self.roll_phase();
        }
        self.last_feedback = now;
    }

    fn decrease(&mut self, factor: f64, now: SimTime, force: bool) {
        // at most one multiplicative cut per RTT (same discipline as
        // Swift/TIMELY — keeps burst-length-proportional signal storms
        // from collapsing the rate to the floor); a forced cut (RTO)
        // bypasses the limiter: a dead pipe must brake unconditionally
        if !force {
            if let Some(last) = self.last_decrease {
                if (now.saturating_sub(last)) as f64 < self.base_rtt {
                    return;
                }
            }
        }
        self.last_decrease = Some(now);
        self.rate = (self.rate * factor).max(self.line_rate / 1000.0);
    }

    fn on_ack(&mut self, acked: usize, now: SimTime) {
        self.maybe_phase_boundary(now);
        self.phase_acked += acked;
        if self.within_budget() {
            // additive climb back to line rate; aggressive by design —
            // bounded loss means the pipe is allowed to stay hot
            self.rate = (self.rate + self.line_rate / 20.0).min(self.line_rate);
        }
    }

    fn on_loss(&mut self, timeout: bool, now: SimTime) {
        if timeout {
            // an RTO is never bounded loss: the pipe may be dead
            self.phase_lost += 4 * self.loss_quantum;
            self.decrease(self.brake, now, true);
            return;
        }
        self.phase_lost += self.loss_quantum;
        if !self.within_budget() {
            self.decrease(self.brake, now, false);
        }
        // within budget: absorb the loss, hold the rate — the whole point
    }
}

impl CongestionControl for Dblp {
    fn name(&self) -> &'static str {
        "DBLP"
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn cwnd(&self) -> usize {
        (self.rate * self.base_rtt) as usize
    }

    fn on_signal(&mut self, sig: CcSignal, ctx: &CcCtx) {
        match sig {
            CcSignal::AckBatch { acked_bytes, .. } => self.on_ack(acked_bytes, ctx.now),
            CcSignal::LossHint { timeout } => self.on_loss(timeout, ctx.now),
            // marks get a mild brake — microbursts still see pushback even
            // while the loss ledger is in the green
            CcSignal::EcnMark => self.decrease(0.85, ctx.now, false),
            // RTT/INT/credit streams are other algorithms' food
            _ => {}
        }
    }

    fn state_bytes(&self) -> usize {
        // rate + phase ledger (acked, lost) + last-feedback timestamp +
        // last-decrease timestamp: 5 registers at 6 B fixed-point
        30
    }

    /// Fluid epoch tick: the phase detector is time-driven (feedback
    /// silence), so it must advance even when no packet events exist.
    /// An epoch tick is NOT feedback — it must not refresh
    /// `last_feedback` (that would make periodic ticks during a compute
    /// gap suppress the very silence they should detect). It only checks
    /// the gap: the first tick past `idle_gap` rolls the ledger — the
    /// same boundary a packet engine detects on the first ACK of the
    /// next burst. During an active phase the per-epoch `AckBatch`es
    /// keep `last_feedback` fresh and this is a no-op.
    fn on_epoch(&mut self, ctx: &CcCtx) {
        if self.phase_id > 0
            && (ctx.now.saturating_sub(self.last_feedback)) as f64 > self.idle_gap
        {
            self.roll_phase();
            self.last_feedback = ctx.now;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(now: SimTime) -> CcCtx {
        CcCtx {
            now,
            qpn: 1,
            bytes: 0,
            hops: 2,
        }
    }

    fn ack(cc: &mut Dblp, now: SimTime, bytes: usize) {
        cc.on_signal(
            CcSignal::AckBatch {
                acked_bytes: bytes,
                marked: false,
            },
            &ctx(now),
        );
    }

    fn loss(cc: &mut Dblp, now: SimTime, timeout: bool) {
        cc.on_signal(CcSignal::LossHint { timeout }, &ctx(now));
    }

    /// The headline property: losses inside the phase budget do not move
    /// the rate at all.
    #[test]
    fn bounded_loss_holds_rate_within_budget() {
        let mut cc = Dblp::new(3.125, 5_000);
        ack(&mut cc, 1_000, 64 * 1024);
        let r0 = cc.rate();
        for i in 0..5 {
            loss(&mut cc, 2_000 + i * 100, false);
        }
        assert!(cc.within_budget());
        assert_eq!(cc.rate(), r0, "in-budget losses must not brake");
    }

    #[test]
    fn budget_exhaustion_brakes_multiplicatively() {
        let mut cc = Dblp::new(3.125, 5_000);
        ack(&mut cc, 1_000, 8 * 1024);
        let r0 = cc.rate();
        // floor is 16 KB + 2% of 8 KB ⇒ ~11 hints overrun it
        for i in 0..40 {
            loss(&mut cc, 10_000 + i * 10_000, false);
        }
        assert!(!cc.within_budget());
        assert!(cc.rate() < r0, "over-budget losses must brake");
        assert!(cc.rate() > 0.0);
    }

    #[test]
    fn timeout_always_brakes_even_in_budget() {
        let mut cc = Dblp::new(3.125, 5_000);
        ack(&mut cc, 1_000, 1024 * 1024);
        let r0 = cc.rate();
        loss(&mut cc, 2_000, true);
        assert!(cc.rate() < r0, "an RTO is never bounded loss");
    }

    /// Phase detection: feedback after an idle gap rolls the ledger and
    /// restores full rate.
    #[test]
    fn idle_gap_rolls_phase_and_resets_budget() {
        let mut cc = Dblp::new(3.125, 5_000);
        ack(&mut cc, 1_000, 4 * 1024);
        for i in 0..40 {
            loss(&mut cc, 2_000 + i * 10_000, false);
        }
        assert!(!cc.within_budget());
        let braked = cc.rate();
        assert!(braked < 3.125);
        let p = cc.phases_seen();
        // next ack lands well past idle_gap (4 × 5 µs = 20 µs)
        ack(&mut cc, 500_000_000, 4 * 1024);
        assert_eq!(cc.phases_seen(), p + 1, "gap must open a new phase");
        assert!(cc.within_budget(), "new phase starts with a clean ledger");
        assert_eq!(cc.rate(), 3.125, "new phase releases the brake");
    }

    /// Back-to-back feedback inside a phase must NOT roll the ledger.
    #[test]
    fn continuous_feedback_stays_in_one_phase() {
        let mut cc = Dblp::new(3.125, 5_000);
        for i in 0..100 {
            ack(&mut cc, 1_000 + i * 2_000, 1500); // 2 µs apart < 20 µs gap
        }
        assert_eq!(cc.phases_seen(), 1);
    }

    /// A timeout landing inside the first base_rtt of sim time must still
    /// cut the rate: the RTO brake bypasses the per-RTT limiter entirely.
    #[test]
    fn timeout_brakes_before_first_rtt_elapses() {
        let mut cc = Dblp::new(3.125, 5_000);
        ack(&mut cc, 100, 1024 * 1024);
        let r0 = cc.rate();
        loss(&mut cc, 200, true); // 200 ns << base_rtt = 5 µs
        assert!(cc.rate() < r0, "RTO brake must not be rate-limited");
    }

    /// The limiter must not swallow the very first congestion signal of
    /// the sim either: a mark before one base_rtt has elapsed still cuts.
    #[test]
    fn first_signal_passes_limiter_in_early_sim() {
        let mut cc = Dblp::new(3.125, 5_000);
        ack(&mut cc, 100, 1024);
        let r0 = cc.rate();
        cc.on_signal(CcSignal::EcnMark, &ctx(200));
        assert!(cc.rate() < r0, "first mark must pass the per-RTT limiter");
    }

    #[test]
    fn mark_applies_mild_brake() {
        let mut cc = Dblp::new(3.125, 5_000);
        ack(&mut cc, 1_000, 1024);
        let r0 = cc.rate();
        cc.on_signal(CcSignal::EcnMark, &ctx(50_000));
        assert!(cc.rate() < r0);
        assert!(cc.rate() > 0.5 * r0, "mark brake must be mild");
    }

    /// Fluid epoch cadence (PR 10): the idle-gap phase boundary must be
    /// detectable from epoch ticks alone — and ticks that land inside the
    /// gap must neither roll the phase nor refresh `last_feedback` (which
    /// would mask the silence and defer the boundary forever).
    #[test]
    fn on_epoch_detects_idle_gap_phase_boundary() {
        let mut cc = Dblp::new(3.125, 5_000);
        // a tick before any feedback must not open a phase
        cc.on_epoch(&ctx(1_000));
        assert_eq!(cc.phases_seen(), 0);
        // burn the budget so the brake is engaged, then go silent
        ack(&mut cc, 1_000, 4 * 1024);
        for i in 0..40 {
            loss(&mut cc, 2_000 + i * 100, false);
        }
        assert!(!cc.within_budget());
        let p = cc.phases_seen();
        let last_ack = 10_000;
        ack(&mut cc, last_ack, 1024);
        assert_eq!(cc.phases_seen(), p, "in-phase ack must not roll");
        // epoch ticks every base_rtt inside the 20 µs idle_gap: no roll
        for e in 1..=4u64 {
            cc.on_epoch(&ctx(last_ack + e * 5_000));
        }
        assert_eq!(cc.phases_seen(), p, "in-gap ticks must not roll");
        // first tick past the gap rolls once and releases the brake
        cc.on_epoch(&ctx(last_ack + 21_000));
        assert_eq!(cc.phases_seen(), p + 1, "gap tick must open a new phase");
        assert!(cc.within_budget(), "new phase starts with a clean ledger");
        assert_eq!(cc.rate(), 3.125, "new phase releases the brake");
    }

    /// Trait-surface sanity for the CC v2 plane: DBLP is sender-side only.
    #[test]
    fn plays_no_receiver_roles() {
        let mut cc = Dblp::new(3.125, 5_000);
        assert!(!cc.wants_cnp());
        assert!(!cc.announces_demand());
        assert!(cc.next_grant(4096).is_none());
        assert!(cc.try_send(usize::MAX / 2), "DBLP never credit-gates");
        assert!(cc.cwnd() > 0);
        assert!(cc.state_bytes() > 0);
    }
}
