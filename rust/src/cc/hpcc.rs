//! HPCC (Li et al., SIGCOMM'19): in-band-telemetry-driven precise CC.
//!
//! Switches stamp egress queue depth and a cumulative tx-byte counter into
//! data packets (the fabric's uniform `NetHints` header, stamped at
//! dequeue); receivers echo it on feedback. The sender reconstructs the
//! bottleneck's output rate from consecutive counter samples —
//! txRate = ΔtxBytes/ΔT, exactly the paper's INT arithmetic — and drives
//! link utilization U = qlen/(B·T_base) + txRate/B toward a target η < 1
//! with multiplicative adjustment plus a small additive probe. This is the
//! single-hop specialization of HPCC's per-link max — exact for our ToR
//! topology (`CcCtx::hops` = 2, one bottleneck). Because txRate measures
//! the port's *total* output (background tenants included), HPCC backs off
//! for traffic it cannot see in its own ACK stream.
//!
//! CC v2 signal subscription: `IntTelemetry` (the control law) and
//! `LossHint`. `EcnMark` is deliberately ignored — marks are already
//! folded into the qdepth telemetry HPCC reads, so reacting to both would
//! double-count congestion.

use crate::cc::{CcCtx, CcSignal, CongestionControl};
use crate::sim::SimTime;

#[derive(Debug)]
pub struct Hpcc {
    line_rate: f64,
    base_rtt: f64,
    rate: f64,
    /// Target utilization η.
    eta: f64,
    /// EWMA of estimated utilization.
    u_ewma: f64,
    /// Additive probe, bytes/ns.
    wai: f64,
    last_update: SimTime,
    /// Previous INT sample: (observation time, port cumulative tx bytes).
    last_int: Option<(SimTime, u64)>,
    /// Bottleneck output rate reconstructed from the INT counter, bytes/ns.
    txrate: f64,
    /// Loss cuts are rate-limited to one per base RTT, like every other
    /// multiplicative update in this law.
    last_loss: SimTime,
}

impl Hpcc {
    pub fn new(line_rate: f64, base_rtt: u64) -> Hpcc {
        Hpcc {
            line_rate,
            base_rtt: base_rtt as f64,
            rate: line_rate,
            eta: 0.95,
            u_ewma: 0.0,
            wai: line_rate / 100.0,
            last_update: 0,
            last_int: None,
            txrate: 0.0,
            last_loss: 0,
        }
    }

    /// Measured bottleneck output rate (bytes/ns) from the last two INT
    /// counter samples.
    pub fn txrate(&self) -> f64 {
        self.txrate
    }

    fn on_int(&mut self, now: SimTime, qdepth: u32, tx_bytes: u64, link_rate: f64) {
        // reconstruct the port's output rate from the cumulative counter
        // (ΔtxBytes/ΔT); same-timestamp samples reuse the last estimate
        match self.last_int {
            Some((t, b)) if now > t => {
                self.txrate = tx_bytes.saturating_sub(b) as f64 / (now - t) as f64;
                self.last_int = Some((now, tx_bytes.max(b)));
            }
            Some(_) => {}
            None => self.last_int = Some((now, tx_bytes)),
        }
        // utilization estimate from INT: queued bytes normalized by the
        // *stamped* link's BDP, plus the measured share of that link —
        // the telemetry is self-contained, B comes from the signal
        let bdp = link_rate * self.base_rtt;
        let u = qdepth as f64 / bdp + self.txrate / link_rate;
        self.u_ewma = if self.u_ewma == 0.0 {
            u
        } else {
            0.2 * u + 0.8 * self.u_ewma
        };
        // at most one multiplicative update per base RTT
        if (now as f64 - self.last_update as f64) < self.base_rtt {
            return;
        }
        self.last_update = now;
        if self.u_ewma > 1e-9 {
            self.rate = (self.rate * self.eta / self.u_ewma + self.wai)
                .clamp(self.line_rate / 1000.0, self.line_rate);
        }
    }
}

impl CongestionControl for Hpcc {
    fn name(&self) -> &'static str {
        "HPCC"
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn cwnd(&self) -> usize {
        // HPCC's window form: W = η·BDP scaled by the current rate share
        (self.rate * self.base_rtt.max(1.0)) as usize
    }

    fn on_signal(&mut self, sig: CcSignal, ctx: &CcCtx) {
        match sig {
            CcSignal::IntTelemetry {
                qdepth,
                tx_bytes,
                link_rate,
            } => self.on_int(ctx.now, qdepth, tx_bytes, link_rate),
            CcSignal::LossHint { timeout } => {
                // one loss cut per base RTT: gap-detection hints can fire
                // per ACK and must not compound within a window
                if (ctx.now as f64 - self.last_loss as f64) < self.base_rtt {
                    return;
                }
                self.last_loss = ctx.now;
                let f = if timeout { 0.5 } else { 0.8 };
                self.rate = (self.rate * f).max(self.line_rate / 1000.0);
            }
            _ => {}
        }
    }

    fn state_bytes(&self) -> usize {
        // rate, U ewma, last INT sample (time + counter), txrate — HPCC
        // needs a bit more than DCQCN per QP
        28
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn int(cc: &mut Hpcc, now: SimTime, qdepth: u32, tx_bytes: u64) {
        cc.on_signal(
            CcSignal::IntTelemetry {
                qdepth,
                tx_bytes,
                link_rate: 3.125,
            },
            &CcCtx {
                now,
                qpn: 1,
                bytes: 1500,
                hops: 2,
            },
        );
    }

    /// Walk the INT counter forward at `share` of line rate with constant
    /// `qdepth`, one sample every 10 µs.
    fn feed(cc: &mut Hpcc, from: u64, samples: u64, qdepth: u32, share: f64) -> u64 {
        let step_ns = 10_000u64;
        let mut tx = (from as f64 * step_ns as f64 * 3.125 * share) as u64;
        for i in from..from + samples {
            tx += (step_ns as f64 * 3.125 * share) as u64;
            int(cc, i * step_ns, qdepth, tx);
        }
        from + samples
    }

    #[test]
    fn idle_port_keeps_line_rate() {
        let mut cc = Hpcc::new(3.125, 5_000);
        // empty queue, idle port: nothing to back off for
        feed(&mut cc, 1, 100, 0, 0.0);
        assert!(cc.rate() > 0.9 * 3.125, "rate={}", cc.rate());
    }

    #[test]
    fn port_at_target_utilization_holds_near_line() {
        let mut cc = Hpcc::new(3.125, 5_000);
        // port output sitting exactly at η with empty queues: U ≈ η, the
        // multiplicative term is neutral and the probe pushes toward line
        feed(&mut cc, 1, 200, 0, 0.95);
        assert!(cc.rate() > 0.9 * 3.125, "rate={}", cc.rate());
    }

    #[test]
    fn saturated_port_backs_off() {
        let mut cc = Hpcc::new(3.125, 5_000);
        // a port pinned at full line rate (other tenants included): U ≈ 1
        // > η, so the sender trims its share multiplicatively until only
        // the additive probe sustains it
        feed(&mut cc, 1, 200, 0, 1.0);
        assert!(
            cc.rate() < 0.8 * 3.125 && cc.rate() > 0.1 * 3.125,
            "rate={}",
            cc.rate()
        );
        assert!(cc.txrate() > 2.9, "measured txrate={}", cc.txrate());
    }

    #[test]
    fn deep_queues_cut_rate() {
        let mut cc = Hpcc::new(3.125, 5_000);
        // deep queue vs BDP=15625, port saturated
        feed(&mut cc, 1, 50, 200_000, 1.0);
        assert!(cc.rate() < 1.0, "rate={}", cc.rate());
    }

    #[test]
    fn recovers_when_queue_drains() {
        let mut cc = Hpcc::new(3.125, 5_000);
        let next = feed(&mut cc, 1, 50, 200_000, 1.0);
        let low = cc.rate();
        feed(&mut cc, next, 250, 0, 0.1);
        assert!(cc.rate() > low);
    }

    #[test]
    fn updates_rate_limited_per_rtt() {
        let mut cc = Hpcc::new(3.125, 1_000_000);
        int(&mut cc, 10, 500_000, 0);
        let r = cc.rate();
        int(&mut cc, 20, 500_000, 100);
        assert_eq!(cc.rate(), r);
    }

    #[test]
    fn same_timestamp_samples_do_not_divide_by_zero() {
        let mut cc = Hpcc::new(3.125, 5_000);
        int(&mut cc, 1_000, 0, 5_000);
        int(&mut cc, 1_000, 0, 9_000); // coalesced echo, same stamp
        assert!(cc.rate() > 0.0);
        assert!(cc.txrate() >= 0.0);
    }

    #[test]
    fn epoch_cadence_int_from_virtual_queues_steers_rate() {
        // the fluid plane synthesizes IntTelemetry once per base RTT from
        // its virtual-queue and tx-byte integrals: qdepth is the
        // time-averaged bottleneck vq, tx_bytes its transmit integral.
        // The law must steer on exactly that cadence — deep vq backs
        // off, drained vq plus idle port recovers.
        let mut cc = Hpcc::new(3.125, 5_000);
        let step = 5_000u64; // one sample per base RTT, the epoch cadence
        let mut tx = 0u64;
        let mut t = 0u64;
        for _ in 0..60 {
            t += step;
            tx += (step as f64 * 3.125) as u64; // port saturated
            int(&mut cc, t, 120_000, tx); // vq far past BDP = 15625
        }
        let low = cc.rate();
        assert!(low < 1.0, "deep virtual queues must back off, rate={low}");
        for _ in 0..400 {
            t += step;
            tx += (step as f64 * 0.1) as u64; // port nearly idle
            int(&mut cc, t, 0, tx); // vq drained
        }
        assert!(cc.rate() > low, "drained vq must recover");
        // the epoch tick itself carries no INT — no rate movement
        let r = cc.rate();
        cc.on_epoch(&CcCtx { now: t + step, qpn: 1, bytes: 0, hops: 2 });
        assert_eq!(cc.rate(), r);
    }

    #[test]
    fn marks_are_ignored_int_is_authoritative() {
        let mut cc = Hpcc::new(3.125, 5_000);
        let r0 = cc.rate();
        cc.on_signal(
            CcSignal::EcnMark,
            &CcCtx {
                now: 100_000,
                qpn: 1,
                bytes: 0,
                hops: 2,
            },
        );
        assert_eq!(cc.rate(), r0, "HPCC reads INT, not marks");
    }
}
