//! HPCC (Li et al., SIGCOMM'19): in-band-telemetry-driven precise CC.
//!
//! Switches stamp egress queue depth into data packets (our fabric stamps
//! `tele_qlen` at dequeue); receivers echo it on feedback. The sender
//! computes link utilization U = qlen/(B·T_base) + rate/B and drives U to a
//! target η < 1 with multiplicative adjustment plus a small additive probe.
//! This is the single-hop specialization of HPCC's per-link max — exact for
//! our ToR topology.

use crate::cc::{AckFeedback, CongestionControl};
use crate::sim::SimTime;

#[derive(Debug)]
pub struct Hpcc {
    line_rate: f64,
    base_rtt: f64,
    rate: f64,
    /// Target utilization η.
    eta: f64,
    /// EWMA of estimated utilization.
    u_ewma: f64,
    /// Additive probe, bytes/ns.
    wai: f64,
    last_update: SimTime,
}

impl Hpcc {
    pub fn new(line_rate: f64, base_rtt: u64) -> Hpcc {
        Hpcc {
            line_rate,
            base_rtt: base_rtt as f64,
            rate: line_rate,
            eta: 0.95,
            u_ewma: 0.0,
            wai: line_rate / 100.0,
            last_update: 0,
        }
    }
}

impl CongestionControl for Hpcc {
    fn name(&self) -> &'static str {
        "HPCC"
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn on_ack(&mut self, fb: AckFeedback) {
        // utilization estimate from INT: queued bytes normalized by BDP,
        // plus our own share of the link
        let bdp = self.line_rate * self.base_rtt;
        let u = fb.tele_qlen as f64 / bdp + self.rate / self.line_rate;
        self.u_ewma = if self.u_ewma == 0.0 {
            u
        } else {
            0.2 * u + 0.8 * self.u_ewma
        };
        // at most one multiplicative update per base RTT
        if (fb.now as f64 - self.last_update as f64) < self.base_rtt {
            return;
        }
        self.last_update = fb.now;
        if self.u_ewma > 1e-9 {
            self.rate = (self.rate * self.eta / self.u_ewma + self.wai)
                .clamp(self.line_rate / 1000.0, self.line_rate);
        }
    }

    fn on_cnp(&mut self, _now: SimTime) {
        self.rate = (self.rate * 0.8).max(self.line_rate / 1000.0);
    }

    fn on_timeout(&mut self, _now: SimTime) {
        self.rate = (self.rate * 0.5).max(self.line_rate / 1000.0);
    }

    fn state_bytes(&self) -> usize {
        // rate, U ewma, last_update, reference counters — HPCC needs a bit
        // more than DCQCN per QP
        28
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fb(now: SimTime, qlen: u32) -> AckFeedback {
        AckFeedback {
            now,
            rtt_ns: None,
            ecn_echo: false,
            acked_bytes: 1500,
            tele_qlen: qlen,
        }
    }

    #[test]
    fn empty_queues_keep_line_rate() {
        let mut cc = Hpcc::new(3.125, 5_000);
        for i in 0..100 {
            cc.on_ack(fb(i * 10_000, 0));
        }
        // U ≈ rate/line = 1 > η=0.95 slightly cuts, then stabilizes near η
        assert!(cc.rate() > 0.85 * 3.125, "rate={}", cc.rate());
    }

    #[test]
    fn deep_queues_cut_rate() {
        let mut cc = Hpcc::new(3.125, 5_000);
        for i in 0..50 {
            cc.on_ack(fb(i * 10_000, 200_000)); // deep queue vs BDP=15625
        }
        assert!(cc.rate() < 1.0, "rate={}", cc.rate());
    }

    #[test]
    fn recovers_when_queue_drains() {
        let mut cc = Hpcc::new(3.125, 5_000);
        for i in 0..50 {
            cc.on_ack(fb(i * 10_000, 200_000));
        }
        let low = cc.rate();
        for i in 50..300 {
            cc.on_ack(fb(i * 10_000, 0));
        }
        assert!(cc.rate() > low);
    }

    #[test]
    fn updates_rate_limited_per_rtt() {
        let mut cc = Hpcc::new(3.125, 1_000_000);
        cc.on_ack(fb(10, 500_000));
        let r = cc.rate();
        cc.on_ack(fb(20, 500_000));
        assert_eq!(cc.rate(), r);
    }
}
