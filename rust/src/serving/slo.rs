//! SLO metrics for the open-loop serving subsystem.
//!
//! Per tenant: TTFT (first token minus *true arrival time* — queueing
//! delay is measured from when the request entered the system, never
//! from when its batch formed), TPOT (decode time per generated token),
//! queueing delay, and the fraction of completed requests meeting the
//! configured targets. Tails are reported at p50/p99/p99.9 (the
//! `Samples::p999` satellite). KV-cache migration traffic between the
//! prefill and decode pools is accounted here too, so a serving row can
//! assert "bytes moved between pools > 0".
//!
//! Everything in this module is a pure function of simulated quantities:
//! `SloReport::to_json` output is byte-identical across runs, schedulers,
//! and sweep worker counts.

use crate::sim::SimTime;
use crate::util::json::Json;
use crate::util::stats::Samples;

/// Per-request latency targets. A completed request attains its SLO when
/// BOTH its TTFT and its TPOT are within target.
#[derive(Clone, Copy, Debug)]
pub struct SloTargets {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        // calibrated to the simulated scale (V100-class pools, §5.1.1
        // fabrics): an unloaded prefill round lands well under 20 ms and
        // a decode step near 1 ms, so these targets leave headroom that
        // congestion and bursts then eat into.
        SloTargets {
            ttft_ms: 20.0,
            tpot_ms: 4.0,
        }
    }
}

/// Joined per-request record (prefill side + decode side).
#[derive(Clone, Copy, Debug)]
pub struct RequestRecord {
    pub tenant: usize,
    pub ttft_ns: SimTime,
    pub queue_delay_ns: SimTime,
    pub tpot_ns: f64,
    pub output_tokens: usize,
}

/// Accumulated metrics for one tenant.
#[derive(Debug, Default)]
pub struct TenantMetrics {
    pub name: String,
    pub ttft_ns: Samples,
    pub tpot_ns: Samples,
    pub queue_delay_ns: Samples,
    pub completed: usize,
    pub slo_ok: usize,
}

impl TenantMetrics {
    pub fn attainment(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.slo_ok as f64 / self.completed as f64
        }
    }
}

/// The serving run's result surface: per-tenant metrics plus pool-level
/// KV-migration and throughput accounting.
#[derive(Debug, Default)]
pub struct SloReport {
    pub tenants: Vec<TenantMetrics>,
    /// KV-cache bytes that actually landed in the decode pool.
    pub kv_bytes_moved: u64,
    /// KV-cache bytes lost to bounded completion / transport failure.
    pub kv_bytes_lost: u64,
    pub kv_transfers: usize,
    pub tokens_generated: u64,
    pub requests_offered: usize,
    pub requests_completed: usize,
    pub total_sim_ns: SimTime,
}

impl SloReport {
    pub fn new(tenant_names: &[String]) -> SloReport {
        SloReport {
            tenants: tenant_names
                .iter()
                .map(|n| TenantMetrics {
                    name: n.clone(),
                    ..TenantMetrics::default()
                })
                .collect(),
            ..SloReport::default()
        }
    }

    /// Fold one completed request into its tenant's samples and score it
    /// against the targets.
    pub fn record(&mut self, r: &RequestRecord, slo: &SloTargets) {
        let t = &mut self.tenants[r.tenant];
        t.ttft_ns.push(r.ttft_ns as f64);
        t.tpot_ns.push(r.tpot_ns);
        t.queue_delay_ns.push(r.queue_delay_ns as f64);
        t.completed += 1;
        let ok = (r.ttft_ns as f64) <= slo.ttft_ms * 1e6 && r.tpot_ns <= slo.tpot_ms * 1e6;
        if ok {
            t.slo_ok += 1;
        }
        self.requests_completed += 1;
    }

    pub fn throughput_tps(&self) -> f64 {
        if self.total_sim_ns == 0 {
            0.0
        } else {
            self.tokens_generated as f64 / (self.total_sim_ns as f64 / 1e9)
        }
    }

    /// Deterministic JSON: one row per tenant (p50/p99/p99.9 TTFT and
    /// TPOT, queue-delay tail, attainment) plus the pool-level counters.
    pub fn to_json(&mut self) -> Json {
        let mut rows = Vec::with_capacity(self.tenants.len());
        for t in &mut self.tenants {
            let mut row = Json::obj();
            row.set("tenant", t.name.as_str())
                .set("completed", t.completed)
                .set("ttft_p50_ns", t.ttft_ns.p50())
                .set("ttft_p99_ns", t.ttft_ns.p99())
                .set("ttft_p999_ns", t.ttft_ns.p999())
                .set("tpot_p50_ns", t.tpot_ns.p50())
                .set("tpot_p99_ns", t.tpot_ns.p99())
                .set("tpot_p999_ns", t.tpot_ns.p999())
                .set("queue_delay_p50_ns", t.queue_delay_ns.p50())
                .set("queue_delay_p99_ns", t.queue_delay_ns.p99())
                .set("slo_attainment", t.attainment());
            rows.push(row);
        }
        let mut o = Json::obj();
        o.set("tenants", Json::Arr(rows))
            .set("kv_bytes_moved", self.kv_bytes_moved)
            .set("kv_bytes_lost", self.kv_bytes_lost)
            .set("kv_transfers", self.kv_transfers)
            .set("tokens_generated", self.tokens_generated)
            .set("requests_offered", self.requests_offered)
            .set("requests_completed", self.requests_completed)
            .set("total_sim_ns", self.total_sim_ns)
            .set("throughput_tps", self.throughput_tps());
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(tenant: usize, ttft_ms: f64, tpot_ms: f64) -> RequestRecord {
        RequestRecord {
            tenant,
            ttft_ns: (ttft_ms * 1e6) as SimTime,
            queue_delay_ns: (ttft_ms * 0.5 * 1e6) as SimTime,
            tpot_ns: tpot_ms * 1e6,
            output_tokens: 4,
        }
    }

    #[test]
    fn attainment_counts_both_targets() {
        let slo = SloTargets {
            ttft_ms: 20.0,
            tpot_ms: 4.0,
        };
        let mut rep = SloReport::new(&["a".into()]);
        rep.record(&rec(0, 10.0, 2.0), &slo); // ok
        rep.record(&rec(0, 30.0, 2.0), &slo); // ttft miss
        rep.record(&rec(0, 10.0, 8.0), &slo); // tpot miss
        rep.record(&rec(0, 19.9, 3.9), &slo); // ok
        assert_eq!(rep.tenants[0].completed, 4);
        assert_eq!(rep.tenants[0].slo_ok, 2);
        assert!((rep.tenants[0].attainment() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn report_json_is_deterministic_and_per_tenant() {
        let slo = SloTargets::default();
        let build = || {
            let mut rep = SloReport::new(&["chat".into(), "batch".into()]);
            for i in 0..50 {
                rep.record(&rec(i % 2, 1.0 + i as f64 * 0.3, 1.0), &slo);
            }
            rep.kv_bytes_moved = 123_456;
            rep.kv_transfers = 50;
            rep.tokens_generated = 200;
            rep.total_sim_ns = 1_000_000_000;
            rep.to_json().to_string_pretty()
        };
        let a = build();
        assert_eq!(a, build());
        assert!(a.contains("\"tenant\": \"chat\""));
        assert!(a.contains("\"ttft_p999_ns\""));
        assert!(a.contains("\"slo_attainment\""));
    }

    #[test]
    fn throughput_from_sim_time() {
        let mut rep = SloReport::new(&["a".into()]);
        rep.tokens_generated = 500;
        rep.total_sim_ns = 2 * crate::sim::SEC;
        assert!((rep.throughput_tps() - 250.0).abs() < 1e-9);
    }
}
