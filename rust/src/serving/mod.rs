//! Open-loop serving subsystem: multi-tenant workload generation
//! ([`workload`]), a disaggregated prefill/decode pool model with
//! KV-cache migration over the simulated fabric ([`pool`]), and the SLO
//! metrics layer ([`slo`]).
//!
//! The question this subsystem exists to answer (ROADMAP item 2): what
//! SLO attainment does OptiNIC's bounded completion buy over the
//! reliable RoCE family when requests arrive *open-loop* — at a rate the
//! system does not control — and multiple tenants plus background
//! traffic share one fabric? The closed-loop Fig 4 path
//! (`coordinator/serve.rs`) remains as a compatibility mode; it
//! measures service capacity, not SLO attainment.
//!
//! [`ServingCell`] is the one-struct experiment spec shared by the
//! `serve_sweep` bench, the `optinic serve --qps ...` CLI path, and the
//! determinism/jobs-parity tests, so all three run byte-identical cells.

pub mod pool;
pub mod slo;
pub mod workload;

pub use pool::{run_serving, ModelDims, PoolCfg, ServingCfg};
pub use slo::{SloReport, SloTargets};
pub use workload::{ArrivalKind, Request, TenantCfg};

use crate::net::fabric::FabricCfg;
use crate::sim::cluster::{Cluster, ClusterCfg};
use crate::sim::SchedKind;
use crate::transport::TransportKind;
use crate::util::json::Json;

/// One fully-specified serving experiment: transport × arrival process ×
/// topology, plus load knobs. `run_serving_cell` is a pure function of
/// this struct — cells can run on any sweep worker in any order.
#[derive(Clone, Debug)]
pub struct ServingCell {
    pub transport: TransportKind,
    pub arrival: ArrivalKind,
    /// false = single-switch CloudLab fabric; true = leaf-spine.
    pub leaf_spine: bool,
    /// Aggregate offered load across all tenants, requests/s.
    pub qps: f64,
    pub tenants: usize,
    pub requests_per_tenant: usize,
    pub bg_load: f64,
    pub slo: SloTargets,
    pub seed: u64,
    pub scheduler: SchedKind,
}

impl ServingCell {
    pub fn new(transport: TransportKind, arrival: ArrivalKind, leaf_spine: bool) -> ServingCell {
        ServingCell {
            transport,
            arrival,
            leaf_spine,
            qps: 400.0,
            tenants: 2,
            requests_per_tenant: 24,
            bg_load: 0.2,
            slo: SloTargets::default(),
            seed: 7,
            scheduler: SchedKind::Wheel,
        }
    }

    pub fn topo_name(&self) -> &'static str {
        if self.leaf_spine {
            "leaf-spine"
        } else {
            "single-switch"
        }
    }

    /// The tenant set: aggregate QPS split evenly, every tenant on the
    /// cell's arrival process, deterministic names.
    pub fn tenant_cfgs(&self) -> Vec<TenantCfg> {
        let n = self.tenants.max(1);
        (0..n)
            .map(|i| TenantCfg::new(&format!("tenant{i}"), self.qps / n as f64, self.arrival))
            .collect()
    }
}

/// Run one serving cell end to end and emit its labeled result row.
/// Deterministic: byte-identical output for the same cell, across
/// schedulers and sweep worker counts.
pub fn run_serving_cell(cell: &ServingCell) -> Json {
    let mut scfg = ServingCfg::new(cell.tenant_cfgs(), cell.requests_per_tenant);
    scfg.slo = cell.slo;
    scfg.seed = cell.seed;

    let mut fabric = FabricCfg::cloudlab(scfg.nodes());
    if cell.leaf_spine {
        fabric = fabric.with_leaf_spine(2, 2);
    }
    let ccfg = ClusterCfg::new(fabric, cell.transport)
        .with_seed(cell.seed)
        .with_bg_load(cell.bg_load)
        .with_scheduler(cell.scheduler);
    let mut cluster = Cluster::new(ccfg);
    let mut report = run_serving(&mut cluster, &scfg);

    let mut out = Json::obj();
    out.set("transport", cell.transport.name())
        .set("arrival", cell.arrival.name())
        .set("topo", cell.topo_name())
        .set("qps", cell.qps)
        .set("bg_load", cell.bg_load)
        .set("slo", report.to_json())
        .set("events_processed", cluster.events_processed);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cell(transport: TransportKind) -> ServingCell {
        let mut cell = ServingCell::new(transport, ArrivalKind::Poisson, false);
        cell.requests_per_tenant = 8;
        cell.bg_load = 0.1;
        cell
    }

    /// The acceptance-critical property: a cell completes every request
    /// and moves KV bytes between the pools — for the bounded transport
    /// and for a reliable one.
    #[test]
    fn cells_complete_and_move_kv_bytes() {
        for transport in [TransportKind::Optinic, TransportKind::Roce] {
            let out = run_serving_cell(&quick_cell(transport));
            let slo = out.get("slo").unwrap();
            let offered = slo.get("requests_offered").unwrap().as_i64().unwrap();
            let done = slo.get("requests_completed").unwrap().as_i64().unwrap();
            assert_eq!(offered, 16, "{transport:?}");
            assert_eq!(done, offered, "{transport:?}");
            assert!(
                slo.get("kv_bytes_moved").unwrap().as_i64().unwrap() > 0,
                "{transport:?}: no KV bytes moved between pools"
            );
            assert!(slo.get("tokens_generated").unwrap().as_i64().unwrap() > done);
        }
    }

    /// Replay determinism for the full serving stack, including the
    /// wheel-vs-heap scheduler A/B (satellite 3).
    #[test]
    fn serving_cell_replays_byte_identical_across_schedulers() {
        let mk = |sched| {
            let mut cell = quick_cell(TransportKind::Optinic);
            cell.arrival = ArrivalKind::diurnal_default();
            cell.scheduler = sched;
            run_serving_cell(&cell).to_string_pretty()
        };
        let a = mk(SchedKind::Wheel);
        let b = mk(SchedKind::Wheel);
        let h = mk(SchedKind::Heap);
        assert_eq!(a, b, "same-scheduler replay diverged");
        assert_eq!(a, h, "wheel vs heap diverged");
    }

    /// Leaf-spine topology runs the same workload to completion.
    #[test]
    fn leaf_spine_cell_completes() {
        let mut cell = quick_cell(TransportKind::Optinic);
        cell.leaf_spine = true;
        let out = run_serving_cell(&cell);
        let slo = out.get("slo").unwrap();
        assert_eq!(
            slo.get("requests_completed").unwrap().as_i64().unwrap(),
            slo.get("requests_offered").unwrap().as_i64().unwrap()
        );
        assert_eq!(out.get("topo").unwrap().as_str().unwrap(), "leaf-spine");
    }

    /// Per-tenant rows exist and carry the tail percentiles the SLO layer
    /// promises.
    #[test]
    fn report_rows_are_per_tenant_with_tails() {
        let out = run_serving_cell(&quick_cell(TransportKind::Roce));
        let slo = out.get("slo").unwrap();
        let rows = match slo.get("tenants").unwrap() {
            Json::Arr(rows) => rows,
            other => panic!("tenants not an array: {other:?}"),
        };
        assert_eq!(rows.len(), 2);
        for row in rows {
            assert!(row.get("ttft_p999_ns").is_some());
            assert!(row.get("tpot_p999_ns").is_some());
            assert!(row.get("slo_attainment").is_some());
            assert!(row.get("queue_delay_p99_ns").is_some());
        }
    }
}
