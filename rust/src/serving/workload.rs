//! Open-loop serving workload generator: multi-tenant request arrival
//! processes over simulated time.
//!
//! Closed-loop drivers (the Fig 4 path) regulate offered load by waiting
//! for the system — they can never overload it, so they cannot ask the
//! SLO question. This module generates arrivals *independent of service*:
//! Poisson (memoryless, constant rate) and diurnal (sinusoidally
//! rate-modulated Poisson via Lewis–Shedler thinning, the "synchronized
//! burst" shape §2.1 worries about). Each tenant draws from its own
//! forked PRNG stream, so adding a tenant never perturbs another
//! tenant's arrival sequence, and the merged trace is a pure function of
//! `(tenants, per_tenant, seed)`.

use crate::sim::SimTime;
use crate::util::prng::Pcg64;

/// Arrival process shape for one tenant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalKind {
    /// Homogeneous Poisson process at the tenant's QPS.
    Poisson,
    /// Sinusoidally modulated Poisson: rate(t) = qps · (1 + amplitude ·
    /// sin(2πt/period − π/2)). Starts at the trough, peaks mid-period —
    /// the mean rate over a full period is still `qps`.
    Diurnal {
        period_ms: u64,
        /// Peak-to-mean rate swing in [0, 1): 0.8 ⇒ peaks at 1.8×, troughs
        /// at 0.2× the mean rate.
        amplitude_milli: u32,
    },
}

impl ArrivalKind {
    /// The default "day" is compressed to figure scale: 200 ms period so a
    /// sub-second simulation sees full peak/trough cycles.
    pub fn diurnal_default() -> ArrivalKind {
        ArrivalKind::Diurnal {
            period_ms: 200,
            amplitude_milli: 800,
        }
    }

    pub fn parse(s: &str) -> Option<ArrivalKind> {
        match s {
            "poisson" => Some(ArrivalKind::Poisson),
            "diurnal" | "bursty" => Some(ArrivalKind::diurnal_default()),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Diurnal { .. } => "diurnal",
        }
    }

    fn amplitude(&self) -> f64 {
        match self {
            ArrivalKind::Poisson => 0.0,
            ArrivalKind::Diurnal {
                amplitude_milli, ..
            } => *amplitude_milli as f64 / 1000.0,
        }
    }

    /// Instantaneous rate (requests per ns) at simulated time `t_ns` for a
    /// tenant with mean rate `qps`. Exposed so tests can pin the envelope.
    pub fn rate_at(&self, qps: f64, t_ns: SimTime) -> f64 {
        let mean = qps / 1e9;
        match self {
            ArrivalKind::Poisson => mean,
            ArrivalKind::Diurnal { period_ms, .. } => {
                let period_ns = (*period_ms as f64) * 1e6;
                let phase = std::f64::consts::TAU * (t_ns as f64) / period_ns
                    - std::f64::consts::FRAC_PI_2;
                mean * (1.0 + self.amplitude() * phase.sin())
            }
        }
    }
}

/// One model tenant: an independent arrival process plus request-shape
/// distributions, sharing the fabric with every other tenant (and with
/// PR 5's background traffic).
#[derive(Clone, Debug)]
pub struct TenantCfg {
    pub name: String,
    /// Mean request rate, requests per second of simulated time.
    pub qps: f64,
    pub arrival: ArrivalKind,
    /// Mean prompt length (tokens); lengths are exponential-ish, capped
    /// at 4× the mean so KV staging buffers stay bounded.
    pub prompt_tokens_mean: usize,
    /// Mean decode length (tokens ≥ 1, same cap).
    pub output_tokens_mean: usize,
}

impl TenantCfg {
    pub fn new(name: &str, qps: f64, arrival: ArrivalKind) -> TenantCfg {
        TenantCfg {
            name: name.to_string(),
            qps,
            arrival,
            prompt_tokens_mean: 64,
            output_tokens_mean: 8,
        }
    }

    /// Hard cap applied to sampled prompt lengths (KV buffer sizing).
    pub fn prompt_tokens_cap(&self) -> usize {
        (4 * self.prompt_tokens_mean).max(1)
    }

    pub fn output_tokens_cap(&self) -> usize {
        (4 * self.output_tokens_mean).max(1)
    }
}

/// One request in the merged open-loop trace. `id` is the global index in
/// arrival order (ties broken by tenant index — deterministic).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: usize,
    pub tenant: usize,
    pub arrival_ns: SimTime,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

/// Sample a capped-exponential token count with mean `mean`, min 1.
fn sample_tokens(rng: &mut Pcg64, mean: usize, cap: usize) -> usize {
    let x = rng.exponential(1.0 / mean.max(1) as f64);
    (x.round() as usize).clamp(1, cap)
}

/// Generate `per_tenant` requests for each tenant and merge them into one
/// arrival-ordered trace. Pure function of its arguments: each tenant
/// draws from `Pcg64::new(seed, 0xA221 ^ tenant_index)`.
pub fn generate(tenants: &[TenantCfg], per_tenant: usize, seed: u64) -> Vec<Request> {
    let mut all: Vec<Request> = Vec::with_capacity(tenants.len() * per_tenant);
    for (ti, t) in tenants.iter().enumerate() {
        let mut rng = Pcg64::new(seed, 0xA221 ^ ti as u64);
        // Lewis–Shedler thinning against the peak rate; for Poisson the
        // acceptance probability is identically 1.
        let peak = (t.qps / 1e9) * (1.0 + t.arrival.amplitude());
        let mut clock = 0.0f64;
        for _ in 0..per_tenant {
            loop {
                clock += rng.exponential(peak);
                let accept = t.arrival.rate_at(t.qps, clock as SimTime) / peak;
                if rng.chance(accept) {
                    break;
                }
            }
            all.push(Request {
                id: 0, // assigned after the merge sort
                tenant: ti,
                arrival_ns: clock as SimTime,
                prompt_tokens: sample_tokens(
                    &mut rng,
                    t.prompt_tokens_mean,
                    t.prompt_tokens_cap(),
                ),
                output_tokens: sample_tokens(
                    &mut rng,
                    t.output_tokens_mean,
                    t.output_tokens_cap(),
                ),
            });
        }
    }
    all.sort_by_key(|r| (r.arrival_ns, r.tenant));
    for (i, r) in all.iter_mut().enumerate() {
        r.id = i;
    }
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_tenant(qps: f64, arrival: ArrivalKind) -> Vec<TenantCfg> {
        vec![TenantCfg::new("t0", qps, arrival)]
    }

    /// Poisson pin: interarrival mean within 5% of 1/qps and coefficient
    /// of variation within 10% of 1 (the memoryless signature).
    #[test]
    fn poisson_interarrival_mean_and_cv() {
        let reqs = generate(&one_tenant(1000.0, ArrivalKind::Poisson), 20_000, 3);
        let gaps: Vec<f64> = reqs
            .windows(2)
            .map(|w| (w[1].arrival_ns - w[0].arrival_ns) as f64)
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        let expect = 1e9 / 1000.0;
        assert!((mean - expect).abs() / expect < 0.05, "mean={mean}");
        assert!((cv - 1.0).abs() < 0.10, "cv={cv}");
    }

    /// Diurnal envelope: arrivals binned by phase quarter must track the
    /// configured rate curve — the peak quarter (centered mid-period)
    /// carries several times the trough quarter, and the whole trace
    /// still averages out to ~qps.
    #[test]
    fn diurnal_envelope_tracks_rate_curve() {
        let arrival = ArrivalKind::Diurnal {
            period_ms: 10,
            amplitude_milli: 800,
        };
        let reqs = generate(&one_tenant(2000.0, arrival), 20_000, 9);
        let period_ns = 10 * 1_000_000u64;
        let mut quarters = [0usize; 4];
        for r in &reqs {
            quarters[((r.arrival_ns % period_ns) * 4 / period_ns) as usize] += 1;
        }
        // rate(t) troughs at the period boundary and peaks mid-period, so
        // the two middle quarters dominate the two outer ones
        let peak = quarters[1] + quarters[2];
        let trough = quarters[0] + quarters[3];
        assert!(
            peak as f64 > 2.5 * trough as f64,
            "quarters={quarters:?}"
        );
        // mean rate over whole periods ≈ qps
        let span_s = reqs.last().unwrap().arrival_ns as f64 / 1e9;
        let rate = reqs.len() as f64 / span_s;
        assert!((rate - 2000.0).abs() / 2000.0 < 0.05, "rate={rate}");
    }

    #[test]
    fn generation_is_deterministic_and_tenant_independent() {
        let tenants = vec![
            TenantCfg::new("chat", 800.0, ArrivalKind::Poisson),
            TenantCfg::new("batch", 200.0, ArrivalKind::diurnal_default()),
        ];
        let a = generate(&tenants, 500, 42);
        let b = generate(&tenants, 500, 42);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                (x.id, x.tenant, x.arrival_ns, x.prompt_tokens, x.output_tokens),
                (y.id, y.tenant, y.arrival_ns, y.prompt_tokens, y.output_tokens)
            );
        }
        // adding a tenant must not perturb tenant 0's stream
        let mut three = tenants.clone();
        three.push(TenantCfg::new("extra", 100.0, ArrivalKind::Poisson));
        let c = generate(&three, 500, 42);
        let a0: Vec<SimTime> =
            a.iter().filter(|r| r.tenant == 0).map(|r| r.arrival_ns).collect();
        let c0: Vec<SimTime> =
            c.iter().filter(|r| r.tenant == 0).map(|r| r.arrival_ns).collect();
        assert_eq!(a0, c0);
    }

    #[test]
    fn trace_is_sorted_with_contiguous_ids() {
        let tenants = vec![
            TenantCfg::new("a", 500.0, ArrivalKind::Poisson),
            TenantCfg::new("b", 500.0, ArrivalKind::diurnal_default()),
        ];
        let reqs = generate(&tenants, 200, 7);
        assert_eq!(reqs.len(), 400);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(r.prompt_tokens >= 1 && r.output_tokens >= 1);
            if i > 0 {
                assert!(reqs[i - 1].arrival_ns <= r.arrival_ns);
            }
        }
    }

    #[test]
    fn token_lengths_respect_mean_and_cap() {
        let mut t = TenantCfg::new("t", 100.0, ArrivalKind::Poisson);
        t.prompt_tokens_mean = 64;
        t.output_tokens_mean = 8;
        let reqs = generate(&[t.clone()], 5000, 5);
        let pm: f64 =
            reqs.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / reqs.len() as f64;
        // capped exponential: mean lands a bit under the target mean
        assert!(pm > 40.0 && pm < 70.0, "prompt mean={pm}");
        assert!(reqs.iter().all(|r| r.prompt_tokens <= t.prompt_tokens_cap()));
        assert!(reqs.iter().all(|r| r.output_tokens <= t.output_tokens_cap()));
    }

    #[test]
    fn arrival_kind_parse_and_names() {
        assert_eq!(ArrivalKind::parse("poisson"), Some(ArrivalKind::Poisson));
        assert_eq!(
            ArrivalKind::parse("diurnal"),
            Some(ArrivalKind::diurnal_default())
        );
        assert!(ArrivalKind::parse("nope").is_none());
        assert_eq!(ArrivalKind::Poisson.name(), "poisson");
        assert_eq!(ArrivalKind::diurnal_default().name(), "diurnal");
    }
}
