//! Disaggregated prefill/decode pool model running *inside* the DES.
//!
//! The closed-loop Fig 4 path drives one collective at a time to
//! completion under an external clock, so nothing ever contends. This
//! module instead installs one event-driven [`ServingApp`] per node in a
//! single `cluster.run()`: prefill TP exchanges, decode TP exchanges,
//! KV-cache migrations, and PR 5's background traffic all share the
//! fabric concurrently — which is exactly the regime where OptiNIC's
//! bounded completion vs. the reliable family's retransmission tails
//! should separate.
//!
//! Topology: nodes `0..P` are the prefill pool (leader = node 0), nodes
//! `P..P+D` the decode pool (leader = node `P`). Each pool runs
//! continuous batching, coordinated by its leader over the reliable
//! ctrl channel (the paper's pre-existing reliable connection, §3.1.2).
//!
//! **TP exchange model ("collapsed ring")**: a real per-layer ring
//! AllReduce moves `2(k−1)/k · N` bytes per rank in `2(k−1)` phases per
//! layer. We preserve the per-rank byte volume — each member sends ONE
//! message to its ring successor per step — and fold the phase-latency
//! floor (`n_layers · 2(k−1)` half-RTTs) into the compute delay.
//! Contention, loss, and bounded-vs-reliable dynamics are real; the
//! phase structure is not (docs/SERVING.md discusses the approximation).
//!
//! **KV-cache migration**: after a prefill round, each request's KV
//! cache (`2 · n_layers · kv_dim · act_bytes · prompt_tokens`) moves to
//! a decode node over the data fabric. OptiNIC drops two-sided arrivals
//! with no posted receive (`rx_no_recv_wqe`, no RNR storm), so transfers
//! rendezvous first: the decode node posts the receive into a staging
//! slot, *then* tells the prefill source to send.

use std::collections::VecDeque;

use crate::coordinator::gpu::{GpuKind, GpuModel};
use crate::net::CtrlMsg;
use crate::serving::slo::{RequestRecord, SloReport, SloTargets};
use crate::serving::workload::{self, Request, TenantCfg};
use crate::sim::cluster::{App, AppCtx, Cluster};
use crate::sim::SimTime;
use crate::transport::TransportKind;
use crate::util::prng::Pcg64;
use crate::verbs::{CqEvent, MrId, NodeId, QpHandle, QpType, Wqe};

// ---------------------------------------------------------------------------
// Model dimensions
// ---------------------------------------------------------------------------

/// Transformer dimensions the serving flows are sized from. Small by
/// default so DES cells stay fast; the *ratios* (KV bytes per token,
/// exchange bytes per token) are what the transport comparison needs.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub hidden: usize,
    pub n_layers: usize,
    /// Per-layer K (and V) width per token, elements.
    pub kv_dim: usize,
    /// Bytes per activation element (2 = fp16).
    pub act_bytes: usize,
}

impl ModelDims {
    pub fn tiny() -> ModelDims {
        ModelDims {
            hidden: 256,
            n_layers: 4,
            kv_dim: 64,
            act_bytes: 2,
        }
    }

    /// Parameter-count estimate: 12·L·H² (attention + MLP, no embeddings).
    pub fn params(&self) -> usize {
        12 * self.n_layers * self.hidden * self.hidden
    }

    /// KV-cache footprint of one request's prompt.
    pub fn kv_bytes(&self, prompt_tokens: usize) -> usize {
        2 * self.n_layers * self.kv_dim * self.act_bytes * prompt_tokens
    }

    /// Collapsed-ring exchange bytes per member for a TP step over
    /// `ranks` members processing `tokens` tokens (0 when unsharded).
    pub fn tp_exchange_bytes(&self, tokens: usize, ranks: usize) -> usize {
        if ranks < 2 {
            return 0;
        }
        let full = tokens * self.hidden * self.act_bytes * self.n_layers;
        full * 2 * (ranks - 1) / ranks
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct PoolCfg {
    pub prefill_ranks: usize,
    pub decode_ranks: usize,
    /// Continuous-batching cap for one prefill round (requests).
    pub max_batch: usize,
    /// Concurrent sequences the decode pool iterates over.
    pub max_active: usize,
    /// KV staging slots per decode node (concurrent inbound migrations).
    pub kv_slots: usize,
}

impl Default for PoolCfg {
    fn default() -> Self {
        PoolCfg {
            prefill_ranks: 2,
            decode_ranks: 2,
            max_batch: 8,
            max_active: 32,
            kv_slots: 4,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServingCfg {
    pub dims: ModelDims,
    pub pool: PoolCfg,
    pub tenants: Vec<TenantCfg>,
    pub requests_per_tenant: usize,
    pub slo: SloTargets,
    pub gpu: GpuModel,
    pub seed: u64,
}

impl ServingCfg {
    pub fn new(tenants: Vec<TenantCfg>, requests_per_tenant: usize) -> ServingCfg {
        ServingCfg {
            dims: ModelDims::tiny(),
            pool: PoolCfg::default(),
            tenants,
            requests_per_tenant,
            slo: SloTargets::default(),
            gpu: GpuModel::new(GpuKind::V100),
            seed: 7,
        }
    }

    /// Cluster size the pools need: prefill ranks + decode ranks.
    pub fn nodes(&self) -> usize {
        self.pool.prefill_ranks + self.pool.decode_ranks
    }

    /// Largest prompt any tenant can sample — sizes the KV staging slots.
    fn prompt_cap(&self) -> usize {
        self.tenants
            .iter()
            .map(|t| t.prompt_tokens_cap())
            .max()
            .unwrap_or(1)
    }
}

// ---------------------------------------------------------------------------
// Protocol constants
// ---------------------------------------------------------------------------

// Ctrl tags (collectives use a 0x71be0 namespace; keep ours disjoint).
const TAG_STEP_BEGIN: u64 = 0x5e_0001;
const TAG_STEP_DONE: u64 = 0x5e_0002;
const TAG_KV_PREP: u64 = 0x5e_0003;
const TAG_KV_READY: u64 = 0x5e_0004;
const TAG_KV_DONE: u64 = 0x5e_0005;
const TAG_SHUTDOWN: u64 = 0x5e_0006;

// wr_id layout: kind in the top byte, step id / req id in the low bits
// (KV receives also carry the staging-slot index in bits 32..56).
const WR_KIND_SHIFT: u64 = 56;
const WR_RING_SEND: u64 = 1;
const WR_RING_RECV: u64 = 2;
const WR_KV_SEND: u64 = 3;
const WR_KV_RECV: u64 = 4;
const WR_KV_SLOT_SHIFT: u64 = 32;

// Wake tokens (token u64::MAX is the cluster start signal — stay clear).
const TOK_KIND_SHIFT: u64 = 48;
const TOK_ARRIVAL: u64 = 1 << TOK_KIND_SHIFT;
const TOK_RING_SEND: u64 = 2 << TOK_KIND_SHIFT;
const TOK_STEP_NOEX: u64 = 3 << TOK_KIND_SHIFT;
const TOK_MASK: u64 = 0xffff << TOK_KIND_SHIFT;

fn enc(vals: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn dec(payload: &[u8]) -> Vec<u64> {
    payload
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Bounded-completion deadline for one message: 3× the unloaded transfer
/// time + 8 RTTs + 0.5 ms slack. Generous enough that loss is rare on an
/// idle fabric; under congestion this is where OptiNIC trades data for
/// latency while the reliable family retransmits into the queue.
fn msg_deadline(bytes: usize, bytes_per_ns: f64, rtt_ns: u64) -> SimTime {
    (3.0 * bytes as f64 / bytes_per_ns.max(1e-9)) as SimTime + 8 * rtt_ns + 500_000
}

// ---------------------------------------------------------------------------
// Per-request output records (merged into the SloReport after the run)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct PrefillRec {
    req_id: usize,
    tenant: usize,
    queue_delay_ns: SimTime,
    ttft_ns: SimTime,
}

#[derive(Clone, Copy, Debug)]
struct DecodeRec {
    req_id: usize,
    tenant: usize,
    tpot_ns: f64,
    output_tokens: usize,
}

// ---------------------------------------------------------------------------
// Coordinators (leader-only state)
// ---------------------------------------------------------------------------

struct PrefillCoord {
    workload: Vec<Request>,
    next_arrival: usize,
    /// Request indices admitted but not yet in a prefill round.
    queue: VecDeque<usize>,
    /// Continuous-batching cap for one round.
    round_capacity: usize,
    decode_ranks: usize,
    busy: bool,
    step: u64,
    round: Vec<usize>,
    round_start: SimTime,
    pending_done: usize,
    /// Round-robin cursor for KV destination placement.
    kv_rr: usize,
    rng: Pcg64,
    gpu: GpuModel,
    recs: Vec<PrefillRec>,
    ring_bytes_lost: u64,
}

struct ActiveReq {
    req_id: usize,
    tenant: usize,
    remaining: usize,
    output_tokens: usize,
    admit_ns: SimTime,
}

struct DecodeCoord {
    total: usize,
    max_active: usize,
    /// KV landed, awaiting admission to the active set.
    ready: VecDeque<ActiveReq>,
    active: Vec<ActiveReq>,
    busy: bool,
    step: u64,
    pending_done: usize,
    completed: usize,
    rng: Pcg64,
    gpu: GpuModel,
    recs: Vec<DecodeRec>,
    kv_bytes_moved: u64,
    kv_bytes_lost: u64,
    kv_transfers: usize,
    tokens: u64,
    ring_bytes_lost: u64,
}

// ---------------------------------------------------------------------------
// The per-node app
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct RingLinks {
    to_succ: QpHandle,
    from_pred: QpHandle,
}

/// In-flight TP step state for this member.
struct MemberStep {
    step: u64,
    bytes: usize,
    deadline: SimTime,
    send_done: bool,
    recv_done: bool,
    lost_bytes: u64,
}

/// One serving node: a pool member (ring exchanges, KV send/recv duties)
/// plus, on the pool's leader node, the coordinator state machine.
pub struct ServingApp {
    dims: ModelDims,
    /// OptiNIC family: bounded completions, per-message deadlines.
    bounded: bool,
    /// Pool leader this member reports STEP_DONE to.
    leader: NodeId,
    pool_size: usize,
    ring: Option<RingLinks>,
    ring_tx_mr: MrId,
    ring_rx_mr: MrId,
    cur_step: Option<MemberStep>,
    // prefill members: KV source duties
    kv_tx_mr: MrId,
    /// Per-peer KV QP table (prefill: one entry per decode node; decode:
    /// one entry per prefill node).
    kv_qps: Vec<(NodeId, QpHandle)>,
    // decode members: KV sink duties (staging slots)
    kv_rx_mr: MrId,
    kv_slot_bytes: usize,
    kv_slots_free: Vec<usize>,
    /// KV_PREP payload parked in each busy slot (for KV_DONE forwarding).
    kv_inflight: Vec<Option<[u64; 5]>>,
    /// KV_PREPs waiting for a free staging slot.
    kv_pending: VecDeque<[u64; 5]>,
    decode_leader: NodeId,
    bytes_per_ns: f64,
    pf: Option<PrefillCoord>,
    dc: Option<DecodeCoord>,
    done: bool,
}

impl ServingApp {
    fn msg_deadline(&self, bytes: usize, ctx: &AppCtx) -> SimTime {
        msg_deadline(bytes, self.bytes_per_ns, ctx.base_rtt_ns())
    }

    fn phase_floor(dims: &ModelDims, pool: usize, rtt: u64) -> SimTime {
        if pool >= 2 {
            (dims.n_layers * 2 * (pool - 1)) as u64 * (rtt / 2)
        } else {
            0
        }
    }

    fn broadcast_shutdown(&self, ctx: &mut AppCtx) {
        let nodes_total = self.decode_leader + self.pool_size;
        for n in 0..nodes_total {
            ctx.send_ctrl(
                n,
                CtrlMsg {
                    tag: TAG_SHUTDOWN,
                    payload: Vec::new(),
                },
            );
        }
    }

    // -- prefill coordinator ------------------------------------------------

    /// Move due arrivals into the admission queue and re-arm the wake for
    /// the next future arrival.
    fn admit_arrivals(&mut self, ctx: &mut AppCtx) {
        let now = ctx.time;
        let c = self.pf.as_mut().unwrap();
        while c.next_arrival < c.workload.len()
            && c.workload[c.next_arrival].arrival_ns <= now
        {
            c.queue.push_back(c.next_arrival);
            c.next_arrival += 1;
        }
        if c.next_arrival < c.workload.len() {
            let gap = c.workload[c.next_arrival].arrival_ns - now;
            ctx.wake_in(gap.max(1), TOK_ARRIVAL);
        }
    }

    fn try_start_prefill(&mut self, ctx: &mut AppCtx) {
        let dims = self.dims;
        let pool = self.pool_size;
        let bytes_per_ns = self.bytes_per_ns;
        let rtt = ctx.base_rtt_ns();
        let c = self.pf.as_mut().unwrap();
        if c.busy || c.queue.is_empty() {
            return;
        }
        c.busy = true;
        c.round.clear();
        let take = c.queue.len().min(c.round_capacity);
        for _ in 0..take {
            c.round.push(c.queue.pop_front().unwrap());
        }
        c.round_start = ctx.time;
        c.step += 1;
        let step = c.step;
        let tokens: usize = c.round.iter().map(|&i| c.workload[i].prompt_tokens).sum();
        // forward pass ≈ 2·params·tokens FLOPs
        let flops = 2.0 * dims.params() as f64 * tokens as f64;
        let (delays, base) = c.gpu.step_delays(flops, pool, &mut c.rng);
        let bytes = dims.tp_exchange_bytes(tokens, pool);
        let floor = Self::phase_floor(&dims, pool, rtt);
        let max_delay = base + delays.iter().max().copied().unwrap_or(0) + floor;
        let deadline = max_delay + msg_deadline(bytes.max(1), bytes_per_ns, rtt);
        c.pending_done = pool;
        for (i, d) in delays.iter().enumerate() {
            ctx.send_ctrl(
                i as NodeId,
                CtrlMsg {
                    tag: TAG_STEP_BEGIN,
                    payload: enc(&[step, bytes as u64, base + d + floor, deadline]),
                },
            );
        }
    }

    fn prefill_round_complete(&mut self, ctx: &mut AppCtx) {
        let dims = self.dims;
        let pool = self.pool_size;
        let decode_base = pool as NodeId;
        let now = ctx.time;
        let c = self.pf.as_mut().unwrap();
        // first token emitted for every request in the round; queueing
        // delay is measured from each request's OWN arrival time
        let mut preps: Vec<(NodeId, [u64; 5])> = Vec::with_capacity(c.round.len());
        for &idx in &c.round {
            let r = c.workload[idx];
            c.recs.push(PrefillRec {
                req_id: r.id,
                tenant: r.tenant,
                queue_delay_ns: c.round_start.saturating_sub(r.arrival_ns),
                ttft_ns: now.saturating_sub(r.arrival_ns),
            });
            let kv = dims.kv_bytes(r.prompt_tokens) as u64;
            let src = (r.id % pool) as u64;
            let dst = decode_base + (c.kv_rr % c.decode_ranks);
            c.kv_rr += 1;
            preps.push((
                dst,
                [r.id as u64, kv, src, r.tenant as u64, r.output_tokens as u64],
            ));
        }
        c.round.clear();
        c.busy = false;
        for (dst, p) in preps {
            ctx.send_ctrl(
                dst,
                CtrlMsg {
                    tag: TAG_KV_PREP,
                    payload: enc(&p),
                },
            );
        }
        self.try_start_prefill(ctx);
    }

    // -- decode coordinator -------------------------------------------------

    fn try_start_decode(&mut self, ctx: &mut AppCtx) {
        let dims = self.dims;
        let pool = self.pool_size;
        let decode_base = self.leader;
        let bytes_per_ns = self.bytes_per_ns;
        let rtt = ctx.base_rtt_ns();
        let c = self.dc.as_mut().unwrap();
        if c.busy {
            return;
        }
        while c.active.len() < c.max_active && !c.ready.is_empty() {
            let mut r = c.ready.pop_front().unwrap();
            r.admit_ns = ctx.time;
            c.active.push(r);
        }
        if c.active.is_empty() {
            return;
        }
        c.busy = true;
        c.step += 1;
        let step = c.step;
        let batch = c.active.len();
        let flops = GpuModel::decode_step_flops(dims.params(), batch);
        let (delays, base) = c.gpu.step_delays(flops, pool, &mut c.rng);
        let bytes = dims.tp_exchange_bytes(batch, pool);
        let floor = Self::phase_floor(&dims, pool, rtt);
        let max_delay = base + delays.iter().max().copied().unwrap_or(0) + floor;
        let deadline = max_delay + msg_deadline(bytes.max(1), bytes_per_ns, rtt);
        c.pending_done = pool;
        for (i, d) in delays.iter().enumerate() {
            ctx.send_ctrl(
                decode_base + i,
                CtrlMsg {
                    tag: TAG_STEP_BEGIN,
                    payload: enc(&[step, bytes as u64, base + d + floor, deadline]),
                },
            );
        }
    }

    fn decode_step_complete(&mut self, ctx: &mut AppCtx) {
        let now = ctx.time;
        let c = self.dc.as_mut().unwrap();
        c.tokens += c.active.len() as u64;
        let mut i = 0;
        while i < c.active.len() {
            c.active[i].remaining -= 1;
            if c.active[i].remaining == 0 {
                let r = c.active.swap_remove(i);
                let span = now.saturating_sub(r.admit_ns) as f64;
                c.recs.push(DecodeRec {
                    req_id: r.req_id,
                    tenant: r.tenant,
                    tpot_ns: span / r.output_tokens.max(1) as f64,
                    output_tokens: r.output_tokens,
                });
                c.completed += 1;
            } else {
                i += 1;
            }
        }
        c.busy = false;
        let finished = c.completed == c.total;
        if finished {
            self.broadcast_shutdown(ctx);
        } else {
            self.try_start_decode(ctx);
        }
    }

    // -- member: TP ring exchange -------------------------------------------

    fn begin_member_step(&mut self, ctx: &mut AppCtx, vals: &[u64]) {
        let (step, bytes, delay, deadline) = (vals[0], vals[1] as usize, vals[2], vals[3]);
        debug_assert!(self.cur_step.is_none(), "overlapping TP steps");
        if bytes == 0 || self.ring.is_none() {
            // unsharded pool: pure compute, no exchange
            ctx.wake_in(delay.max(1), TOK_STEP_NOEX | step);
            return;
        }
        // post the receive BEFORE any peer can send (rendezvous-by-design:
        // OptiNIC drops unmatched two-sided arrivals)
        let ring = self.ring.unwrap();
        let mut wqe = Wqe::recv(
            (WR_RING_RECV << WR_KIND_SHIFT) | step,
            self.ring_rx_mr,
            0,
            bytes,
        );
        if self.bounded {
            wqe = wqe.with_timeout(deadline);
        }
        ctx.endpoint().post_recv(ring.from_pred, wqe);
        self.cur_step = Some(MemberStep {
            step,
            bytes,
            deadline,
            send_done: false,
            recv_done: false,
            lost_bytes: 0,
        });
        ctx.wake_in(delay.max(1), TOK_RING_SEND | step);
    }

    /// Compute phase over — push this member's exchange to its successor.
    fn post_ring_send(&mut self, ctx: &mut AppCtx, step: u64) {
        let Some(s) = self.cur_step.as_ref() else {
            return;
        };
        if s.step != step {
            return;
        }
        let (bytes, deadline) = (s.bytes, s.deadline);
        let ring = self.ring.unwrap();
        let mut wqe = Wqe::send(
            (WR_RING_SEND << WR_KIND_SHIFT) | step,
            self.ring_tx_mr,
            0,
            bytes,
        );
        if self.bounded {
            wqe = wqe.with_timeout(deadline);
        }
        ctx.endpoint().post_send(ring.to_succ, wqe);
    }

    fn finish_member_step_if_ready(&mut self, ctx: &mut AppCtx) {
        let Some(s) = self.cur_step.as_ref() else {
            return;
        };
        if !(s.send_done && s.recv_done) {
            return;
        }
        let (step, lost) = (s.step, s.lost_bytes);
        self.cur_step = None;
        ctx.send_ctrl(
            self.leader,
            CtrlMsg {
                tag: TAG_STEP_DONE,
                payload: enc(&[step, lost]),
            },
        );
    }

    fn member_step_event(&mut self, ctx: &mut AppCtx, ev: &CqEvent) {
        match *ev {
            CqEvent::SendDone { .. } => {
                if let Some(s) = self.cur_step.as_mut() {
                    s.send_done = true;
                }
            }
            CqEvent::RecvDone {
                delivered_bytes,
                expected_bytes,
                ..
            } => {
                if let Some(s) = self.cur_step.as_mut() {
                    s.recv_done = true;
                    s.lost_bytes += expected_bytes.saturating_sub(delivered_bytes) as u64;
                }
            }
            CqEvent::TimeoutFired {
                is_recv,
                delivered_bytes,
                expected_bytes,
                ..
            } => {
                ctx.metrics.bump("serving_ring_timeout");
                if let Some(s) = self.cur_step.as_mut() {
                    if is_recv {
                        s.recv_done = true;
                        s.lost_bytes +=
                            expected_bytes.saturating_sub(delivered_bytes) as u64;
                    } else {
                        s.send_done = true;
                    }
                }
            }
            CqEvent::QpError {
                is_recv,
                expected_bytes,
                ..
            } => {
                ctx.metrics.bump("serving_qp_error");
                if let Some(s) = self.cur_step.as_mut() {
                    if is_recv {
                        s.recv_done = true;
                        s.lost_bytes += expected_bytes as u64;
                    } else {
                        s.send_done = true;
                    }
                }
            }
        }
        self.finish_member_step_if_ready(ctx);
    }

    // -- member: KV migration duties ----------------------------------------

    /// Decode side, step 1: stage a slot and invite the source to send.
    fn kv_try_post_recv(&mut self, ctx: &mut AppCtx, vals: [u64; 5]) {
        let Some(slot) = self.kv_slots_free.pop() else {
            self.kv_pending.push_back(vals);
            ctx.metrics.bump("serving_kv_stalled");
            return;
        };
        let (req_id, bytes, src) = (vals[0], vals[1] as usize, vals[2] as NodeId);
        let mut wqe = Wqe::recv(
            (WR_KV_RECV << WR_KIND_SHIFT) | ((slot as u64) << WR_KV_SLOT_SHIFT) | req_id,
            self.kv_rx_mr,
            slot * self.kv_slot_bytes,
            bytes,
        );
        if self.bounded {
            // the source fires as soon as KV_READY lands (one ctrl hop),
            // so one extra RTT of headroom covers the rendezvous
            wqe = wqe.with_timeout(self.msg_deadline(bytes, ctx) + ctx.base_rtt_ns());
        }
        ctx.endpoint().post_recv(self.kv_qp(src), wqe);
        self.kv_inflight[slot] = Some(vals);
        ctx.send_ctrl(
            src,
            CtrlMsg {
                tag: TAG_KV_READY,
                payload: enc(&[req_id, bytes as u64]),
            },
        );
    }

    /// Prefill side, step 2: receive is posted — fire the transfer.
    fn kv_send(&mut self, ctx: &mut AppCtx, to: NodeId, vals: &[u64]) {
        let (req_id, bytes) = (vals[0], vals[1] as usize);
        let mut wqe = Wqe::send(
            (WR_KV_SEND << WR_KIND_SHIFT) | req_id,
            self.kv_tx_mr,
            0,
            bytes,
        );
        if self.bounded {
            wqe = wqe.with_timeout(self.msg_deadline(bytes, ctx));
        }
        ctx.endpoint().post_send(self.kv_qp(to), wqe);
    }

    /// Decode side, step 3: transfer completed (fully, partially, or
    /// timed out) — free the slot, report to the decode leader, service
    /// the next queued migration.
    fn kv_recv_complete(
        &mut self,
        ctx: &mut AppCtx,
        wr_id: u64,
        delivered: usize,
        expected: usize,
    ) {
        let slot = ((wr_id >> WR_KV_SLOT_SHIFT) & 0x00ff_ffff) as usize;
        let Some(vals) = self.kv_inflight[slot].take() else {
            return;
        };
        self.kv_slots_free.push(slot);
        let lost = expected.saturating_sub(delivered);
        if lost > 0 {
            ctx.metrics.bump("serving_kv_partial");
        }
        ctx.send_ctrl(
            self.decode_leader,
            CtrlMsg {
                tag: TAG_KV_DONE,
                payload: enc(&[vals[0], vals[3], vals[4], delivered as u64, lost as u64]),
            },
        );
        if let Some(next) = self.kv_pending.pop_front() {
            self.kv_try_post_recv(ctx, next);
        }
    }

    fn kv_qp(&self, peer: NodeId) -> QpHandle {
        self.kv_qps
            .iter()
            .find(|(n, _)| *n == peer)
            .map(|(_, q)| *q)
            .expect("no KV QP to peer")
    }
}

impl App for ServingApp {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        if self.pf.is_some() {
            self.admit_arrivals(ctx);
            self.try_start_prefill(ctx);
        }
        if let Some(c) = &self.dc {
            if c.total == 0 {
                // degenerate empty workload: nothing will ever complete
                self.broadcast_shutdown(ctx);
            }
        }
    }

    fn on_cq_event(&mut self, ctx: &mut AppCtx, ev: CqEvent) {
        if self.done {
            return; // stragglers after shutdown (e.g. late KV send CQEs)
        }
        let wr_id = match ev {
            CqEvent::SendDone { wr_id, .. }
            | CqEvent::RecvDone { wr_id, .. }
            | CqEvent::TimeoutFired { wr_id, .. }
            | CqEvent::QpError { wr_id, .. } => wr_id,
        };
        match wr_id >> WR_KIND_SHIFT {
            WR_RING_SEND | WR_RING_RECV => self.member_step_event(ctx, &ev),
            WR_KV_SEND => {
                // source-side completion: nothing to coordinate (the sink
                // reports KV_DONE); count bounded partial sends
                if matches!(ev, CqEvent::TimeoutFired { .. } | CqEvent::QpError { .. }) {
                    ctx.metrics.bump("serving_kv_send_bounded");
                }
            }
            WR_KV_RECV => match ev {
                CqEvent::RecvDone {
                    wr_id,
                    delivered_bytes,
                    expected_bytes,
                    ..
                } => self.kv_recv_complete(ctx, wr_id, delivered_bytes, expected_bytes),
                CqEvent::TimeoutFired {
                    wr_id,
                    delivered_bytes,
                    expected_bytes,
                    ..
                } => {
                    ctx.metrics.bump("serving_kv_timeout");
                    self.kv_recv_complete(ctx, wr_id, delivered_bytes, expected_bytes)
                }
                CqEvent::QpError {
                    wr_id,
                    expected_bytes,
                    ..
                } => self.kv_recv_complete(ctx, wr_id, 0, expected_bytes),
                CqEvent::SendDone { .. } => {}
            },
            _ => {}
        }
    }

    fn on_wake(&mut self, ctx: &mut AppCtx, token: u64) {
        if self.done {
            return;
        }
        match token & TOK_MASK {
            TOK_ARRIVAL => {
                self.admit_arrivals(ctx);
                self.try_start_prefill(ctx);
            }
            TOK_RING_SEND => self.post_ring_send(ctx, token & !TOK_MASK),
            TOK_STEP_NOEX => {
                let step = token & !TOK_MASK;
                ctx.send_ctrl(
                    self.leader,
                    CtrlMsg {
                        tag: TAG_STEP_DONE,
                        payload: enc(&[step, 0]),
                    },
                );
            }
            _ => {}
        }
    }

    fn on_ctrl(&mut self, ctx: &mut AppCtx, from: NodeId, msg: CtrlMsg) {
        if self.done {
            return;
        }
        match msg.tag {
            TAG_STEP_BEGIN => {
                let vals = dec(&msg.payload);
                self.begin_member_step(ctx, &vals);
            }
            TAG_STEP_DONE => {
                let vals = dec(&msg.payload);
                // (is the round complete?, is this the prefill leader?)
                let fire = if let Some(c) = self.pf.as_mut() {
                    c.ring_bytes_lost += vals[1];
                    c.pending_done -= 1;
                    (c.pending_done == 0, true)
                } else if let Some(c) = self.dc.as_mut() {
                    c.ring_bytes_lost += vals[1];
                    c.pending_done -= 1;
                    (c.pending_done == 0, false)
                } else {
                    debug_assert!(false, "STEP_DONE at non-leader");
                    (false, false)
                };
                match fire {
                    (true, true) => self.prefill_round_complete(ctx),
                    (true, false) => self.decode_step_complete(ctx),
                    _ => {}
                }
            }
            TAG_KV_PREP => {
                let v = dec(&msg.payload);
                self.kv_try_post_recv(ctx, [v[0], v[1], v[2], v[3], v[4]]);
            }
            TAG_KV_READY => {
                let vals = dec(&msg.payload);
                self.kv_send(ctx, from, &vals);
            }
            TAG_KV_DONE => {
                let vals = dec(&msg.payload);
                let c = self.dc.as_mut().expect("KV_DONE at non-leader");
                c.kv_transfers += 1;
                c.kv_bytes_moved += vals[3];
                c.kv_bytes_lost += vals[4];
                let output_tokens = (vals[2] as usize).max(1);
                c.ready.push_back(ActiveReq {
                    req_id: vals[0] as usize,
                    tenant: vals[1] as usize,
                    remaining: output_tokens,
                    output_tokens,
                    admit_ns: 0,
                });
                self.try_start_decode(ctx);
            }
            TAG_SHUTDOWN => {
                self.done = true;
            }
            _ => {}
        }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

// ---------------------------------------------------------------------------
// Wiring + run
// ---------------------------------------------------------------------------

/// Build the serving pools on `cluster` (which must have `cfg.nodes()`
/// nodes), run the open-loop workload to completion, and merge the
/// per-pool records into an [`SloReport`].
pub fn run_serving(cluster: &mut Cluster, cfg: &ServingCfg) -> SloReport {
    let p = cfg.pool.prefill_ranks;
    let d = cfg.pool.decode_ranks;
    assert!(p >= 1 && d >= 1, "each pool needs at least one rank");
    assert_eq!(
        cluster.nodes(),
        p + d,
        "cluster size must equal prefill + decode ranks"
    );
    let bounded = matches!(
        cluster.cfg.transport,
        TransportKind::Optinic | TransportKind::OptinicHw
    );
    let bytes_per_ns = cluster.cfg.fabric.bytes_per_ns();

    let workload = workload::generate(&cfg.tenants, cfg.requests_per_tenant, cfg.seed);
    let total = workload.len();

    // buffer sizing: worst-case round is max_batch prompts at the cap;
    // worst-case decode step iterates max_active sequences
    let prompt_cap = cfg.prompt_cap();
    let pre_ring_bytes = cfg
        .dims
        .tp_exchange_bytes(cfg.pool.max_batch * prompt_cap, p)
        .max(1);
    let dec_ring_bytes = cfg.dims.tp_exchange_bytes(cfg.pool.max_active, d).max(1);
    let kv_slot_bytes = cfg.dims.kv_bytes(prompt_cap).max(1);

    // ring QPs within each pool (skip unsharded pools)
    let mut ring_links: Vec<Option<RingLinks>> = vec![None; p + d];
    for (base, k) in [(0usize, p), (p, d)] {
        if k < 2 {
            continue;
        }
        let mut to_succ: Vec<Option<QpHandle>> = vec![None; k];
        let mut from_pred: Vec<Option<QpHandle>> = vec![None; k];
        for i in 0..k {
            let (qa, qb) = cluster.connect(base + i, base + (i + 1) % k, QpType::Xp);
            to_succ[i] = Some(qa);
            from_pred[(i + 1) % k] = Some(qb);
        }
        for i in 0..k {
            ring_links[base + i] = Some(RingLinks {
                to_succ: to_succ[i].unwrap(),
                from_pred: from_pred[i].unwrap(),
            });
        }
    }

    // KV QPs: full bipartite prefill × decode
    let mut kv_tables: Vec<Vec<(NodeId, QpHandle)>> = vec![Vec::new(); p + d];
    for i in 0..p {
        for j in 0..d {
            let (qa, qb) = cluster.connect(i, p + j, QpType::Xp);
            kv_tables[i].push((p + j, qa));
            kv_tables[p + j].push((i, qb));
        }
    }

    let mut apps: Vec<ServingApp> = Vec::with_capacity(p + d);
    for node in 0..p + d {
        let is_prefill = node < p;
        let ring_bytes = if is_prefill { pre_ring_bytes } else { dec_ring_bytes };
        let ring_tx_mr = cluster.mem.register(node, ring_bytes);
        let ring_rx_mr = cluster.mem.register(node, ring_bytes);
        let kv_tx_mr = if is_prefill {
            cluster.mem.register(node, kv_slot_bytes)
        } else {
            ring_tx_mr // unused on decode nodes
        };
        let kv_rx_mr = if is_prefill {
            ring_rx_mr // unused on prefill nodes
        } else {
            cluster.mem.register(node, cfg.pool.kv_slots * kv_slot_bytes)
        };
        apps.push(ServingApp {
            dims: cfg.dims,
            bounded,
            leader: if is_prefill { 0 } else { p },
            pool_size: if is_prefill { p } else { d },
            ring: ring_links[node],
            ring_tx_mr,
            ring_rx_mr,
            cur_step: None,
            kv_tx_mr,
            kv_qps: kv_tables[node].clone(),
            kv_rx_mr,
            kv_slot_bytes,
            kv_slots_free: if is_prefill {
                Vec::new()
            } else {
                (0..cfg.pool.kv_slots).rev().collect()
            },
            kv_inflight: (0..cfg.pool.kv_slots).map(|_| None).collect(),
            kv_pending: VecDeque::new(),
            decode_leader: p,
            bytes_per_ns,
            pf: None,
            dc: None,
            done: false,
        });
    }

    apps[0].pf = Some(PrefillCoord {
        workload: workload.clone(),
        next_arrival: 0,
        queue: VecDeque::new(),
        round_capacity: cfg.pool.max_batch.max(1),
        decode_ranks: d,
        busy: false,
        step: 0,
        round: Vec::with_capacity(cfg.pool.max_batch),
        round_start: 0,
        pending_done: 0,
        kv_rr: 0,
        rng: Pcg64::new(cfg.seed, 0x11AD),
        gpu: cfg.gpu.clone(),
        recs: Vec::with_capacity(total),
        ring_bytes_lost: 0,
    });
    apps[p].dc = Some(DecodeCoord {
        total,
        max_active: cfg.pool.max_active.max(1),
        ready: VecDeque::new(),
        active: Vec::with_capacity(cfg.pool.max_active),
        busy: false,
        step: 0,
        pending_done: 0,
        completed: 0,
        rng: Pcg64::new(cfg.seed, 0xDECD),
        gpu: cfg.gpu.clone(),
        recs: Vec::with_capacity(total),
        kv_bytes_moved: 0,
        kv_bytes_lost: 0,
        kv_transfers: 0,
        tokens: 0,
        ring_bytes_lost: 0,
    });

    for (node, app) in apps.into_iter().enumerate() {
        cluster.set_app(node, Box::new(app));
    }
    cluster.start_apps();
    let completed = cluster.run();
    if !completed {
        cluster.metrics.bump("serving_run_truncated");
    }

    // extract both leaders and join their per-request records
    let mut pf_app = cluster.take_app(0).expect("prefill leader app");
    let pf = pf_app
        .as_any()
        .downcast_mut::<ServingApp>()
        .expect("prefill leader type")
        .pf
        .take()
        .expect("prefill coordinator");
    let mut dc_app = cluster.take_app(p).expect("decode leader app");
    let dc = dc_app
        .as_any()
        .downcast_mut::<ServingApp>()
        .expect("decode leader type")
        .dc
        .take()
        .expect("decode coordinator");
    cluster
        .metrics
        .add("serving_ring_bytes_lost", pf.ring_bytes_lost + dc.ring_bytes_lost);

    let names: Vec<String> = cfg.tenants.iter().map(|t| t.name.clone()).collect();
    let mut report = SloReport::new(&names);
    report.requests_offered = total;
    report.total_sim_ns = cluster.time;
    report.kv_bytes_moved = dc.kv_bytes_moved;
    report.kv_bytes_lost = dc.kv_bytes_lost;
    report.kv_transfers = dc.kv_transfers;
    report.tokens_generated = dc.tokens + pf.recs.len() as u64;

    let mut by_req: Vec<Option<PrefillRec>> = vec![None; total];
    for r in &pf.recs {
        by_req[r.req_id] = Some(*r);
    }
    for r in &dc.recs {
        let Some(pr) = by_req[r.req_id] else { continue };
        report.record(
            &RequestRecord {
                tenant: r.tenant,
                ttft_ns: pr.ttft_ns,
                queue_delay_ns: pr.queue_delay_ns,
                tpot_ns: r.tpot_ns,
                output_tokens: r.output_tokens,
            },
            &cfg.slo,
        );
    }
    report
}
