//! PJRT runtime: loads the AOT artifacts emitted by `python/compile/aot.py`
//! (HLO text + manifest.json) and executes them on the XLA CPU client.
//!
//! Python never runs on this path — `make artifacts` is the only place the
//! interpreter is invoked. The engine compiles each HLO module once (lazy,
//! cached) and exposes typed entry points over flat `f32`/`i32` buffers,
//! which is exactly the representation the simulated collectives move.
//!
//! ## The `pjrt` feature
//!
//! The real engine needs the `xla` native bindings (PJRT CPU client) and
//! pre-built artifacts — both environment-dependent, neither available
//! offline. It is therefore gated behind the `pjrt` cargo feature. With
//! the feature off (the default), [`Engine`] keeps the identical API:
//! manifest/file entry points work, execution entry points return a clear
//! error. Everything network/simulation-side — the entire tier-1 test
//! surface — runs without it.
//!
//! Enabling the feature is a two-step operation (see rust/Cargo.toml):
//! the `xla` dependency must be added alongside `--features pjrt`,
//! because declaring it even optionally would break offline resolution.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Model configuration mirrored from the manifest.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_count: usize,
    pub init_file: String,
}

/// Hadamard kernel artifact descriptor.
#[derive(Clone, Debug)]
pub struct HadamardInfo {
    pub rows: usize,
    pub p: usize,
    pub file: String,
}

/// Parsed manifest.json.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: HashMap<String, ModelInfo>,
    pub hadamard: Vec<HadamardInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!(
                "reading {}/manifest.json — run `make artifacts`",
                dir.display()
            )
        })?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = HashMap::new();
        if let Some(ms) = j.get("models").and_then(Json::as_obj) {
            for (name, m) in ms {
                let cfg = m.get("config").ok_or_else(|| anyhow!("model config"))?;
                let geti = |k: &str| -> Result<usize> {
                    cfg.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("manifest: missing {k}"))
                };
                models.insert(
                    name.clone(),
                    ModelInfo {
                        name: name.clone(),
                        vocab: geti("vocab")?,
                        d_model: geti("d_model")?,
                        n_layers: geti("n_layers")?,
                        n_heads: geti("n_heads")?,
                        d_ff: geti("d_ff")?,
                        seq_len: geti("seq_len")?,
                        batch: geti("batch")?,
                        param_count: m
                            .get("param_count")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("param_count"))?,
                        init_file: m
                            .get("init_file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("init_file"))?
                            .to_string(),
                    },
                );
            }
        }
        let mut hadamard = Vec::new();
        if let Some(hs) = j.get("hadamard").and_then(Json::as_obj) {
            for (key, h) in hs {
                let (rows, p) = key
                    .split_once('x')
                    .and_then(|(a, b)| Some((a.parse().ok()?, b.parse().ok()?)))
                    .ok_or_else(|| anyhow!("bad hadamard key {key}"))?;
                hadamard.push(HadamardInfo {
                    rows,
                    p,
                    file: h
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("hadamard file"))?
                        .to_string(),
                });
            }
        }
        Ok(Manifest {
            dir,
            models,
            hadamard,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model '{name}' not in manifest (rebuild artifacts with --models)")
        })
    }
}

/// Default artifact location probing, shared by both engine builds.
fn default_artifact_dir() -> Result<&'static str> {
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        if Path::new(cand).join("manifest.json").exists() {
            return Ok(cand);
        }
    }
    Err(anyhow!(
        "artifacts/manifest.json not found — run `make artifacts` first"
    ))
}

/// Initial parameters from the AOT'd init file (pure file I/O; shared by
/// both engine builds).
fn read_init_params(manifest: &Manifest, model: &str) -> Result<Vec<f32>> {
    let info = manifest.model(model)?;
    let bytes = std::fs::read(manifest.dir.join(&info.init_file))?;
    anyhow::ensure!(bytes.len() == info.param_count * 4, "init file size");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// The PJRT execution engine: one CPU client, lazily compiled executables.
#[cfg(feature = "pjrt")]
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<Engine> {
        Engine::load(default_artifact_dir()?)
    }

    fn exe(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(file) {
            let path = self.manifest.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("path utf8"))?,
            )
            .with_context(|| format!("loading HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.cache.insert(file.to_string(), exe);
        }
        Ok(&self.cache[file])
    }

    fn run(&mut self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(file)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    // ---- typed entry points ---------------------------------------------------

    /// Initial parameters (deterministic, seed 42 baked at AOT time).
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        read_init_params(&self.manifest, model)
    }

    /// Per-worker compute step: (loss, flat gradients).
    pub fn fwd_bwd(
        &mut self,
        model: &str,
        params: &[f32],
        tokens: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let info = self.manifest.model(model)?.clone();
        anyhow::ensure!(params.len() == info.param_count, "param len");
        anyhow::ensure!(
            tokens.len() == info.batch * (info.seq_len + 1),
            "token len {} != {}x{}",
            tokens.len(),
            info.batch,
            info.seq_len + 1
        );
        let p = xla::Literal::vec1(params);
        let t = xla::Literal::vec1(tokens)
            .reshape(&[info.batch as i64, info.seq_len as i64 + 1])?;
        let out = self.run(&format!("{model}_fwd_bwd.hlo.txt"), &[p, t])?;
        anyhow::ensure!(out.len() == 2, "fwd_bwd arity");
        let loss = out[0].get_first_element::<f32>()?;
        let grads = out[1].to_vec::<f32>()?;
        Ok((loss, grads))
    }

    /// Optimizer step over flat buffers → (params', momentum').
    pub fn apply(
        &mut self,
        model: &str,
        params: &[f32],
        grads: &[f32],
        momentum: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.run(
            &format!("{model}_apply.hlo.txt"),
            &[
                xla::Literal::vec1(params),
                xla::Literal::vec1(grads),
                xla::Literal::vec1(momentum),
                xla::Literal::scalar(lr),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "apply arity");
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<f32>()?))
    }

    /// Last-position logits [batch * vocab] (decode step).
    pub fn infer(&mut self, model: &str, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let info = self.manifest.model(model)?.clone();
        anyhow::ensure!(tokens.len() == info.batch * info.seq_len, "token len");
        let p = xla::Literal::vec1(params);
        let t =
            xla::Literal::vec1(tokens).reshape(&[info.batch as i64, info.seq_len as i64])?;
        let out = self.run(&format!("{model}_infer.hlo.txt"), &[p, t])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Next-token accuracy over [batch, seq_len+1] token sequences.
    pub fn accuracy(&mut self, model: &str, params: &[f32], tokens: &[i32]) -> Result<f32> {
        let info = self.manifest.model(model)?.clone();
        anyhow::ensure!(tokens.len() == info.batch * (info.seq_len + 1), "token len");
        let p = xla::Literal::vec1(params);
        let t = xla::Literal::vec1(tokens)
            .reshape(&[info.batch as i64, info.seq_len as i64 + 1])?;
        let out = self.run(&format!("{model}_accuracy.hlo.txt"), &[p, t])?;
        Ok(out[0].get_first_element::<f32>()?)
    }

    /// Block-wise Hadamard transform via the L1 Pallas artifact.
    /// `data.len()` must equal `rows * p` for a registered (rows, p) shape.
    pub fn hadamard(&mut self, rows: usize, p: usize, data: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(data.len() == rows * p, "hadamard input size");
        let info = self
            .manifest
            .hadamard
            .iter()
            .find(|h| h.rows == rows && h.p == p)
            .ok_or_else(|| anyhow!("no hadamard artifact for {rows}x{p}"))?
            .clone();
        let x = xla::Literal::vec1(data).reshape(&[rows as i64, p as i64])?;
        let out = self.run(&info.file, &[x])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Registered Hadamard kernel shapes.
    pub fn hadamard_shapes(&self) -> Vec<(usize, usize)> {
        self.manifest
            .hadamard
            .iter()
            .map(|h| (h.rows, h.p))
            .collect()
    }
}

/// Stub engine used when the `pjrt` feature is off (the default, offline
/// build). Manifest/file entry points behave identically; execution entry
/// points fail with a descriptive error instead of failing to link against
/// the absent XLA bindings. Tier-1 tests never construct an `Engine`.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        Ok(Engine {
            manifest: Manifest::load(dir)?,
        })
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<Engine> {
        Engine::load(default_artifact_dir()?)
    }

    fn unavailable<T>(what: &str) -> Result<T> {
        Err(anyhow!(
            "{what}: built without the `pjrt` feature — add the `xla` \
             dependency and rebuild with `--features pjrt` (requires the \
             XLA/PJRT native toolchain and `make artifacts`; see \
             rust/Cargo.toml)"
        ))
    }

    /// Initial parameters (pure file I/O; works without PJRT).
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        read_init_params(&self.manifest, model)
    }

    pub fn fwd_bwd(
        &mut self,
        _model: &str,
        _params: &[f32],
        _tokens: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        Self::unavailable("fwd_bwd")
    }

    pub fn apply(
        &mut self,
        _model: &str,
        _params: &[f32],
        _grads: &[f32],
        _momentum: &[f32],
        _lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        Self::unavailable("apply")
    }

    pub fn infer(&mut self, _model: &str, _params: &[f32], _tokens: &[i32]) -> Result<Vec<f32>> {
        Self::unavailable("infer")
    }

    pub fn accuracy(&mut self, _model: &str, _params: &[f32], _tokens: &[i32]) -> Result<f32> {
        Self::unavailable("accuracy")
    }

    pub fn hadamard(&mut self, _rows: usize, _p: usize, _data: &[f32]) -> Result<Vec<f32>> {
        Self::unavailable("hadamard")
    }

    /// Registered Hadamard kernel shapes (manifest only; works without
    /// PJRT).
    pub fn hadamard_shapes(&self) -> Vec<(usize, usize)> {
        self.manifest
            .hadamard
            .iter()
            .map(|h| (h.rows, h.p))
            .collect()
    }
}

// Quarantined behind the `pjrt` feature: these tests are genuinely
// environment-dependent — they execute AOT'd HLO through the XLA CPU
// client and need `make artifacts` to have run first. The tier-1 suite
// (`cargo test` with default features) skips them by construction.
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    // These tests require `make artifacts` to have run; they are the
    // L3↔L2↔L1 integration seam (the Makefile builds artifacts before
    // `cargo test`).

    fn engine() -> Engine {
        Engine::load_default().expect("run `make artifacts` first")
    }

    #[test]
    fn manifest_parses() {
        let e = engine();
        assert!(e.manifest.models.contains_key("tiny"));
        assert!(!e.manifest.hadamard.is_empty());
    }

    #[test]
    fn init_params_load() {
        let e = engine();
        let p = e.init_params("tiny").unwrap();
        assert_eq!(p.len(), e.manifest.model("tiny").unwrap().param_count);
        assert!(p.iter().all(|x| x.is_finite()));
        // layernorm gains initialized to 1 exist somewhere
        assert!(p.iter().any(|&x| x == 1.0));
    }

    #[test]
    fn fwd_bwd_executes() {
        let mut e = engine();
        let info = e.manifest.model("tiny").unwrap().clone();
        let params = e.init_params("tiny").unwrap();
        let tokens: Vec<i32> = (0..info.batch * (info.seq_len + 1))
            .map(|i| (i % info.vocab) as i32)
            .collect();
        let (loss, grads) = e.fwd_bwd("tiny", &params, &tokens).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.len(), params.len());
        // near-uniform loss at init
        let uniform = (info.vocab as f32).ln();
        assert!((loss - uniform).abs() < 1.5, "loss={loss} uniform={uniform}");
    }

    #[test]
    fn apply_step_moves_params() {
        let mut e = engine();
        let params = e.init_params("tiny").unwrap();
        let grads = vec![1.0f32; params.len()];
        let mom = vec![0.0f32; params.len()];
        let (p2, m2) = e.apply("tiny", &params, &grads, &mom, 0.1).unwrap();
        assert!((p2[0] - (params[0] - 0.1)).abs() < 1e-6);
        assert!((m2[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn hadamard_kernel_self_inverse() {
        let mut e = engine();
        let (rows, p) = e.hadamard_shapes()[0];
        let data: Vec<f32> = (0..rows * p).map(|i| (i as f32 * 0.37).sin()).collect();
        let enc = e.hadamard(rows, p, &data).unwrap();
        let dec = e.hadamard(rows, p, &enc).unwrap();
        for (a, b) in dec.iter().zip(data.iter()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // encode actually changed the data
        assert!(enc
            .iter()
            .zip(data.iter())
            .any(|(a, b)| (a - b).abs() > 1e-3));
    }

    #[test]
    fn loss_decreases_over_pjrt_steps() {
        // the whole train loop through PJRT: a few steps must reduce loss
        let mut e = engine();
        let info = e.manifest.model("tiny").unwrap().clone();
        let mut params = e.init_params("tiny").unwrap();
        let mut mom = vec![0.0f32; params.len()];
        let corpus = crate::data::Corpus::new(info.vocab, 0xC0FFEE);
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..8 {
            let tokens = corpus.batch(info.batch, info.seq_len + 1, step as u64);
            let (loss, grads) = e.fwd_bwd("tiny", &params, &tokens).unwrap();
            let (p2, m2) = e.apply("tiny", &params, &grads, &mom, 0.05).unwrap();
            params = p2;
            mom = m2;
            if step == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first - 0.1, "loss did not decrease: {first} → {last}");
    }
}
