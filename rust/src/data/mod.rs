//! Synthetic training corpus (substitution for ARC-Challenge, DESIGN.md §2).
//!
//! Token streams follow a noisy affine bigram process
//! `next = (3·cur + noise) mod V`, with `noise ∈ [0, 4)` drawn from a
//! Zipf-tilted distribution. The process is (a) learnable — a transformer
//! quickly drops below the uniform-loss floor by modeling the bigram —
//! and (b) never saturates to zero loss (the noise term), so loss curves
//! keep discriminating between transports for hundreds of steps.
//!
//! Mirrored by `python/tests/test_model.py::synth_batch`; kept dependency-
//! free and deterministic per (seed, step) so every simulated worker can
//! draw its own shard without coordination.

use crate::util::prng::Pcg64;

#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub seed: u64,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        Corpus { vocab, seed }
    }

    /// One [batch, len] token block for a global step (flattened, i32).
    /// Different `step` values yield disjoint pseudo-documents.
    pub fn batch(&self, batch: usize, len: usize, step: u64) -> Vec<i32> {
        self.batch_for_worker(batch, len, step, 0)
    }

    /// Shard by worker so data-parallel ranks see different data.
    pub fn batch_for_worker(
        &self,
        batch: usize,
        len: usize,
        step: u64,
        worker: u64,
    ) -> Vec<i32> {
        let mut rng = Pcg64::new(self.seed ^ (step.wrapping_mul(0x9e37_79b9)), worker);
        let mut out = Vec::with_capacity(batch * len);
        for _ in 0..batch {
            let mut cur = rng.below(self.vocab as u64) as i64;
            out.push(cur as i32);
            for _ in 1..len {
                // Zipf-tilted noise: 0 is most likely, 3 least
                let r = rng.f64();
                let noise = if r < 0.55 {
                    0
                } else if r < 0.8 {
                    1
                } else if r < 0.95 {
                    2
                } else {
                    3
                };
                cur = (3 * cur + noise) % self.vocab as i64;
                out.push(cur as i32);
            }
        }
        out
    }

    /// Held-out evaluation batch (disjoint seed space from training).
    pub fn eval_batch(&self, batch: usize, len: usize, idx: u64) -> Vec<i32> {
        self.batch_for_worker(batch, len, idx ^ 0xEEEE_EEEE, EVAL_WORKER)
    }
}

const EVAL_WORKER: u64 = 0xE7A1;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_step() {
        let c = Corpus::new(256, 7);
        assert_eq!(c.batch(4, 16, 0), c.batch(4, 16, 0));
        assert_ne!(c.batch(4, 16, 0), c.batch(4, 16, 1));
    }

    #[test]
    fn workers_get_disjoint_data() {
        let c = Corpus::new(256, 7);
        assert_ne!(
            c.batch_for_worker(4, 16, 0, 0),
            c.batch_for_worker(4, 16, 0, 1)
        );
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::new(100, 3);
        for t in c.batch(8, 64, 5) {
            assert!((0..100).contains(&t));
        }
    }

    #[test]
    fn bigram_structure_present() {
        // the most common successor of token t must be (3t) % V
        let c = Corpus::new(64, 9);
        let data = c.batch(64, 128, 2);
        let mut hits = 0;
        let mut total = 0;
        for seq in data.chunks(128) {
            for w in seq.windows(2) {
                total += 1;
                if w[1] as i64 == (3 * w[0] as i64) % 64 {
                    hits += 1;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.45, "bigram frac {frac}");
    }

    #[test]
    fn eval_disjoint_from_train() {
        let c = Corpus::new(256, 7);
        assert_ne!(c.eval_batch(4, 16, 0), c.batch(4, 16, 0));
    }
}
