//! FPGA resource model (paper Table 5): LUT / LUTRAM / FF / BRAM / power
//! for each transport synthesized at 10 K QPs on an Alveo U250.
//!
//! Substitution for Vivado synthesis (DESIGN.md §2): every design is
//! `shell + Σ components`. Component logic costs (LUT/LUTRAM/FF) were
//! calibrated once against the paper's Table 5; memory (BRAM) is computed
//! *structurally* from first principles:
//!
//!   BRAM(design) = shell_brams
//!                + ceil(qp_state_bytes × num_qps / BRAM_BYTES)   (QP store)
//!                + reorder_buffer_bytes / BRAM_BYTES             (IRN/Falcon)
//!                + retransmission_queue                          (HW-retrans)
//!
//! which reproduces the published BRAM column to within rounding — evidence
//! the paper's numbers are themselves this bookkeeping.

use crate::transport::TransportKind;

/// Usable bytes per 36 Kb BRAM tile (4.5 KB).
pub const BRAM_BYTES: usize = 4608;
/// QP count the paper synthesizes for.
pub const NUM_QPS: usize = 10_000;
/// Coyote shell + streaming datapath baseline (no reliability subsystems).
const SHELL_LUT: f64 = 296_000.0;
const SHELL_LUTRAM: f64 = 21_500.0;
const SHELL_FF: f64 = 539_000.0;
const SHELL_BRAM: f64 = 390.0;
/// 1.2 MB NIC reorder buffer (IRN/Falcon prototypes, §4).
const REORDER_BUFFER_BYTES: usize = 1_200_000;
/// Retransmission staging queue for HW-retrans designs (≈1 MiB).
const RETRANS_QUEUE_BRAMS: f64 = 230.0;

/// Logic-cost component (calibrated against the paper's synthesis).
#[derive(Clone, Copy, Debug)]
pub struct LogicComponent {
    pub name: &'static str,
    pub lut: f64,
    pub lutram: f64,
    pub ff: f64,
}

const fn lc(name: &'static str, lut: f64, lutram: f64, ff: f64) -> LogicComponent {
    LogicComponent {
        name,
        lut,
        lutram,
        ff,
    }
}

const GBN_ENGINE: LogicComponent =
    lc("Go-Back-N retransmission engine", 9_000.0, 1_000.0, 12_000.0);
const INORDER_LOGIC: LogicComponent =
    lc("in-order enforcement + PFC", 7_400.0, 800.0, 11_100.0);
const SR_ENGINE: LogicComponent =
    lc("selective-repeat engine", 13_000.0, 1_500.0, 18_000.0);
const BITMAP_TRACKER: LogicComponent =
    lc("bitmap tracking + SACK assembly", 6_200.0, 800.0, 9_000.0);
const OOO_RESEQ: LogicComponent =
    lc("reorder-buffer manager", 4_400.0, 400.0, 7_100.0);
const SRNIC_HOSTIF: LogicComponent =
    lc("host-recovery interface + cumulative ACK", 8_500.0, 1_000.0, 12_500.0);
const FALCON_MP: LogicComponent =
    lc("multipath select + resequencer + delay CC", 13_800.0, 1_600.0, 20_200.0);
const XP_TIMEOUT: LogicComponent =
    lc("bounded-completion timers + byte counters", 2_400.0, 200.0, 4_000.0);

/// Full synthesis-style report for one design.
#[derive(Clone, Debug)]
pub struct ResourceReport {
    pub kind: TransportKind,
    pub lut: f64,
    pub lutram: f64,
    pub ff: f64,
    pub bram: f64,
    pub power_w: f64,
    pub mtbf_hours: f64,
    pub components: Vec<&'static str>,
}

fn logic_components(kind: TransportKind) -> Vec<LogicComponent> {
    match kind {
        TransportKind::Roce | TransportKind::Uccl => vec![GBN_ENGINE, INORDER_LOGIC],
        TransportKind::Irn => vec![SR_ENGINE, BITMAP_TRACKER, OOO_RESEQ],
        TransportKind::Srnic => vec![SRNIC_HOSTIF],
        TransportKind::Falcon => vec![FALCON_MP],
        TransportKind::Optinic | TransportKind::OptinicHw => vec![XP_TIMEOUT],
    }
}

fn has_hw_retrans_queue(kind: TransportKind) -> bool {
    matches!(
        kind,
        TransportKind::Roce | TransportKind::Uccl | TransportKind::Irn | TransportKind::Falcon
    )
}

fn has_reorder_buffer(kind: TransportKind) -> bool {
    matches!(kind, TransportKind::Irn | TransportKind::Falcon)
}

/// "Synthesize" a design: compute its resource report.
pub fn synthesize(kind: TransportKind) -> ResourceReport {
    let logic = logic_components(kind);
    let lut = SHELL_LUT + logic.iter().map(|c| c.lut).sum::<f64>();
    let lutram = SHELL_LUTRAM + logic.iter().map(|c| c.lutram).sum::<f64>();
    let ff = SHELL_FF + logic.iter().map(|c| c.ff).sum::<f64>();

    // structural BRAM
    let qp_bytes = crate::hw::qp_state::breakdown(kind).total();
    let qp_store = (qp_bytes * NUM_QPS) as f64 / BRAM_BYTES as f64;
    let mut bram = SHELL_BRAM + qp_store;
    if has_reorder_buffer(kind) {
        bram += REORDER_BUFFER_BYTES as f64 / BRAM_BYTES as f64;
    }
    if has_hw_retrans_queue(kind) {
        bram += RETRANS_QUEUE_BRAMS;
    }

    // power: linear in logic + memory activity, anchored at the OptiNIC
    // (32.5 W) operating point
    let power_w = 32.5 + 0.1 * (lut - 298_400.0) / 1_000.0 + 0.8 * (bram - 503.0) / 1_000.0;

    let mtbf_hours = crate::hw::seu::mtbf_hours(ff, bram, lutram);

    ResourceReport {
        kind,
        lut,
        lutram,
        ff,
        bram,
        power_w,
        mtbf_hours,
        components: logic.iter().map(|c| c.name).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn within(actual: f64, paper: f64, tol_frac: f64) -> bool {
        (actual - paper).abs() / paper <= tol_frac
    }

    /// Table 5, LUT column (K).
    #[test]
    fn lut_matches_paper() {
        let rows = [
            (TransportKind::Roce, 312.4),
            (TransportKind::Irn, 319.6),
            (TransportKind::Srnic, 304.5),
            (TransportKind::Falcon, 309.8),
            (TransportKind::Uccl, 312.4),
            (TransportKind::Optinic, 298.4),
        ];
        for (k, paper_k) in rows {
            let r = synthesize(k);
            assert!(
                within(r.lut / 1000.0, paper_k, 0.01),
                "{:?}: {} vs {paper_k}",
                k,
                r.lut / 1000.0
            );
        }
    }

    /// Table 5, BRAM column — structural computation, ±10%.
    #[test]
    fn bram_matches_paper() {
        let rows = [
            (TransportKind::Roce, 1500.0),
            (TransportKind::Irn, 2200.0),
            (TransportKind::Srnic, 900.0),
            (TransportKind::Falcon, 1600.0),
            (TransportKind::Uccl, 1500.0),
            (TransportKind::Optinic, 500.0),
        ];
        for (k, paper) in rows {
            let r = synthesize(k);
            assert!(
                within(r.bram, paper, 0.1),
                "{:?}: {} vs {paper}",
                k,
                r.bram
            );
        }
    }

    #[test]
    fn bram_reduction_factor() {
        // headline: 2.7× lower BRAM than RoCE (abstract), 63–73% reduction
        let roce = synthesize(TransportKind::Roce).bram;
        let opt = synthesize(TransportKind::Optinic).bram;
        let factor = roce / opt;
        assert!((2.4..=3.3).contains(&factor), "factor={factor}");
    }

    #[test]
    fn power_ordering() {
        let p: Vec<f64> = [
            TransportKind::Irn,
            TransportKind::Roce,
            TransportKind::Falcon,
            TransportKind::Srnic,
            TransportKind::Optinic,
        ]
        .iter()
        .map(|k| synthesize(*k).power_w)
        .collect();
        // monotone decreasing in the order above
        for w in p.windows(2) {
            assert!(w[0] > w[1], "{p:?}");
        }
        let opt = synthesize(TransportKind::Optinic).power_w;
        assert!((32.0..33.0).contains(&opt));
    }

    #[test]
    fn optinic_smallest_everything() {
        let opt = synthesize(TransportKind::Optinic);
        for k in TransportKind::ALL {
            if k == TransportKind::Optinic {
                continue;
            }
            let r = synthesize(k);
            assert!(opt.lut <= r.lut);
            assert!(opt.ff <= r.ff);
            assert!(opt.bram <= r.bram);
            assert!(opt.mtbf_hours >= r.mtbf_hours);
        }
    }
}
