//! Per-QP NIC context accounting (paper Table 4).
//!
//! Each design's per-QP SRAM footprint is the sum of the state its
//! protocol machine keeps per connection. The component list below is the
//! bookkeeping behind Table 4's "NIC State per QP" row; OptiNIC's row is
//! the paper's §2.4 claim ("reduces per-QP state to just 20 bytes" of
//! transport state + CC metadata + addressing = 52 B NIC context).

use crate::transport::TransportKind;

/// One named piece of per-QP state.
#[derive(Clone, Copy, Debug)]
pub struct Component {
    pub name: &'static str,
    pub bytes: usize,
}

#[derive(Clone, Debug)]
pub struct QpStateBreakdown {
    pub kind: TransportKind,
    pub components: Vec<Component>,
}

impl QpStateBreakdown {
    pub fn total(&self) -> usize {
        self.components.iter().map(|c| c.bytes).sum()
    }
}

const fn c(name: &'static str, bytes: usize) -> Component {
    Component { name, bytes }
}

// Shared building blocks ------------------------------------------------------

/// Connection addressing & basic QPC: QPN pair, GID/route, MTU, QP state
/// machine word. Present in every connected transport.
const BASE_ADDRESSING: Component = c("connection addressing + QPC base", 84);
/// Send-queue state: SQ ring pointers, next PSN, in-flight window, doorbell.
const SEND_QUEUE: Component = c("send queue state (PSN, window, ring ptrs)", 64);
/// Receive-queue state: RQ ring pointers, expected PSN, MSN.
const RECV_QUEUE: Component = c("recv queue state (ePSN, ring ptrs)", 48);
/// Hardware retransmission: retry counters, RNR/retry timers, last-acked,
/// rewind registers.
const HW_RETRANS: Component = c("retransmission engine state (timers, retries)", 56);
/// Strict in-order enforcement & drop/dup detection.
const INORDER: Component = c("in-order tracking + dup detection", 35);
/// On-NIC WQE cache (RoCE-class NICs cache outstanding WQEs).
const WQE_CACHE: Component = c("WQE cache entries", 100);
/// DCQCN-class CC metadata (rates, alpha, byte counter, timestamps).
const CC_META: Component = c("congestion-control metadata", 20);
/// IRN: per-QP receive bitmap windows (BSN tracking).
const IRN_BITMAP: Component = c("selective-repeat bitmaps (rx/tx BSN windows)", 128);
/// IRN: outstanding-request table entries + SACK assembly.
const IRN_OUTSTANDING: Component = c("outstanding-request table + SACK state", 61);
/// SRNIC: lean cumulative-ACK + host-recovery handle.
const SRNIC_LEAN: Component = c("cumulative ACK + host recovery handle", 26);
/// Falcon: delay-based CC state (Swift RTT filters).
const FALCON_CC: Component = c("delay-based CC state (RTT filters)", 28);
/// Falcon: multipath state (path table, per-path CWND shares, resequencer).
const FALCON_MULTIPATH: Component = c("multipath/resequencing state", 50);
/// Falcon: sliding-window bitmap (smaller than IRN's).
const FALCON_WINDOW: Component = c("sliding-window tracking", 20);

/// OptiNIC XP: the 20 B transport context of §2.4 ...
const XP_EXPECTED_SEQ: Component = c("expected wqe_seq", 4);
const XP_BYTE_COUNTER: Component = c("active-message byte counter", 4);
const XP_MSG_LEN: Component = c("active-message length", 4);
const XP_DEADLINE: Component = c("deadline register (48-bit ns)", 6);
const XP_DST: Component = c("active placement base (mr, offset)", 2);
/// ... plus addressing + CC.
const XP_ADDRESSING: Component = c("connection addressing (minimal)", 12);

/// The per-QP state breakdown for a design.
pub fn breakdown(kind: TransportKind) -> QpStateBreakdown {
    let components = match kind {
        TransportKind::Roce => vec![
            BASE_ADDRESSING,
            SEND_QUEUE,
            RECV_QUEUE,
            HW_RETRANS,
            INORDER,
            WQE_CACHE,
            CC_META,
        ],
        TransportKind::Irn => vec![
            BASE_ADDRESSING,
            SEND_QUEUE,
            RECV_QUEUE,
            HW_RETRANS,
            INORDER,
            WQE_CACHE,
            CC_META,
            IRN_BITMAP,
            IRN_OUTSTANDING,
        ],
        TransportKind::Srnic => vec![
            BASE_ADDRESSING,
            SEND_QUEUE,
            RECV_QUEUE,
            SRNIC_LEAN,
            CC_META,
        ],
        TransportKind::Falcon => vec![
            BASE_ADDRESSING,
            SEND_QUEUE,
            RECV_QUEUE,
            HW_RETRANS,
            FALCON_CC,
            FALCON_MULTIPATH,
            FALCON_WINDOW,
        ],
        // UCCL runs on an unmodified RoCE NIC: the NIC-side QPC is RoCE's.
        TransportKind::Uccl => vec![
            BASE_ADDRESSING,
            SEND_QUEUE,
            RECV_QUEUE,
            HW_RETRANS,
            INORDER,
            WQE_CACHE,
            CC_META,
        ],
        TransportKind::Optinic | TransportKind::OptinicHw => vec![
            XP_ADDRESSING,
            XP_EXPECTED_SEQ,
            XP_BYTE_COUNTER,
            XP_MSG_LEN,
            XP_DEADLINE,
            XP_DST,
            CC_META,
        ],
    };
    QpStateBreakdown { kind, components }
}

/// SRAM budget used by Table 4's "Max QPs" column.
pub const SRAM_BUDGET_BYTES: usize = 4 * 1024 * 1024;

/// Connections each design opens per peer (UCCL opens 256; others 2 —
/// control + data, §5.3.4).
pub fn conns_per_peer(kind: TransportKind) -> usize {
    match kind {
        TransportKind::Uccl => crate::transport::uccl::CONNS_PER_PEER,
        _ => 2,
    }
}

/// Max QPs within the SRAM budget.
pub fn max_qps(kind: TransportKind) -> usize {
    SRAM_BUDGET_BYTES / breakdown(kind).total()
}

/// Cluster size supportable: every node talks to every other node through
/// `conns_per_peer` QPs.
pub fn cluster_size(kind: TransportKind) -> usize {
    max_qps(kind) / conns_per_peer(kind)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin the Table 4 "NIC State per QP" row exactly.
    #[test]
    fn matches_paper_table4_state() {
        assert_eq!(breakdown(TransportKind::Roce).total(), 407);
        assert_eq!(breakdown(TransportKind::Irn).total(), 596);
        assert_eq!(breakdown(TransportKind::Srnic).total(), 242);
        assert_eq!(breakdown(TransportKind::Falcon).total(), 350);
        assert_eq!(breakdown(TransportKind::Uccl).total(), 407);
        assert_eq!(breakdown(TransportKind::Optinic).total(), 52);
    }

    #[test]
    fn optinic_transport_state_is_20_bytes() {
        // §2.4: "reduces per-QP state to just 20 bytes" — transport fields
        // only (excluding addressing and CC metadata).
        let b = breakdown(TransportKind::Optinic);
        let transport_only: usize = b
            .components
            .iter()
            .filter(|c| {
                !c.name.contains("addressing") && !c.name.contains("congestion")
            })
            .map(|c| c.bytes)
            .sum();
        assert_eq!(transport_only, 20);
    }

    #[test]
    fn qp_scalability_ordering() {
        // OptiNIC supports ~an order of magnitude more QPs than RoCE
        assert!(max_qps(TransportKind::Optinic) >= 7 * max_qps(TransportKind::Roce));
        // ~80K QPs within 4 MB
        let q = max_qps(TransportKind::Optinic);
        assert!((70_000..=90_000).contains(&q), "{q}");
        // UCCL cluster size collapses due to 256 conns/peer
        assert!(cluster_size(TransportKind::Uccl) < 100);
        assert!(cluster_size(TransportKind::Optinic) > 30_000);
    }

    #[test]
    fn hw_and_sw_optinic_identical_context() {
        assert_eq!(
            breakdown(TransportKind::Optinic).total(),
            breakdown(TransportKind::OptinicHw).total()
        );
    }
}
