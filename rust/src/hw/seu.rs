//! SEU (single-event upset) reliability model — the MTBF column of Table 5.
//!
//! Substitution for the Xilinx SEU Estimator (§5.1.2): soft-error
//! susceptibility is proportional to the critical state bits a design keeps
//! live — flip-flops at full weight, BRAM bits derated (interleaved ECC +
//! SEM scrubbing repairs most configuration upsets, but protocol state in
//! BRAM that is consumed before the scrub interval still corrupts
//! behavior), LUTRAM in between. The fleet-level failure rate scales the
//! per-device FIT by the deployment (15 000 nodes) and the junction-
//! temperature acceleration at 100 °C (§5.1.2).
//!
//!   MTBF(design) = K / (FF_K + W_BRAM·BRAM_tiles_K·36 + W_LUTRAM·LUTRAM_K)
//!
//! with K anchored so that the RoCE design lands at its measured 42.8 h.

/// BRAM weight (per K-tile·Kbit): protocol state held in BRAM dominates the
/// behavioral-SEU cross-section relative to distributed FFs because a tile
/// concentrates thousands of live protocol bits behind one address decoder.
/// Fitted once against the paper's (RoCE, OptiNIC) MTBF anchor pair.
const W_BRAM: f64 = 22.89;
/// LUTRAM weight per K entries.
const W_LUTRAM: f64 = 0.0; // LUTRAM upsets are overwhelmingly scrub-repaired
/// Anchor constant: RoCE (FF=562.1K, BRAM=1503 tiles) ⇒ 42.8 h.
const K_ANCHOR: f64 = 77_057.0;

/// Cluster-scale MTBF in hours for a design with the given resource usage
/// (`ff`, `lutram` in cells; `bram` in 36 Kb tiles).
pub fn mtbf_hours(ff: f64, bram: f64, lutram: f64) -> f64 {
    let critical =
        ff / 1000.0 + W_BRAM * (bram / 1000.0) * 36.0 + W_LUTRAM * lutram / 1000.0;
    K_ANCHOR / critical
}

/// Per-event fault model used by the behavioral fault-injection experiment:
/// how often, in simulated time, does a given design take an SEU hit that
/// lands in *protocol* state? Derived from the same critical-bit count.
#[derive(Clone, Copy, Debug)]
pub struct SeuModel {
    /// Mean time between protocol-state upsets across the cluster, ns.
    pub mean_upset_interval_ns: f64,
}

impl SeuModel {
    /// Build from a resource report, compressing real-world hours to
    /// simulated seconds with `accel` (fault-acceleration factor), so the
    /// experiment observes many faults in a short simulated window.
    pub fn from_mtbf(mtbf_hours: f64, accel: f64) -> SeuModel {
        let ns = mtbf_hours * 3600.0 * 1e9 / accel;
        SeuModel {
            mean_upset_interval_ns: ns,
        }
    }

    pub fn next_upset_after(&self, rng: &mut crate::util::prng::Pcg64) -> u64 {
        rng.exponential(1.0 / self.mean_upset_interval_ns) as u64 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 5, MTBF column, ±6%.
    #[test]
    fn mtbf_matches_paper() {
        let rows: [(f64, f64, f64, f64); 6] = [
            // (ff, bram, lutram, paper_mtbf_h)
            (562_100.0, 1503.0, 23_300.0, 42.8), // RoCE
            (573_100.0, 2183.0, 24_200.0, 30.9), // IRN
            (551_500.0, 915.0, 22_500.0, 57.8),  // SRNIC
            (559_200.0, 1647.0, 23_100.0, 40.5), // Falcon
            (562_100.0, 1503.0, 23_300.0, 42.8), // UCCL
            (543_000.0, 503.0, 21_700.0, 80.5),  // OptiNIC
        ];
        for (ff, bram, lutram, paper) in rows {
            let m = mtbf_hours(ff, bram, lutram);
            assert!(
                (m - paper).abs() / paper < 0.06,
                "mtbf {m} vs paper {paper}"
            );
        }
    }

    #[test]
    fn optinic_nearly_doubles_mtbf() {
        let roce = mtbf_hours(562_100.0, 1503.0, 23_300.0);
        let opt = mtbf_hours(543_000.0, 503.0, 21_700.0);
        let ratio = opt / roce;
        assert!((1.7..=2.1).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn seu_model_interval_scales() {
        let mut rng = crate::util::prng::Pcg64::seeded(3);
        let fast = SeuModel::from_mtbf(40.0, 1e12);
        let mean = (0..2000)
            .map(|_| fast.next_upset_after(&mut rng) as f64)
            .sum::<f64>()
            / 2000.0;
        let expect = 40.0 * 3600.0 * 1e9 / 1e12;
        assert!((mean - expect).abs() / expect < 0.1, "{mean} vs {expect}");
    }
}
