//! Hardware model: per-QP NIC state accounting (Table 4), FPGA resource
//! model (Table 5), SEU/MTBF reliability model, and behavioral fault
//! injection (§2.4, §5.3.4–5.3.5).
//!
//! The paper synthesized each design on an Alveo U250 via Coyote-v2 +
//! Vivado 2022.1 at 10 K QPs. We have no FPGA toolchain, so this module is
//! an *analytical* substitution (DESIGN.md §2): each design is a sum of
//! subsystem components (shell, QP context store, retransmission engine,
//! reorder buffers, bitmap trackers, timeout logic, ...), with component
//! costs calibrated once against the paper's published synthesis results.
//! The QP-state table is *derived from the protocol state machines we
//! actually implement* in `transport/` — a consistency test pins the two
//! together.

pub mod fault;
pub mod qp_state;
pub mod resources;
pub mod seu;

pub use qp_state::{breakdown, QpStateBreakdown};
pub use resources::{synthesize, ResourceReport};
pub use seu::{mtbf_hours, SeuModel};
