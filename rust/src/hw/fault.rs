//! Behavioral fault injection (§2.4): SEU hits land in live NIC protocol
//! state while a workload runs; reliable designs can stall a QP forever
//! (stuck timer, corrupted sequence number), while OptiNIC's tiny,
//! self-healing state degrades to at-worst a partial completion.
//!
//! This module computes fault *schedules* from the SEU model; the actual
//! corruption happens via `Transport::inject_fault` through the engine's
//! `Event::InjectFault`. Results are summarized by [`FaultOutcome`].
//!
//! Since the leaf–spine rework it also builds the *network-level* fault
//! scenarios — link flap, degraded link, spine failure — on top of the
//! engine's `Event::NetFault` machinery (docs/TOPOLOGY.md §Faults).

use crate::hw::seu::SeuModel;
use crate::net::{LinkId, NetFault};
use crate::sim::cluster::Cluster;
use crate::sim::SimTime;
use crate::transport::TransportKind;
use crate::util::prng::Pcg64;

/// Outcome of a fault-injection run.
#[derive(Clone, Debug, Default)]
pub struct FaultOutcome {
    pub faults_injected: u64,
    pub stalled_qps: usize,
    pub workload_completed: bool,
    pub sim_time_ns: SimTime,
}

/// Schedule Poisson fault arrivals over `[0, horizon]` using the design's
/// MTBF compressed by `accel`. Returns the number of scheduled injections.
pub fn schedule_faults(
    cluster: &mut Cluster,
    kind: TransportKind,
    horizon: SimTime,
    accel: f64,
    seed: u64,
) -> usize {
    let report = crate::hw::resources::synthesize(kind);
    let model = SeuModel::from_mtbf(report.mtbf_hours, accel);
    let mut rng = Pcg64::new(seed, 0xfa017);
    let mut t: SimTime = 0;
    let mut n = 0;
    loop {
        t += model.next_upset_after(&mut rng);
        if t >= horizon {
            break;
        }
        cluster.schedule_fault(t);
        n += 1;
    }
    n
}

// ---- network-level fault scenarios (leaf–spine) -----------------------------

/// Link flap: `link` blackholes at `down_at` and recovers at `up_at`.
/// Routing converges (masks the link out of ECMP/spray) `reroute_ns`
/// after the failure; recovery clears the mask.
pub fn schedule_link_flap(cluster: &mut Cluster, link: LinkId, down_at: SimTime, up_at: SimTime) {
    assert!(up_at > down_at, "flap must recover after it fails");
    cluster.schedule_net_fault(down_at, NetFault::LinkDown(link));
    cluster.schedule_net_fault(up_at, NetFault::LinkUp(link));
}

/// Spine failure: every link touching `spine` goes down at `down_at`
/// (and, if `up_at` is given, the whole spine returns). Requires a
/// leaf–spine fabric.
pub fn schedule_spine_failure(
    cluster: &mut Cluster,
    spine: usize,
    down_at: SimTime,
    up_at: Option<SimTime>,
) {
    let links = cluster.fabric.topo.spine_links(spine);
    assert!(
        !links.is_empty(),
        "spine failure needs a leaf–spine topology"
    );
    for link in links {
        cluster.schedule_net_fault(down_at, NetFault::LinkDown(link));
        if let Some(up) = up_at {
            cluster.schedule_net_fault(up, NetFault::LinkUp(link));
        }
    }
}

/// Degraded link: serialization stretches by `factor` from `at` on
/// (schedule a second call with factor 1 to heal).
pub fn schedule_link_degrade(cluster: &mut Cluster, link: LinkId, at: SimTime, factor: u32) {
    cluster.schedule_net_fault(at, NetFault::Degrade(link, factor));
}

/// Summarize a finished run.
pub fn outcome(cluster: &Cluster, completed: bool) -> FaultOutcome {
    FaultOutcome {
        faults_injected: cluster.metrics.counter("faults_injected"),
        stalled_qps: cluster.total_stalled_qps(),
        workload_completed: completed,
        sim_time_ns: cluster.time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FabricCfg;
    use crate::sim::cluster::ClusterCfg;

    #[test]
    fn spine_failure_downs_and_restores_every_spine_link() {
        let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic));
        schedule_spine_failure(&mut c, 0, 10, Some(1_000_000));
        let links = c.fabric.topo.spine_links(0);
        c.run_until(20);
        for &l in &links {
            assert!(!c.fabric.ports[l].up, "link {l} must be down");
        }
        // routing convergence masks the dead links after reroute_ns
        c.run_until(20 + c.cfg.fabric.reroute_ns + 10);
        for &l in &links {
            assert!(c.fabric.ports[l].routed_out, "link {l} must be masked");
        }
        // spine 1 untouched
        for &l in &c.fabric.topo.spine_links(1) {
            assert!(c.fabric.ports[l].up && !c.fabric.ports[l].routed_out);
        }
        c.run_until(1_000_100);
        for &l in &links {
            assert!(c.fabric.ports[l].up && !c.fabric.ports[l].routed_out);
        }
        assert!(c.metrics.counter("net_faults") >= 8);
    }

    #[test]
    fn link_degrade_takes_effect_on_schedule() {
        let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic));
        let link = c.fabric.topo.up_link(0, 0);
        schedule_link_degrade(&mut c, link, 50, 8);
        c.run_until(100);
        assert_eq!(c.fabric.ports[link].degrade, 8);
        schedule_link_degrade(&mut c, link, 200, 1);
        c.run_until(300);
        assert_eq!(c.fabric.ports[link].degrade, 1);
    }

    #[test]
    fn schedules_proportional_to_inverse_mtbf() {
        let horizon = 10 * crate::sim::MS;
        let accel = 1e13;
        let mk = |kind| {
            let mut c = Cluster::new(ClusterCfg::new(FabricCfg::cloudlab(4), kind));
            schedule_faults(&mut c, kind, horizon, accel, 42)
        };
        let irn = mk(TransportKind::Irn); // lowest MTBF → most faults
        let opt = mk(TransportKind::Optinic); // highest MTBF → fewest
        assert!(irn > opt, "irn={irn} opt={opt}");
        assert!(opt > 0);
    }
}
