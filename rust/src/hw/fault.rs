//! Behavioral fault injection (§2.4): SEU hits land in live NIC protocol
//! state while a workload runs; reliable designs can stall a QP forever
//! (stuck timer, corrupted sequence number), while OptiNIC's tiny,
//! self-healing state degrades to at-worst a partial completion.
//!
//! This module computes fault *schedules* from the SEU model; the actual
//! corruption happens via `Transport::inject_fault` through the engine's
//! `Event::InjectFault`. Results are summarized by [`FaultOutcome`].

use crate::hw::seu::SeuModel;
use crate::sim::cluster::Cluster;
use crate::sim::SimTime;
use crate::transport::TransportKind;
use crate::util::prng::Pcg64;

/// Outcome of a fault-injection run.
#[derive(Clone, Debug, Default)]
pub struct FaultOutcome {
    pub faults_injected: u64,
    pub stalled_qps: usize,
    pub workload_completed: bool,
    pub sim_time_ns: SimTime,
}

/// Schedule Poisson fault arrivals over `[0, horizon]` using the design's
/// MTBF compressed by `accel`. Returns the number of scheduled injections.
pub fn schedule_faults(
    cluster: &mut Cluster,
    kind: TransportKind,
    horizon: SimTime,
    accel: f64,
    seed: u64,
) -> usize {
    let report = crate::hw::resources::synthesize(kind);
    let model = SeuModel::from_mtbf(report.mtbf_hours, accel);
    let mut rng = Pcg64::new(seed, 0xfa017);
    let mut t: SimTime = 0;
    let mut n = 0;
    loop {
        t += model.next_upset_after(&mut rng);
        if t >= horizon {
            break;
        }
        cluster.schedule_fault(t);
        n += 1;
    }
    n
}

/// Summarize a finished run.
pub fn outcome(cluster: &Cluster, completed: bool) -> FaultOutcome {
    FaultOutcome {
        faults_injected: cluster.metrics.counter("faults_injected"),
        stalled_qps: cluster.total_stalled_qps(),
        workload_completed: completed,
        sim_time_ns: cluster.time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FabricCfg;
    use crate::sim::cluster::ClusterCfg;

    #[test]
    fn schedules_proportional_to_inverse_mtbf() {
        let horizon = 10 * crate::sim::MS;
        let accel = 1e13;
        let mk = |kind| {
            let mut c = Cluster::new(ClusterCfg::new(FabricCfg::cloudlab(4), kind));
            schedule_faults(&mut c, kind, horizon, accel, 42)
        };
        let irn = mk(TransportKind::Irn); // lowest MTBF → most faults
        let opt = mk(TransportKind::Optinic); // highest MTBF → fewest
        assert!(irn > opt, "irn={irn} opt={opt}");
        assert!(opt > 0);
    }
}
