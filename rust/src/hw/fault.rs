//! Behavioral fault injection (§2.4): SEU hits land in live NIC protocol
//! state while a workload runs; reliable designs can stall a QP forever
//! (stuck timer, corrupted sequence number), while OptiNIC's tiny,
//! self-healing state degrades to at-worst a partial completion.
//!
//! This module computes fault *schedules* from the SEU model; the actual
//! corruption happens via `Transport::inject_fault` through the engine's
//! `Event::InjectFault`. Results are summarized by [`FaultOutcome`].
//!
//! Since the leaf–spine rework it also builds the *network-level* fault
//! scenarios — link flap, degraded link, spine failure — on top of the
//! engine's `Event::NetFault` machinery (docs/TOPOLOGY.md §Faults).

use crate::hw::seu::SeuModel;
use crate::net::{LinkId, NetFault};
use crate::sim::cluster::Cluster;
use crate::sim::SimTime;
use crate::transport::TransportKind;
use crate::util::prng::Pcg64;

/// Outcome of a fault-injection run.
///
/// `faults_scheduled` and `faults_injected` deliberately differ: the
/// schedule is drawn over the whole horizon up front, but an upset only
/// *injects* when its event fires while the workload is still running —
/// faults scheduled past completion (or past an early `run_until` stop)
/// never fire. Campaign reports need both numbers to normalize failure
/// rates correctly.
#[derive(Clone, Debug, Default)]
pub struct FaultOutcome {
    /// SEU upsets placed on the event queue by [`schedule_faults`].
    pub faults_scheduled: u64,
    /// Upsets that actually fired and corrupted live transport state.
    pub faults_injected: u64,
    pub stalled_qps: usize,
    pub workload_completed: bool,
    pub sim_time_ns: SimTime,
}

/// Why a network-fault plan could not be scheduled. Scenario grids match
/// on this to *skip* inapplicable cells (e.g. a spine failure on the
/// single-switch fabric) instead of aborting a whole sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPlanError {
    /// The fabric has no spine tier (single-switch topology).
    NotMultiTier,
    /// Spine or link index beyond the fabric's shape.
    OutOfRange,
    /// Recovery time does not lie after the failure time.
    BadWindow,
    /// The addressed switch tier does not exist in this fabric (e.g. a
    /// core-tier plan on a two-tier leaf–spine, or a tier id the Clos
    /// family does not define).
    NoSuchTier,
}

impl std::fmt::Display for FaultPlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultPlanError::NotMultiTier => {
                write!(f, "fault plan needs a multi-tier topology")
            }
            FaultPlanError::OutOfRange => write!(f, "spine/link index out of range"),
            FaultPlanError::BadWindow => write!(f, "recovery must come after failure"),
            FaultPlanError::NoSuchTier => write!(f, "addressed switch tier does not exist"),
        }
    }
}

/// Schedule Poisson fault arrivals over `[0, horizon]` using the design's
/// MTBF compressed by `accel`. Returns the number of scheduled injections.
pub fn schedule_faults(
    cluster: &mut Cluster,
    kind: TransportKind,
    horizon: SimTime,
    accel: f64,
    seed: u64,
) -> usize {
    let report = crate::hw::resources::synthesize(kind);
    let model = SeuModel::from_mtbf(report.mtbf_hours, accel);
    let mut rng = Pcg64::new(seed, 0xfa017);
    let mut t: SimTime = 0;
    let mut n = 0;
    loop {
        t += model.next_upset_after(&mut rng);
        if t >= horizon {
            break;
        }
        cluster.schedule_fault(t);
        n += 1;
    }
    // recorded separately from `faults_injected` (bumped at fire time):
    // the two counters diverge whenever the workload finishes first
    cluster.metrics.add("faults_scheduled", n as u64);
    n
}

// ---- network-level fault scenarios (leaf–spine) -----------------------------

/// Link flap: `link` blackholes at `down_at` and recovers at `up_at`.
/// Routing converges (masks the link out of ECMP/spray) `reroute_ns`
/// after the failure; recovery clears the mask. Errors (instead of
/// panicking) on an invalid window or a nonexistent link so scenario
/// grids can skip inapplicable cells.
pub fn schedule_link_flap(
    cluster: &mut Cluster,
    link: LinkId,
    down_at: SimTime,
    up_at: SimTime,
) -> Result<(), FaultPlanError> {
    if up_at <= down_at {
        return Err(FaultPlanError::BadWindow);
    }
    if link >= cluster.fabric.ports.len() {
        return Err(FaultPlanError::OutOfRange);
    }
    cluster.schedule_net_fault(down_at, NetFault::LinkDown(link));
    cluster.schedule_net_fault(up_at, NetFault::LinkUp(link));
    Ok(())
}

/// Spine failure: every link touching `spine` goes down at `down_at`
/// (and, if `up_at` is given, the whole spine returns). Works on every
/// multi-tier member of the Clos family — `spine` is the GLOBAL
/// pod-spine index in fat-tree mode (`pod × spines_per_pod + local`),
/// and the link set spans both tiers the spine touches. Returns the
/// number of links taken down; errors on a single-switch fabric or a
/// nonexistent spine rather than panicking mid-sweep.
pub fn schedule_spine_failure(
    cluster: &mut Cluster,
    spine: usize,
    down_at: SimTime,
    up_at: Option<SimTime>,
) -> Result<usize, FaultPlanError> {
    let n_spines = cluster.fabric.topo.n_spines();
    if n_spines == 0 {
        return Err(FaultPlanError::NotMultiTier);
    }
    if spine >= n_spines {
        return Err(FaultPlanError::OutOfRange);
    }
    if let Some(up) = up_at {
        if up <= down_at {
            return Err(FaultPlanError::BadWindow);
        }
    }
    let links = cluster.fabric.topo.spine_links(spine);
    for &link in &links {
        cluster.schedule_net_fault(down_at, NetFault::LinkDown(link));
        if let Some(up) = up_at {
            cluster.schedule_net_fault(up, NetFault::LinkUp(link));
        }
    }
    Ok(links.len())
}

/// Tier-addressed switch failure for the Clos family: plans name a
/// switch as `(tier, pod, index)` instead of hard-coding the two-tier
/// layout. Tier 1 is the spine tier (`pod` selects the pod in fat-tree
/// mode; the leaf–spine fabric is a single pod, so `pod` must be 0);
/// tier 2 is the fat-tree core tier (shared above the pods — `pod` must
/// be 0). Out-of-family tiers come back as
/// [`FaultPlanError::NoSuchTier`], never a panic, so sweeps over mixed
/// topologies skip inapplicable cells. Returns the number of links taken
/// down.
pub fn schedule_tier_failure(
    cluster: &mut Cluster,
    tier: u8,
    pod: usize,
    index: usize,
    down_at: SimTime,
    up_at: Option<SimTime>,
) -> Result<usize, FaultPlanError> {
    if let Some(up) = up_at {
        if up <= down_at {
            return Err(FaultPlanError::BadWindow);
        }
    }
    let topo = cluster.fabric.topo;
    let links = match tier {
        1 => {
            let n = topo.n_spines();
            if n == 0 {
                return Err(FaultPlanError::NotMultiTier);
            }
            let per_pod = match topo.kind {
                crate::net::TopologyKind::FatTree { spines_per_pod, .. } => spines_per_pod,
                _ => n, // leaf–spine: one pod spanning every spine
            };
            if pod >= n / per_pod || index >= per_pod {
                return Err(FaultPlanError::OutOfRange);
            }
            topo.spine_links(pod * per_pod + index)
        }
        2 => {
            let n = topo.n_cores();
            if n == 0 {
                return Err(FaultPlanError::NoSuchTier);
            }
            if pod != 0 || index >= n {
                return Err(FaultPlanError::OutOfRange);
            }
            topo.core_links(index)
        }
        _ => return Err(FaultPlanError::NoSuchTier),
    };
    for &link in &links {
        cluster.schedule_net_fault(down_at, NetFault::LinkDown(link));
        if let Some(up) = up_at {
            cluster.schedule_net_fault(up, NetFault::LinkUp(link));
        }
    }
    Ok(links.len())
}

/// Degraded link: serialization stretches by `factor` from `at` on
/// (schedule a second call with factor 1 to heal).
pub fn schedule_link_degrade(cluster: &mut Cluster, link: LinkId, at: SimTime, factor: u32) {
    cluster.schedule_net_fault(at, NetFault::Degrade(link, factor));
}

/// Summarize a finished run.
pub fn outcome(cluster: &Cluster, completed: bool) -> FaultOutcome {
    FaultOutcome {
        faults_scheduled: cluster.metrics.counter("faults_scheduled"),
        faults_injected: cluster.metrics.counter("faults_injected"),
        stalled_qps: cluster.total_stalled_qps(),
        workload_completed: completed,
        sim_time_ns: cluster.time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FabricCfg;
    use crate::sim::cluster::ClusterCfg;

    #[test]
    fn spine_failure_downs_and_restores_every_spine_link() {
        let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic));
        let downed = schedule_spine_failure(&mut c, 0, 10, Some(1_000_000)).expect("leaf–spine");
        assert_eq!(downed, 4, "2 leaves × up+down links");
        let links = c.fabric.topo.spine_links(0);
        c.run_until(20);
        for &l in &links {
            assert!(!c.fabric.ports[l].up, "link {l} must be down");
        }
        // routing convergence masks the dead links after reroute_ns
        c.run_until(20 + c.cfg.fabric.reroute_ns + 10);
        for &l in &links {
            assert!(c.fabric.ports[l].routed_out, "link {l} must be masked");
        }
        // spine 1 untouched
        for &l in &c.fabric.topo.spine_links(1) {
            assert!(c.fabric.ports[l].up && !c.fabric.ports[l].routed_out);
        }
        c.run_until(1_000_100);
        for &l in &links {
            assert!(c.fabric.ports[l].up && !c.fabric.ports[l].routed_out);
        }
        assert!(c.metrics.counter("net_faults") >= 8);
    }

    #[test]
    fn link_degrade_takes_effect_on_schedule() {
        let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic));
        let link = c.fabric.topo.up_link(0, 0);
        schedule_link_degrade(&mut c, link, 50, 8);
        c.run_until(100);
        assert_eq!(c.fabric.ports[link].degrade, 8);
        schedule_link_degrade(&mut c, link, 200, 1);
        c.run_until(300);
        assert_eq!(c.fabric.ports[link].degrade, 1);
    }

    #[test]
    fn schedules_proportional_to_inverse_mtbf() {
        let horizon = 10 * crate::sim::MS;
        let accel = 1e13;
        let mk = |kind| {
            let mut c = Cluster::new(ClusterCfg::new(FabricCfg::cloudlab(4), kind));
            schedule_faults(&mut c, kind, horizon, accel, 42)
        };
        let irn = mk(TransportKind::Irn); // lowest MTBF → most faults
        let opt = mk(TransportKind::Optinic); // highest MTBF → fewest
        assert!(irn > opt, "irn={irn} opt={opt}");
        assert!(opt > 0);
    }

    /// Scheduled ≠ injected: upsets drawn past the point the run stops
    /// must never count as injected, and the outcome reports both sides
    /// of that ledger.
    #[test]
    fn outcome_reports_scheduled_vs_injected() {
        let mut c = Cluster::new(ClusterCfg::new(
            FabricCfg::cloudlab(4),
            TransportKind::Roce,
        ));
        let n = schedule_faults(&mut c, TransportKind::Roce, 10 * crate::sim::MS, 1e13, 42);
        assert!(n > 1, "need several upsets for the distinction to bite");
        // stop almost immediately: every upset is still in the future
        c.run_until(10);
        let early = outcome(&c, true);
        assert_eq!(early.faults_scheduled, n as u64);
        assert_eq!(
            early.faults_injected, 0,
            "unfired upsets must not count as injected"
        );
        // run the full horizon: fired upsets land in the injected (or
        // no-target) counters, still bounded by the schedule
        c.run_until(10 * crate::sim::MS);
        let late = outcome(&c, true);
        let fired = late.faults_injected + c.metrics.counter("faults_no_target");
        assert_eq!(fired, late.faults_scheduled, "all upsets fire by the horizon");
    }

    /// Invalid plans come back as errors a sweep can skip, not panics
    /// that abort the whole grid.
    #[test]
    fn invalid_fault_plans_error_instead_of_panicking() {
        // single-switch fabric: no spine tier to fail
        let mut c = Cluster::new(ClusterCfg::new(
            FabricCfg::cloudlab(4),
            TransportKind::Optinic,
        ));
        assert_eq!(
            schedule_spine_failure(&mut c, 0, 10, Some(100)),
            Err(FaultPlanError::NotMultiTier)
        );
        let bad_link = c.fabric.ports.len();
        assert_eq!(
            schedule_link_flap(&mut c, bad_link, 10, 100),
            Err(FaultPlanError::OutOfRange)
        );
        // leaf–spine fabric: out-of-range spine and inverted windows
        let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic));
        assert_eq!(
            schedule_spine_failure(&mut c, 99, 10, None),
            Err(FaultPlanError::OutOfRange)
        );
        assert_eq!(
            schedule_spine_failure(&mut c, 0, 100, Some(100)),
            Err(FaultPlanError::BadWindow)
        );
        assert_eq!(
            schedule_link_flap(&mut c, 0, 100, 100),
            Err(FaultPlanError::BadWindow)
        );
        // nothing was scheduled by any of the rejected plans
        c.run_until(1_000);
        assert_eq!(c.metrics.counter("net_faults"), 0);
    }

    fn fat_tree_cluster() -> Cluster {
        let fab = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
        Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic))
    }

    /// Satellite contract: the spine-failure builder addresses GLOBAL
    /// pod-spine indices on a fat-tree and takes down both tiers the
    /// spine touches (its pod's leaves below, every core above).
    #[test]
    fn spine_failure_generalizes_to_fat_tree() {
        let mut c = fat_tree_cluster();
        // pod spine 2 (pod 1, local 0): 2 leaves × 2 dirs + 2 cores × 2 dirs
        let downed = schedule_spine_failure(&mut c, 2, 10, Some(1_000_000)).expect("fat-tree");
        assert_eq!(downed, 8);
        let links = c.fabric.topo.spine_links(2);
        c.run_until(20);
        for &l in &links {
            assert!(!c.fabric.ports[l].up, "link {l} must be down");
        }
        // pod 0's spines untouched
        for s in 0..2 {
            for &l in &c.fabric.topo.spine_links(s) {
                assert!(c.fabric.ports[l].up);
            }
        }
        c.run_until(1_000_100);
        for &l in &links {
            assert!(c.fabric.ports[l].up && !c.fabric.ports[l].routed_out);
        }
        // out of range: only 4 global pod spines exist
        assert_eq!(
            schedule_spine_failure(&mut c, 4, 10, None),
            Err(FaultPlanError::OutOfRange)
        );
    }

    /// `(tier, pod, index)` addressing: tier 1 resolves through the pod,
    /// tier 2 hits the shared core, anything else is a typed error a
    /// sweep can skip.
    #[test]
    fn tier_failure_addresses_pods_and_cores() {
        let mut c = fat_tree_cluster();
        // (1, pod 1, spine 0) == global pod spine 2
        let n = schedule_tier_failure(&mut c, 1, 1, 0, 10, None).expect("spine tier");
        assert_eq!(n, c.fabric.topo.spine_links(2).len());
        c.run_until(20);
        for &l in &c.fabric.topo.spine_links(2) {
            assert!(!c.fabric.ports[l].up);
        }
        // core 1: every pod spine × both directions
        let n = schedule_tier_failure(&mut c, 2, 0, 1, 30, None).expect("core tier");
        assert_eq!(n, 2 * c.fabric.topo.n_spines());
        c.run_until(40);
        for &l in &c.fabric.topo.core_links(1) {
            assert!(!c.fabric.ports[l].up);
        }
        // bad addresses come back typed, not as panics
        assert_eq!(
            schedule_tier_failure(&mut c, 3, 0, 0, 10, None),
            Err(FaultPlanError::NoSuchTier)
        );
        assert_eq!(
            schedule_tier_failure(&mut c, 1, 2, 0, 10, None),
            Err(FaultPlanError::OutOfRange)
        );
        assert_eq!(
            schedule_tier_failure(&mut c, 2, 1, 0, 10, None),
            Err(FaultPlanError::OutOfRange),
            "the core tier is shared — pod addressing is meaningless"
        );
        assert_eq!(
            schedule_tier_failure(&mut c, 1, 0, 0, 100, Some(100)),
            Err(FaultPlanError::BadWindow)
        );
    }

    /// On the two-tier fabric, tier addressing degenerates to one pod and
    /// the core tier does not exist.
    #[test]
    fn tier_failure_on_leaf_spine_degenerates() {
        let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Optinic));
        let n = schedule_tier_failure(&mut c, 1, 0, 1, 10, None).expect("one pod");
        assert_eq!(n, 4);
        assert_eq!(
            schedule_tier_failure(&mut c, 2, 0, 0, 10, None),
            Err(FaultPlanError::NoSuchTier),
            "no core tier on a two-tier Clos"
        );
        assert_eq!(
            schedule_tier_failure(&mut c, 1, 1, 0, 10, None),
            Err(FaultPlanError::OutOfRange)
        );
        // single-switch: no spine tier at all
        let mut c = Cluster::new(ClusterCfg::new(
            FabricCfg::cloudlab(4),
            TransportKind::Optinic,
        ));
        assert_eq!(
            schedule_tier_failure(&mut c, 1, 0, 0, 10, None),
            Err(FaultPlanError::NotMultiTier)
        );
    }
}
