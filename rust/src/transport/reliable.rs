//! Shared reliable-transport engine.
//!
//! RoCE, IRN, SRNIC, Falcon, and UCCL all gate forward progress on complete
//! delivery; they differ in *how* they detect and repair loss. This module
//! implements the common machinery — fragmentation, PSN space, windows,
//! ACK/SACK/NACK processing, retransmission, message-level completion —
//! parameterized by [`ReliableCfg`]:
//!
//! * `RelMode::GoBackN` (RoCE): receiver accepts only in-order PSNs, drops
//!   everything else, NACKs the expected PSN; the sender rewinds and
//!   retransmits the whole window — the retransmission storms of §2.3.
//! * `RelMode::SelRepeat` (IRN/SRNIC/Falcon/UCCL): receiver places
//!   out-of-order packets (bitmap-tracked), ACKs carry SACK blocks, the
//!   sender retransmits only the gaps.
//! * `sw_datapath`: SRNIC/UCCL run reordering/retransmission on the host —
//!   modeled as a per-packet processing cost added to the sender pacing
//!   and to receiver→CQE latency.
//! * `spray`: Falcon-style multipath — packets take jittered paths and
//!   arrive reordered (harmless under SR, catastrophic under GBN).

use std::collections::{BTreeMap, VecDeque};

use crate::cc::{Admit, CcDriver, CcKind};
use crate::net::{AckHdr, DataHdr, NackHdr, Packet, PktKind, RethHdr};
use crate::sim::cluster::NicCtx;
use crate::sim::SimTime;
use crate::transport::{
    frag_iter, timer_id, timer_parts, TransportCfg, TIMER_CREDIT, TIMER_PACE, TIMER_RTO,
};
use crate::verbs::{CqStatus, Cqe, LossMap, NodeId, Qp, Qpn, Verb, Wqe};

/// Reliability flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RelMode {
    GoBackN,
    SelRepeat,
}

/// Behavior knobs distinguishing the published designs.
#[derive(Clone, Debug)]
pub struct ReliableCfg {
    pub mode: RelMode,
    /// Reordering/retransmission run on the host CPU (SRNIC, UCCL).
    pub sw_datapath: bool,
    /// Multipath packet spraying (Falcon).
    pub spray: bool,
    /// SACK reorder threshold (packets) before a gap is declared lost.
    pub dup_threshold: u32,
}

/// One fragment awaiting acknowledgment.
#[derive(Clone, Copy, Debug)]
struct FragState {
    msg_seq: u32,
    msg_offset: usize,
    len: usize,
    last: bool,
    acked: bool,
    /// queued for (re)transmission
    queued: bool,
    retransmits: u32,
}

/// Sender-side per-message completion tracking.
#[derive(Clone, Debug)]
struct SendMsg {
    wr_id: u64,
    verb: Verb,
    src_mr: crate::verbs::MrId,
    src_off: usize,
    msg_len: usize,
    frags_unacked: usize,
    remote: Option<crate::verbs::RemoteBuf>,
    imm: Option<u32>,
}

/// Receiver-side per-message reassembly tracking. (For the hardware designs
/// this is the NIC reorder/bitmap state whose SRAM cost Table 4 charges.)
#[derive(Clone, Debug)]
struct RecvMsg {
    /// bitmap of received fragments
    got: Vec<bool>,
    bytes: usize,
    msg_len: usize,
    total_frags: usize,
    wr_id: Option<u64>,
    /// receive placement base (posted recv buffer or RETH)
    dst: Option<(crate::verbs::MrId, usize)>,
    imm: Option<u32>,
    completed: bool,
}

/// Per-QP connection state.
struct QpState {
    qp: Qp,
    // ---- sender ----
    pending: VecDeque<Wqe>,
    msgs: BTreeMap<u32, SendMsg>,
    frags: BTreeMap<u32, FragState>, // psn → frag
    next_psn: u32,
    snd_una: u32,
    next_msg_seq: u32,
    /// PSNs queued for (re)transmission, in order (§Perf: replaces an
    /// O(window) scan per transmitted packet).
    txq: VecDeque<u32>,
    /// Absolute RTO deadline — refreshed on every ACK *without* scheduling
    /// a new event (§Perf: one outstanding timer per QP, not one per ACK).
    rto_deadline: SimTime,
    rto_armed: bool,
    retries: u32,
    stalled: bool,
    outstanding: usize,
    // ---- receiver ----
    expected_psn: u32,
    recv_wqes: VecDeque<Wqe>,
    recv_msgs: BTreeMap<u32, RecvMsg>,
    next_unassigned_msg: u32,
    /// highest in-order msg completed + 1 (messages must complete in order)
    next_deliver_msg: u32,
}

/// The reliable transport engine for one NIC.
pub struct Reliable {
    pub node: NodeId,
    pub cfg: TransportCfg,
    pub rel: ReliableCfg,
    qps: BTreeMap<Qpn, QpState>,
    /// The CC plane: per-QP algorithm instances, pacing, credit grants.
    cc: CcDriver,
}

impl Reliable {
    pub fn new(node: NodeId, cfg: TransportCfg, rel: ReliableCfg) -> Reliable {
        let cc = CcDriver::new(&cfg);
        Reliable {
            node,
            cfg,
            rel,
            qps: BTreeMap::new(),
            cc,
        }
    }

    /// The CC algorithm this engine resolved to.
    pub fn cc_kind(&self) -> CcKind {
        self.cc.kind()
    }

    pub fn create_qp_impl(&mut self, qp: Qp) {
        self.cc.register_qp(qp.qpn);
        self.qps.insert(
            qp.qpn,
            QpState {
                qp,
                pending: VecDeque::new(),
                msgs: BTreeMap::new(),
                frags: BTreeMap::new(),
                next_psn: 0,
                snd_una: 0,
                next_msg_seq: 0,
                txq: VecDeque::new(),
                rto_deadline: 0,
                rto_armed: false,
                retries: 0,
                stalled: false,
                outstanding: 0,
                expected_psn: 0,
                recv_wqes: VecDeque::new(),
                recv_msgs: BTreeMap::new(),
                next_unassigned_msg: 0,
                next_deliver_msg: 0,
            },
        );
    }

    pub fn qp_count(&self) -> usize {
        self.qps.len()
    }

    pub fn stalled_count(&self) -> usize {
        self.qps.values().filter(|q| q.stalled).count()
    }

    /// Per-packet host-CPU cost for software datapaths.
    fn sw_cost(&self) -> SimTime {
        if self.rel.sw_datapath {
            self.cfg.sw_overhead_ns
        } else {
            0
        }
    }

    // ---- posting -------------------------------------------------------------

    /// Charge the host doorbell cost (MMIO + WQE fetch) to the QP's pacing
    /// horizon; one charge per doorbell ring, so batches pay it once.
    fn ring_doorbell(&mut self, now: SimTime, qpn: Qpn) {
        self.cc.charge_doorbell(qpn, now, self.cfg.doorbell_ns);
    }

    fn enqueue_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        let node = self.node;
        let q = self.qps.get_mut(&qpn).expect("unknown QP");
        if q.stalled {
            ctx.push_cqe(error_cqe(&wqe, qpn, ctx.time, false));
            return;
        }
        // receiver-driven schemes: announce demand so the peer's pull
        // pacer grants credits matched to data that wants to leave (the
        // CC plane decides; the engine never names an algorithm)
        if self.cc.announces_demand(qpn) {
            let pr = Packet::pull_req(node, q.qp.peer_node, q.qp.peer_qpn, wqe.total_len());
            ctx.tx(pr);
        }
        q.pending.push_back(wqe);
    }

    pub fn post_send_impl(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.ring_doorbell(ctx.time, qpn);
        self.enqueue_send(ctx, qpn, wqe);
        self.pump(ctx, qpn);
    }

    /// Doorbell-batched posting: one doorbell charge and one pump per
    /// touched QP for the whole batch (verbs v2).
    pub fn post_send_batch_impl(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        let touched = crate::transport::batch_qpns(&batch);
        for &qpn in &touched {
            self.ring_doorbell(ctx.time, qpn);
        }
        for (qpn, wqe) in batch {
            self.enqueue_send(ctx, qpn, wqe);
        }
        for &qpn in &touched {
            self.pump(ctx, qpn);
        }
    }

    pub fn post_recv_impl(&mut self, _ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        let q = self.qps.get_mut(&qpn).expect("unknown QP");
        q.recv_wqes.push_back(wqe);
    }

    /// Move fragments from pending WQEs into the PSN space, then transmit
    /// as the window/pacer allow.
    fn pump(&mut self, ctx: &mut NicCtx, qpn: Qpn) {
        let sw_cost = self.sw_cost();
        let mtu = self.cfg.mtu;
        let window = self.window_bytes();
        let Some(q) = self.qps.get_mut(&qpn) else { return };
        if q.stalled {
            return;
        }
        // admit new messages into the PSN space
        while let Some(wqe) = q.pending.pop_front() {
            let msg_seq = q.next_msg_seq;
            q.next_msg_seq += 1;
            let sge = wqe.sges[0];
            // allocation-free fragmentation (§Perf)
            let frags = frag_iter(wqe.total_len(), mtu);
            q.msgs.insert(
                msg_seq,
                SendMsg {
                    wr_id: wqe.wr_id,
                    verb: wqe.verb,
                    src_mr: sge.mr,
                    src_off: sge.offset,
                    msg_len: wqe.total_len(),
                    frags_unacked: frags.len(),
                    remote: wqe.remote,
                    imm: wqe.imm,
                },
            );
            for (off, len, last) in frags {
                let psn = q.next_psn;
                q.next_psn += 1;
                q.frags.insert(
                    psn,
                    FragState {
                        msg_seq,
                        msg_offset: off,
                        len,
                        last,
                        acked: false,
                        queued: true,
                        retransmits: 0,
                    },
                );
                q.txq.push_back(psn);
            }
        }
        // transmit queued fragments; resolve the CC admission gate once
        // per pump (§Perf: no per-fragment QP-map lookup on the hot path)
        let Some(mut gate) = self.cc.gate(qpn) else { return };
        let mut pace: Option<(SimTime, bool)> = None;
        loop {
            if q.outstanding >= window {
                break;
            }
            // next queued fragment (txq may hold stale entries for frags
            // that were acked after being requeued — skip those)
            let psn = loop {
                let Some(&cand) = q.txq.front() else { break None };
                match q.frags.get(&cand) {
                    Some(f) if f.queued && !f.acked => break Some(cand),
                    _ => {
                        q.txq.pop_front();
                    }
                }
            };
            let Some(psn) = psn else { break };
            let f = q.frags[&psn];
            // one CC-plane gate folds pacing, the software-datapath
            // throughput cap, and credit consumption (no credit is spent
            // for fragments the pacer refuses)
            match gate.admit(ctx.metrics, ctx.time, f.len, sw_cost) {
                Admit::Go => {}
                Admit::Pace { at, arm } => {
                    pace = Some((at, arm));
                    break;
                }
                Admit::NoCredit => break, // Credit packet re-pumps
            }
            // emit
            let msg = &q.msgs[&f.msg_seq];
            let reth = if f.msg_offset == 0 {
                msg.remote.map(|r| RethHdr {
                    mr: r.mr,
                    offset: r.offset,
                    rkey: r.rkey,
                })
            } else {
                None
            };
            let hdr = DataHdr {
                dst_qpn: q.qp.peer_qpn,
                src_qpn: q.qp.qpn,
                psn,
                wqe_seq: f.msg_seq,
                msg_offset: f.msg_offset,
                len: f.len,
                last: f.last,
                msg_len: msg.msg_len,
                src_mr: msg.src_mr,
                src_off: msg.src_off + f.msg_offset,
                reth,
                stride: 1,
                imm: if f.last { msg.imm } else { None },
                deadline: None,
                tx_time: ctx.time,
                hints: crate::net::NetHints::default(),
            };
            let mut pkt = Packet::data(self.node, q.qp.peer_node, hdr);
            pkt.spray = self.rel.spray;
            q.txq.pop_front();
            let frag = q.frags.get_mut(&psn).unwrap();
            frag.queued = false;
            if frag.retransmits > 0 {
                ctx.metrics.retransmissions += 1;
            }
            q.outstanding += f.len;
            ctx.tx(pkt);
        }
        // arm pacing timer (the driver tracked it as outstanding)
        if let Some((at, true)) = pace {
            ctx.set_timer(at - ctx.time, timer_id(qpn, TIMER_PACE, 0));
        }
        // arm RTO while ANY fragment is unacked (single outstanding timer;
        // deadline refreshed in place). Keyed on `frags` rather than bytes
        // in flight: a credit-gated tail (EQDS out of credit with nothing
        // in the air) must still own a timer, or nothing ever re-pumps it.
        if !q.frags.is_empty() {
            q.rto_deadline = ctx.time + self.cfg.rto_ns;
            if !q.rto_armed {
                q.rto_armed = true;
                ctx.set_timer(self.cfg.rto_ns, timer_id(qpn, TIMER_RTO, 0));
            }
        }
    }

    fn window_bytes(&self) -> usize {
        // 2 BDP, floor 64 KiB
        ((2.0 * self.cfg.link_bytes_per_ns * self.cfg.base_rtt_ns as f64) as usize)
            .max(64 * 1024)
    }

    // ---- receive path -----------------------------------------------------------

    pub fn on_packet_impl(&mut self, ctx: &mut NicCtx, pkt: Packet) {
        match pkt.kind {
            PktKind::Data(hdr) => self.on_data(ctx, pkt.src, hdr),
            PktKind::Ack(hdr) => self.on_ack(ctx, hdr),
            PktKind::Nack(hdr) => self.on_nack(ctx, hdr),
            PktKind::Cnp { dst_qpn } => {
                self.cc.on_cnp(ctx.metrics, dst_qpn, ctx.time);
            }
            PktKind::Credit { dst_qpn, bytes } => {
                self.cc.on_credit(ctx.metrics, dst_qpn, ctx.time, bytes);
                self.pump(ctx, dst_qpn);
            }
            PktKind::PullReq { dst_qpn, bytes } => {
                // receiver-driven CC: book the demand; first demand arms
                // the grant timer (fires immediately, then self-paces)
                if self.cc.on_pull_req(dst_qpn, bytes) {
                    ctx.set_timer(1, timer_id(dst_qpn, TIMER_CREDIT, 0));
                }
            }
            _ => {}
        }
    }

    fn on_data(&mut self, ctx: &mut NicCtx, from: NodeId, hdr: DataHdr) {
        let sw_cost = self.sw_cost();
        let qpn = hdr.dst_qpn;
        let mode = self.rel.mode;
        let Some(q) = self.qps.get_mut(&qpn) else { return };

        // GBN: strict in-order PSN acceptance
        if mode == RelMode::GoBackN && hdr.psn != q.expected_psn {
            if hdr.psn > q.expected_psn {
                // gap: NACK the expected PSN (duplicate-ACK style)
                let nack = Packet::nack(
                    ctx.node,
                    from,
                    NackHdr {
                        dst_qpn: hdr.src_qpn,
                        missing_psn: q.expected_psn,
                    },
                );
                ctx.metrics.nacks_sent += 1;
                ctx.tx(nack);
            }
            // drop (also for stale retransmitted duplicates: re-ACK below)
            if hdr.psn < q.expected_psn {
                Self::send_ack(ctx, from, q, &hdr, None);
            }
            return;
        }
        // SR: accept anything not already received
        if mode == RelMode::SelRepeat {
            // message already completed and its reassembly state freed:
            // this is a retransmitted duplicate — re-ACK so the sender's
            // gap detector stops, then drop
            if hdr.wqe_seq < q.next_deliver_msg {
                Self::send_ack(ctx, from, q, &hdr, Some((hdr.psn, hdr.psn)));
                return;
            }
            if let Some(m) = q.recv_msgs.get(&hdr.wqe_seq) {
                let idx = hdr.msg_offset / q.qp.mtu.max(1);
                if m.completed || *m.got.get(idx).unwrap_or(&false) {
                    // duplicate
                    Self::send_ack(ctx, from, q, &hdr, Some((hdr.psn, hdr.psn)));
                    return;
                }
            }
        }

        // assign recv WQEs to messages in order; a dry per-QP RQ falls back
        // to the node's shared receive queue (verbs v2 SRQ)
        while q.next_unassigned_msg <= hdr.wqe_seq {
            let seq = q.next_unassigned_msg;
            let needs_recv_wqe = hdr.reth.is_none() || hdr.imm.is_some();
            let wqe = if needs_recv_wqe {
                match q.recv_wqes.pop_front() {
                    Some(w) => Some(w),
                    None => {
                        let w = ctx.pop_srq();
                        if w.is_some() {
                            ctx.metrics.bump("rx_srq_consumed");
                        }
                        w
                    }
                }
            } else {
                None
            };
            // WRITE without imm: placement comes from RETH; no recv WQE.
            q.next_unassigned_msg += 1;
            let entry = RecvMsg {
                got: vec![],
                bytes: 0,
                msg_len: 0,
                total_frags: 0,
                wr_id: wqe.as_ref().map(|w| w.wr_id),
                dst: wqe.as_ref().map(|w| (w.sges[0].mr, w.sges[0].offset)),
                imm: None,
                completed: false,
            };
            q.recv_msgs.insert(seq, entry);
        }
        let mtu = q.qp.mtu;
        let msg = q.recv_msgs.get_mut(&hdr.wqe_seq).unwrap();
        if msg.msg_len == 0 {
            msg.msg_len = hdr.msg_len;
            msg.total_frags = hdr.msg_len.div_ceil(mtu).max(1);
            msg.got = vec![false; msg.total_frags];
        }
        if let Some(reth) = hdr.reth {
            msg.dst = Some((reth.mr, reth.offset));
        }
        if hdr.imm.is_some() {
            msg.imm = hdr.imm;
        }
        let idx = hdr.msg_offset / mtu.max(1);
        if !msg.got[idx] {
            msg.got[idx] = true;
            msg.bytes += hdr.len;
            // DMA placement
            if let Some((dst_mr, dst_base)) = msg.dst {
                ctx.mem.dma_copy(
                    hdr.src_mr,
                    hdr.src_off,
                    dst_mr,
                    dst_base + hdr.msg_offset,
                    hdr.len,
                    None,
                );
            }
            ctx.metrics.data_bytes_delivered += hdr.len as u64;
        }

        if mode == RelMode::GoBackN {
            q.expected_psn = hdr.psn + 1;
        }

        // ACK with SACK block for SR
        let sack = if mode == RelMode::SelRepeat {
            Some((hdr.psn, hdr.psn))
        } else {
            None
        };
        Self::send_ack(ctx, from, q, &hdr, sack);

        // CC plane, receiver side: record the delivery (grant-rate AIMD
        // for receiver-driven schemes) and apply the notification-point
        // policy — the algorithm, not the engine, decides whether a CE
        // mark produces a CNP (DCQCN yes, everyone else no)
        if self.cc.on_delivery(qpn, ctx.time, hdr.len, &hdr.hints) {
            let cnp = Packet::cnp(ctx.node, from, hdr.src_qpn);
            ctx.metrics.cnps_sent += 1;
            ctx.tx(cnp);
        }

        // deliver completed messages in order
        let mut to_complete = vec![];
        while let Some(m) = q.recv_msgs.get(&q.next_deliver_msg) {
            if m.total_frags > 0 && m.got.iter().all(|&g| g) && !m.completed {
                to_complete.push(q.next_deliver_msg);
                q.recv_msgs.get_mut(&q.next_deliver_msg).unwrap().completed = true;
                let seq = q.next_deliver_msg;
                q.next_deliver_msg += 1;
                // free reassembly state for completed messages
                let m = q.recv_msgs.remove(&seq).unwrap();
                ctx.metrics.full_completions += 1;
                ctx.push_cqe(Cqe {
                    wr_id: m.wr_id.unwrap_or(0),
                    qpn,
                    status: CqStatus::Success,
                    bytes: m.bytes,
                    expected_bytes: m.msg_len,
                    imm: m.imm,
                    time: ctx.time + sw_cost,
                    is_recv: true,
                    // reliable delivery: the loss map is always complete
                    loss: Some(LossMap::complete(m.msg_len)),
                });
            } else {
                break;
            }
        }
        let _ = to_complete;
    }

    fn send_ack(
        ctx: &mut NicCtx,
        to: NodeId,
        q: &mut QpState,
        hdr: &DataHdr,
        sack: Option<(u32, u32)>,
    ) {
        let ack = Packet::ack(
            ctx.node,
            to,
            AckHdr {
                dst_qpn: hdr.src_qpn,
                cumulative_psn: q.expected_psn,
                sack,
                echo_tx_time: hdr.tx_time,
                hints: hdr.hints,
                acked_bytes: hdr.len,
            },
        );
        ctx.metrics.acks_sent += 1;
        ctx.tx(ack);
    }

    fn on_ack(&mut self, ctx: &mut NicCtx, hdr: AckHdr) {
        let qpn = hdr.dst_qpn;
        let mode = self.rel.mode;
        let dup_threshold = self.rel.dup_threshold;
        // CC plane: decompose the feedback into the signal vocabulary
        // (RTT sample, INT, mark, ack batch) before touching reliability
        let rtt = ctx.time.saturating_sub(hdr.echo_tx_time);
        self.cc.on_ack(
            ctx.metrics,
            qpn,
            ctx.time,
            Some(rtt),
            hdr.acked_bytes,
            &hdr.hints,
        );
        let Some(q) = self.qps.get_mut(&qpn) else { return };

        let mut newly_acked: Vec<u32> = vec![];
        match mode {
            RelMode::GoBackN => {
                // cumulative
                let cum = hdr.cumulative_psn;
                for (&psn, f) in q.frags.iter_mut() {
                    if psn < cum && !f.acked {
                        f.acked = true;
                        newly_acked.push(psn);
                    }
                }
                q.snd_una = q.snd_una.max(cum);
            }
            RelMode::SelRepeat => {
                if let Some((a, b)) = hdr.sack {
                    for psn in a..=b {
                        if let Some(f) = q.frags.get_mut(&psn) {
                            if !f.acked {
                                f.acked = true;
                                newly_acked.push(psn);
                            }
                        }
                    }
                }
                // advance snd_una over contiguous acked
                while q.frags.get(&q.snd_una).map(|f| f.acked).unwrap_or(false) {
                    q.snd_una += 1;
                }
                // gap detection: unacked psn far below the highest sacked
                if let Some((_, hi)) = hdr.sack {
                    let mut to_queue = vec![];
                    for (&psn, f) in q.frags.iter() {
                        if !f.acked
                            && !f.queued
                            && psn + dup_threshold < hi
                        {
                            to_queue.push(psn);
                        }
                    }
                    let detected = !to_queue.is_empty();
                    for psn in to_queue {
                        let f = q.frags.get_mut(&psn).unwrap();
                        f.queued = true;
                        f.retransmits += 1;
                        q.outstanding = q.outstanding.saturating_sub(f.len);
                        q.txq.push_back(psn);
                    }
                    if detected {
                        // declared loss is a CC signal: mild hint (the
                        // rate laws rate-limit their response; EQDS
                        // refills the credit the retransmission re-spends)
                        self.cc.on_loss(qpn, ctx.time, false);
                    }
                }
            }
        }

        // message completion accounting + outstanding bytes
        for psn in newly_acked {
            let f = q.frags[&psn];
            q.outstanding = q.outstanding.saturating_sub(f.len);
            let done = {
                let m = q.msgs.get_mut(&f.msg_seq).expect("msg for frag");
                m.frags_unacked -= 1;
                m.frags_unacked == 0
            };
            if done {
                let m = q.msgs.remove(&f.msg_seq).unwrap();
                ctx.push_cqe(Cqe {
                    wr_id: m.wr_id,
                    qpn,
                    status: CqStatus::Success,
                    bytes: m.msg_len,
                    expected_bytes: m.msg_len,
                    imm: None,
                    time: ctx.time,
                    is_recv: false,
                    loss: None,
                });
            }
            q.frags.remove(&psn);
        }
        q.retries = 0;
        // progress pushes the RTO deadline forward; the single outstanding
        // timer re-arms itself on fire if the deadline moved (§Perf).
        // `frags` empty ⇔ nothing unacked remains (acked frags are removed
        // above) — only then may the timer die.
        if q.frags.is_empty() {
            q.rto_deadline = 0;
            // nothing unacked: cancel (lazy) instead of letting the
            // stale entry fire into the transport
            if q.rto_armed {
                q.rto_armed = false;
                ctx.cancel_timer(timer_id(qpn, TIMER_RTO, 0));
            }
        } else {
            q.rto_deadline = ctx.time + self.cfg.rto_ns;
            if !q.rto_armed {
                q.rto_armed = true;
                ctx.set_timer(self.cfg.rto_ns, timer_id(qpn, TIMER_RTO, 0));
            }
        }
        self.pump(ctx, qpn);
    }

    fn on_nack(&mut self, ctx: &mut NicCtx, hdr: NackHdr) {
        let qpn = hdr.dst_qpn;
        let mode = self.rel.mode;
        let Some(q) = self.qps.get_mut(&qpn) else { return };
        match mode {
            RelMode::GoBackN => {
                // rewind: requeue every unacked fragment from missing_psn on
                let mut rewound = 0usize;
                for (&psn, f) in q.frags.range_mut(hdr.missing_psn..) {
                    if !f.acked && !f.queued {
                        f.queued = true;
                        f.retransmits += 1;
                        rewound += f.len;
                        q.txq.push_back(psn);
                    }
                }
                q.outstanding = q.outstanding.saturating_sub(rewound);
            }
            RelMode::SelRepeat => {
                if let Some(f) = q.frags.get_mut(&hdr.missing_psn) {
                    if !f.acked && !f.queued {
                        f.queued = true;
                        f.retransmits += 1;
                        let len = f.len;
                        q.outstanding = q.outstanding.saturating_sub(len);
                        q.txq.push_back(hdr.missing_psn);
                    }
                }
            }
        }
        // NACK-grade loss hint (mild; an RTO is the severe variant)
        self.cc.on_loss(qpn, ctx.time, false);
        self.pump(ctx, qpn);
    }

    pub fn on_timer_impl(&mut self, ctx: &mut NicCtx, id: u64) {
        let (qpn, kind, gen) = timer_parts(id);
        match kind {
            TIMER_PACE => {
                self.cc.pace_fired(qpn);
                self.pump(ctx, qpn);
            }
            TIMER_CREDIT => {
                // receiver-side credit-grant tick (CC plane paces it)
                let chunk = self.cfg.mtu * 4;
                let node = self.node;
                let Some((peer_node, peer_qpn)) = self
                    .qps
                    .get(&qpn)
                    .map(|q| (q.qp.peer_node, q.qp.peer_qpn))
                else {
                    return;
                };
                if let Some((bytes, next)) = self.cc.grant_fired(qpn, chunk) {
                    ctx.tx(Packet::credit(node, peer_node, peer_qpn, bytes));
                    if let Some(gap) = next {
                        ctx.set_timer(gap, timer_id(qpn, TIMER_CREDIT, 0));
                    }
                }
            }
            TIMER_RTO => {
                let _ = gen;
                let max_retries = self.cfg.max_retries;
                let Some(q) = self.qps.get_mut(&qpn) else { return };
                if !q.rto_armed {
                    return;
                }
                q.rto_armed = false;
                if q.rto_deadline == 0 || q.frags.is_empty() {
                    return; // nothing unacked anymore
                }
                if ctx.time < q.rto_deadline {
                    // progress happened since arming: re-arm for the rest
                    q.rto_armed = true;
                    let delay = q.rto_deadline - ctx.time;
                    ctx.set_timer(delay, timer_id(qpn, TIMER_RTO, 0));
                    return;
                }
                q.retries += 1;
                if q.retries > max_retries {
                    // QP error: reliable transports give up (stall)
                    q.stalled = true;
                    let msgs: Vec<_> = q.msgs.values().map(|m| (m.wr_id, m.msg_len)).collect();
                    for (wr_id, len) in msgs {
                        ctx.push_cqe(Cqe {
                            wr_id,
                            qpn,
                            status: CqStatus::Error,
                            bytes: 0,
                            expected_bytes: len,
                            imm: None,
                            time: ctx.time,
                            is_recv: false,
                            loss: None,
                        });
                    }
                    return;
                }
                // retransmit: GBN → everything unacked; SR → unacked gaps
                let mut rewound = 0usize;
                for (&psn, f) in q.frags.iter_mut() {
                    if !f.acked && !f.queued {
                        f.queued = true;
                        f.retransmits += 1;
                        rewound += f.len;
                        q.txq.push_back(psn);
                    }
                }
                q.outstanding = q.outstanding.saturating_sub(rewound);
                // severe loss: the whole window timed out
                self.cc.on_loss(qpn, ctx.time, true);
                self.pump(ctx, qpn);
            }
            _ => {}
        }
    }

    /// SEU fault injection: corrupt a random piece of NIC transport state.
    pub fn inject_fault_impl(
        &mut self,
        rng: &mut crate::util::prng::Pcg64,
    ) -> Option<String> {
        let keys: Vec<Qpn> = self.qps.keys().copied().collect();
        if keys.is_empty() {
            return None;
        }
        let qpn = *rng.choose(&keys);
        let q = self.qps.get_mut(&qpn).unwrap();
        // pick a state word proportional to its SRAM footprint
        match rng.below(5) {
            0 => {
                // corrupt expected_psn → GBN receiver rejects everything
                q.expected_psn ^= 1 << rng.below(20);
                Some(format!("qp{qpn}: expected_psn bit-flip"))
            }
            1 => {
                // corrupt snd_una / window accounting → sender stalls
                q.outstanding = usize::MAX / 2;
                q.stalled = true;
                Some(format!("qp{qpn}: window accounting corrupted (stall)"))
            }
            2 => {
                // stuck retransmission timer: the deadline register is
                // corrupted far into the future — recovery never fires
                q.rto_deadline = SimTime::MAX / 2;
                q.stalled = q.outstanding > 0;
                Some(format!("qp{qpn}: stuck retry timer"))
            }
            3 => {
                // bitmap corruption: mark a received fragment lost forever
                if let Some(m) = q.recv_msgs.values_mut().next() {
                    if let Some(slot) = m.got.iter_mut().find(|g| **g) {
                        *slot = false;
                        return Some(format!("qp{qpn}: receiver bitmap bit-flip"));
                    }
                }
                None
            }
            _ => {
                // corrupt a queued fragment length → placement garbage;
                // modeled as dropping the frag state (message never completes)
                let psn = q.frags.keys().next().copied();
                if let Some(psn) = psn {
                    q.frags.remove(&psn);
                    q.stalled = true;
                    Some(format!("qp{qpn}: WQE cache entry corrupted"))
                } else {
                    None
                }
            }
        }
    }
}

fn error_cqe(wqe: &Wqe, qpn: Qpn, time: SimTime, is_recv: bool) -> Cqe {
    Cqe {
        wr_id: wqe.wr_id,
        qpn,
        status: CqStatus::Error,
        bytes: 0,
        expected_bytes: wqe.total_len(),
        imm: None,
        time,
        is_recv,
        loss: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_floor() {
        let fab = crate::net::FabricCfg::cloudlab(2);
        let cfg = TransportCfg::from_fabric(&fab);
        let r = Reliable::new(
            0,
            cfg,
            ReliableCfg {
                mode: RelMode::GoBackN,
                sw_datapath: false,
                spray: false,
                dup_threshold: 3,
            },
        );
        assert!(r.window_bytes() >= 64 * 1024);
    }
}
