//! SRNIC (Wang et al., NSDI'23): a scalable RDMA NIC architecture.
//!
//! Slims the NIC by removing the WQE cache and onloading retransmission +
//! reordering to host software. Per-QP NIC context drops to 242 B, raising
//! QP density (Table 4) — but the host datapath adds per-packet CPU cost
//! and loss recovery still gates forward progress on full delivery.

use crate::net::Packet;
use crate::sim::cluster::NicCtx;
use crate::transport::reliable::{RelMode, Reliable, ReliableCfg};
use crate::transport::{FeatureMatrix, Transport, TransportCfg};
use crate::verbs::{NodeId, Qp, Qpn, Wqe};

pub struct Srnic {
    inner: Reliable,
}

impl Srnic {
    pub fn new(node: NodeId, cfg: TransportCfg) -> Srnic {
        Srnic {
            inner: Reliable::new(
                node,
                cfg,
                ReliableCfg {
                    mode: RelMode::SelRepeat,
                    sw_datapath: true, // reordering + retransmission on host
                    spray: false,
                    dup_threshold: 3,
                },
            ),
        }
    }
}

impl Transport for Srnic {
    fn name(&self) -> &'static str {
        "SRNIC"
    }

    fn create_qp(&mut self, qp: Qp) {
        self.inner.create_qp_impl(qp);
    }

    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_send_impl(ctx, qpn, wqe);
    }

    fn post_send_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        self.inner.post_send_batch_impl(ctx, batch);
    }

    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_recv_impl(ctx, qpn, wqe);
    }

    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet) {
        self.inner.on_packet_impl(ctx, pkt);
    }

    fn on_timer(&mut self, ctx: &mut NicCtx, timer_id: u64) {
        self.inner.on_timer_impl(ctx, timer_id);
    }

    fn features(&self) -> FeatureMatrix {
        FeatureMatrix {
            reliability: "Selective Repeat (SW)",
            reordering: "Software Reordering",
            congestion_control: "Hardware",
            pfc_required: false,
            target: "RDMA + ML",
            key_focus: "+Connection scalability",
        }
    }

    fn qp_state_bytes(&self) -> usize {
        crate::hw::qp_state::breakdown(crate::transport::TransportKind::Srnic).total()
    }

    fn cc_kind(&self) -> crate::cc::CcKind {
        self.inner.cc_kind()
    }

    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String> {
        self.inner.inject_fault_impl(rng)
    }

    fn stalled_qps(&self) -> usize {
        self.inner.stalled_count()
    }
}
