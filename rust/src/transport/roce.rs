//! RoCEv2 with RC QPs — the production baseline (§2.3, §5.1.2).
//!
//! Go-Back-N retransmission in hardware, strict in-order delivery, PFC
//! required for lossless operation. A single dropped packet forces the
//! receiver to discard everything after the gap and the sender to rewind —
//! the retransmission storms and PFC head-of-line blocking the paper's
//! motivation section describes.

use crate::net::Packet;
use crate::sim::cluster::NicCtx;
use crate::transport::reliable::{RelMode, Reliable, ReliableCfg};
use crate::transport::{FeatureMatrix, Transport, TransportCfg};
use crate::verbs::{NodeId, Qp, Qpn, Wqe};

pub struct Roce {
    inner: Reliable,
}

impl Roce {
    pub fn new(node: NodeId, cfg: TransportCfg) -> Roce {
        Roce {
            inner: Reliable::new(
                node,
                cfg,
                ReliableCfg {
                    mode: RelMode::GoBackN,
                    sw_datapath: false,
                    spray: false,
                    dup_threshold: 3,
                },
            ),
        }
    }
}

impl Transport for Roce {
    fn name(&self) -> &'static str {
        "RoCE"
    }

    fn create_qp(&mut self, qp: Qp) {
        self.inner.create_qp_impl(qp);
    }

    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_send_impl(ctx, qpn, wqe);
    }

    fn post_send_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        self.inner.post_send_batch_impl(ctx, batch);
    }

    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_recv_impl(ctx, qpn, wqe);
    }

    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet) {
        self.inner.on_packet_impl(ctx, pkt);
    }

    fn on_timer(&mut self, ctx: &mut NicCtx, timer_id: u64) {
        self.inner.on_timer_impl(ctx, timer_id);
    }

    fn features(&self) -> FeatureMatrix {
        FeatureMatrix {
            reliability: "Go-Back-N (HW)",
            reordering: "No/Dropped",
            congestion_control: "Hardware",
            pfc_required: true,
            target: "General RDMA",
            key_focus: "High performance",
        }
    }

    /// Per-QP NIC context (Table 4: 407 B). Breakdown in `hw::qp_state`.
    fn qp_state_bytes(&self) -> usize {
        crate::hw::qp_state::breakdown(crate::transport::TransportKind::Roce).total()
    }

    fn requires_pfc(&self) -> bool {
        true
    }

    fn cc_kind(&self) -> crate::cc::CcKind {
        self.inner.cc_kind()
    }

    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String> {
        self.inner.inject_fault_impl(rng)
    }

    fn stalled_qps(&self) -> usize {
        self.inner.stalled_count()
    }
}
