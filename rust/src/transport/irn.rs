//! IRN (Mittal et al., SIGCOMM'18): "Revisiting Network Support for RDMA".
//!
//! Removes PFC by adding NIC-resident selective repeat: per-QP bitmap
//! tracking of received PSNs, SACK-carrying ACKs, and BSN-based loss
//! recovery. Out-of-order packets are placed directly but tracked in NIC
//! state — the bitmap + outstanding-request tables that inflate its per-QP
//! footprint to 596 B (Table 4) and its BRAM usage (Table 5).

use crate::net::Packet;
use crate::sim::cluster::NicCtx;
use crate::transport::reliable::{RelMode, Reliable, ReliableCfg};
use crate::transport::{FeatureMatrix, Transport, TransportCfg};
use crate::verbs::{NodeId, Qp, Qpn, Wqe};

pub struct Irn {
    inner: Reliable,
}

impl Irn {
    pub fn new(node: NodeId, cfg: TransportCfg) -> Irn {
        Irn {
            inner: Reliable::new(
                node,
                cfg,
                ReliableCfg {
                    mode: RelMode::SelRepeat,
                    sw_datapath: false,
                    spray: false,
                    dup_threshold: 3,
                },
            ),
        }
    }
}

impl Transport for Irn {
    fn name(&self) -> &'static str {
        "IRN"
    }

    fn create_qp(&mut self, qp: Qp) {
        self.inner.create_qp_impl(qp);
    }

    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_send_impl(ctx, qpn, wqe);
    }

    fn post_send_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        self.inner.post_send_batch_impl(ctx, batch);
    }

    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_recv_impl(ctx, qpn, wqe);
    }

    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet) {
        self.inner.on_packet_impl(ctx, pkt);
    }

    fn on_timer(&mut self, ctx: &mut NicCtx, timer_id: u64) {
        self.inner.on_timer_impl(ctx, timer_id);
    }

    fn features(&self) -> FeatureMatrix {
        FeatureMatrix {
            reliability: "Selective Repeat (HW)",
            reordering: "Buffered in NIC",
            congestion_control: "Hardware",
            pfc_required: false,
            target: "General RDMA",
            key_focus: "+Network efficiency",
        }
    }

    fn qp_state_bytes(&self) -> usize {
        crate::hw::qp_state::breakdown(crate::transport::TransportKind::Irn).total()
    }

    fn cc_kind(&self) -> crate::cc::CcKind {
        self.inner.cc_kind()
    }

    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String> {
        self.inner.inject_fault_impl(rng)
    }

    fn stalled_qps(&self) -> usize {
        self.inner.stalled_count()
    }
}
