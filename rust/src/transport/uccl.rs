//! UCCL (Zhou et al., 2025): an extensible software transport for GPU
//! networking.
//!
//! Onloads the entire transport control plane — congestion control, flow
//! scheduling, multipath — into host software, using the NIC purely as a
//! datapath. Behaviorally: software selective repeat (per-packet host CPU
//! cost on both ends), software CC, and multipath spraying across its many
//! connections. UCCL opens 256 connections per peer (vs 2 for the other
//! designs), which is what collapses its cluster-scale column in Table 4.

use crate::net::Packet;
use crate::sim::cluster::NicCtx;
use crate::transport::reliable::{RelMode, Reliable, ReliableCfg};
use crate::transport::{FeatureMatrix, Transport, TransportCfg};
use crate::verbs::{NodeId, Qp, Qpn, Wqe};

/// Connections opened per peer (UCCL's multipath fan-out).
pub const CONNS_PER_PEER: usize = 256;

pub struct Uccl {
    inner: Reliable,
}

impl Uccl {
    pub fn new(node: NodeId, mut cfg: TransportCfg) -> Uccl {
        // software CC: slower control loop — model with software datapath
        // cost; algorithm itself stays (DCQCN logic in software).
        cfg.sw_overhead_ns = cfg.sw_overhead_ns.max(200);
        Uccl {
            inner: Reliable::new(
                node,
                cfg,
                ReliableCfg {
                    mode: RelMode::SelRepeat,
                    sw_datapath: true,
                    spray: true, // multipath across its connection fan-out
                    dup_threshold: 8,
                },
            ),
        }
    }
}

impl Transport for Uccl {
    fn name(&self) -> &'static str {
        "UCCL"
    }

    fn create_qp(&mut self, qp: Qp) {
        self.inner.create_qp_impl(qp);
    }

    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_send_impl(ctx, qpn, wqe);
    }

    fn post_send_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        self.inner.post_send_batch_impl(ctx, batch);
    }

    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_recv_impl(ctx, qpn, wqe);
    }

    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet) {
        self.inner.on_packet_impl(ctx, pkt);
    }

    fn on_timer(&mut self, ctx: &mut NicCtx, timer_id: u64) {
        self.inner.on_timer_impl(ctx, timer_id);
    }

    fn features(&self) -> FeatureMatrix {
        FeatureMatrix {
            reliability: "Selective Repeat (SW)",
            reordering: "Software Reordering",
            congestion_control: "Software",
            pfc_required: false,
            target: "ML Collectives",
            key_focus: "+Programmable transport",
        }
    }

    fn qp_state_bytes(&self) -> usize {
        crate::hw::qp_state::breakdown(crate::transport::TransportKind::Uccl).total()
    }

    fn cc_kind(&self) -> crate::cc::CcKind {
        self.inner.cc_kind()
    }

    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String> {
        self.inner.inject_fault_impl(rng)
    }

    fn stalled_qps(&self) -> usize {
        self.inner.stalled_count()
    }
}
