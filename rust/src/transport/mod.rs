//! Transport layer: the six RDMA NIC designs compared in the paper
//! (Table 1), behind one trait.
//!
//! | Transport | Reliability | Reordering | CC | PFC |
//! |-----------|-------------|------------|----|-----|
//! | RoCE      | Go-Back-N (HW) | no/dropped | HW | required |
//! | IRN       | Selective Repeat (HW) | NIC buffer | HW | no |
//! | SRNIC     | Selective Repeat (SW) | SW reorder | HW | no |
//! | Falcon    | Selective Repeat (HW) | NIC buffer | HW (delay) + multipath | no |
//! | UCCL      | Selective Repeat (SW) | SW reorder | SW | no |
//! | OptiNIC   | **Best effort** | **offset-based placement** | HW | no |

pub mod falcon;
pub mod irn;
pub mod optinic;
pub mod reliable;
pub mod roce;
pub mod srnic;
pub mod uccl;

use crate::net::Packet;
use crate::sim::cluster::NicCtx;
use crate::sim::SimTime;
use crate::verbs::{Qp, Qpn, Wqe};

/// One NIC's transport engine. The DES engine drives it with packets and
/// timer fires; it reacts by DMA-placing data, transmitting packets, and
/// pushing CQEs.
pub trait Transport {
    fn name(&self) -> &'static str;

    /// Install a connected QP endpoint.
    fn create_qp(&mut self, qp: Qp);

    /// Post to the send queue.
    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe);

    /// Post to the receive queue (two-sided verbs).
    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe);

    /// A packet addressed to this NIC arrived.
    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet);

    /// A transport timer fired (ids are transport-managed).
    fn on_timer(&mut self, ctx: &mut NicCtx, timer_id: u64);

    /// Qualitative design-space position (paper Table 1).
    fn features(&self) -> FeatureMatrix;

    /// Per-QP NIC context in bytes (paper Table 4). Computed from the
    /// state the implementation actually keeps in "NIC SRAM".
    fn qp_state_bytes(&self) -> usize;

    /// Does this transport require lossless (PFC) operation?
    fn requires_pfc(&self) -> bool {
        false
    }

    /// Flip random bits in live NIC state (SEU fault injection, §2.4).
    /// Returns a human-readable description of what was corrupted, or None
    /// if the transport holds no corruptible NIC state for that roll.
    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String>;

    /// Number of QPs currently stalled (no forward progress possible
    /// without external recovery) — used by the fault experiments.
    fn stalled_qps(&self) -> usize {
        0
    }
}

/// Qualitative feature matrix (paper Tables 1 & 2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FeatureMatrix {
    pub reliability: &'static str,
    pub reordering: &'static str,
    pub congestion_control: &'static str,
    pub pfc_required: bool,
    pub target: &'static str,
    pub key_focus: &'static str,
}

/// Transport selector used by configs/CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransportKind {
    Roce,
    Irn,
    Srnic,
    Falcon,
    Uccl,
    Optinic,
    /// OptiNIC with software overheads removed — the paper's "OPTINIC (HW)"
    /// configuration in Fig 5 (same protocol, zero host-side per-fragment
    /// CPU cost).
    OptinicHw,
}

impl TransportKind {
    pub const ALL: [TransportKind; 6] = [
        TransportKind::Roce,
        TransportKind::Irn,
        TransportKind::Srnic,
        TransportKind::Falcon,
        TransportKind::Uccl,
        TransportKind::Optinic,
    ];

    pub fn parse(s: &str) -> Option<TransportKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "roce" | "rocev2" | "rc" => TransportKind::Roce,
            "irn" => TransportKind::Irn,
            "srnic" => TransportKind::Srnic,
            "falcon" => TransportKind::Falcon,
            "uccl" => TransportKind::Uccl,
            "optinic" | "xp" => TransportKind::Optinic,
            "optinic-hw" | "optinic_hw" | "xp-hw" => TransportKind::OptinicHw,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Roce => "RoCE",
            TransportKind::Irn => "IRN",
            TransportKind::Srnic => "SRNIC",
            TransportKind::Falcon => "Falcon",
            TransportKind::Uccl => "UCCL",
            TransportKind::Optinic => "OptiNIC",
            TransportKind::OptinicHw => "OptiNIC (HW)",
        }
    }

    /// Instantiate a transport engine for one NIC.
    pub fn build(
        &self,
        node: crate::verbs::NodeId,
        cfg: &TransportCfg,
    ) -> Box<dyn Transport> {
        match self {
            TransportKind::Roce => Box::new(roce::Roce::new(node, cfg.clone())),
            TransportKind::Irn => Box::new(irn::Irn::new(node, cfg.clone())),
            TransportKind::Srnic => Box::new(srnic::Srnic::new(node, cfg.clone())),
            TransportKind::Falcon => Box::new(falcon::Falcon::new(node, cfg.clone())),
            TransportKind::Uccl => Box::new(uccl::Uccl::new(node, cfg.clone())),
            TransportKind::Optinic => {
                // the paper's software prototype (§4): EQDS receiver-driven
                // CC and per-fragment WRITE_WITH_IMM host cost (§3.3 UC emu)
                let mut c = cfg.clone();
                if !c.cc_forced {
                    c.cc = crate::cc::CcKind::Eqds;
                }
                c.sw_overhead_ns = c.sw_overhead_ns.max(1_000);
                Box::new(optinic::Optinic::new(node, c, false))
            }
            TransportKind::OptinicHw => {
                // FPGA datapath: same protocol, no host-side per-fragment
                // cost; EQDS retained (any of §3.1.3's CCs compose)
                let mut c = cfg.clone();
                if !c.cc_forced {
                    c.cc = crate::cc::CcKind::Eqds;
                }
                Box::new(optinic::Optinic::new(node, c, true))
            }
        }
    }
}

/// Shared transport tuning knobs.
#[derive(Clone, Debug)]
pub struct TransportCfg {
    pub mtu: usize,
    /// Link rate, used for initial pacing rates (bytes/ns).
    pub link_bytes_per_ns: f64,
    /// Base RTT of the fabric, ns (pacing/timeout initialization).
    pub base_rtt_ns: u64,
    /// Congestion-control algorithm.
    pub cc: crate::cc::CcKind,
    /// When true, `cc` is an explicit experiment choice and transports must
    /// not substitute their paper-default algorithm (CC ablations).
    pub cc_forced: bool,
    /// Retransmission timeout for reliable transports, ns.
    pub rto_ns: u64,
    /// Max retransmission attempts before the QP errors out.
    pub max_retries: u32,
    /// Per-fragment software overhead for host-driven transports
    /// (segmentation, timers, pacing in software — §4's RoCE prototype).
    pub sw_overhead_ns: u64,
    /// Default OptiNIC message timeout when a WQE does not carry one, ns.
    pub default_msg_timeout_ns: u64,
}

impl TransportCfg {
    pub fn from_fabric(f: &crate::net::FabricCfg) -> TransportCfg {
        TransportCfg {
            // payload per wire MTU, rounded down to a 4-byte boundary so
            // fragment edges never split an f32 — a lost fragment must zero
            // whole elements, not tear them (§3.2 placement semantics)
            mtu: (1500 - 58) & !3,
            link_bytes_per_ns: f.bytes_per_ns(),
            base_rtt_ns: f.base_rtt_ns(),
            cc: crate::cc::CcKind::Dcqcn,
            cc_forced: false,
            rto_ns: 12 * f.base_rtt_ns() + 50_000,
            max_retries: 7,
            sw_overhead_ns: 150,
            default_msg_timeout_ns: 5_000_000,
        }
    }
}

/// Fragment a message into MTU-sized pieces. Returns (msg_offset, len, last).
pub fn fragment(msg_len: usize, mtu: usize) -> Vec<(usize, usize, bool)> {
    assert!(mtu > 0);
    if msg_len == 0 {
        return vec![(0, 0, true)];
    }
    let mut out = Vec::with_capacity(msg_len.div_ceil(mtu));
    let mut off = 0;
    while off < msg_len {
        let len = mtu.min(msg_len - off);
        let last = off + len == msg_len;
        out.push((off, len, last));
        off += len;
    }
    out
}

// ---- transport timer id encoding -------------------------------------------
// Timers are engine-scheduled but transport-interpreted. The id packs the
// QP number, a kind tag, and a generation counter so stale timers (from
// cancelled/rearmed logical timers) can be recognized and ignored.

pub const TIMER_PACE: u8 = 1;
pub const TIMER_RTO: u8 = 2;
pub const TIMER_MSG_DEADLINE: u8 = 3;
pub const TIMER_CREDIT: u8 = 4;
pub const TIMER_SEND_DEADLINE: u8 = 5;

pub fn timer_id(qpn: Qpn, kind: u8, generation: u32) -> u64 {
    ((qpn as u64) << 32) | ((kind as u64) << 24) | (generation as u64 & 0xff_ffff)
}

pub fn timer_parts(id: u64) -> (Qpn, u8, u32) {
    (
        (id >> 32) as Qpn,
        ((id >> 24) & 0xff) as u8,
        (id & 0xff_ffff) as u32,
    )
}

/// Rate-based pacer shared by all transports: tracks the time the link/CC
/// next permits a transmission.
#[derive(Clone, Copy, Debug)]
pub struct Pacer {
    pub next_tx: SimTime,
}

impl Pacer {
    pub fn new() -> Pacer {
        Pacer { next_tx: 0 }
    }

    /// Earliest time a packet of `bytes` may start transmitting given
    /// `rate` (bytes/ns); advances internal state assuming it does.
    pub fn reserve(&mut self, now: SimTime, bytes: usize, rate_bytes_per_ns: f64) -> SimTime {
        let start = self.next_tx.max(now);
        let dur = (bytes as f64 / rate_bytes_per_ns).ceil() as SimTime;
        self.next_tx = start + dur.max(1);
        start
    }
}

impl Default for Pacer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fragment_covers_message_exactly() {
        let frags = fragment(10_000, 1442);
        let total: usize = frags.iter().map(|(_, l, _)| l).sum();
        assert_eq!(total, 10_000);
        assert!(frags.iter().rev().skip(1).all(|(_, _, last)| !last));
        assert!(frags.last().unwrap().2);
        // offsets contiguous
        let mut expect = 0;
        for (off, len, _) in &frags {
            assert_eq!(*off, expect);
            expect += len;
        }
    }

    #[test]
    fn fragment_empty_message() {
        let frags = fragment(0, 1000);
        assert_eq!(frags, vec![(0, 0, true)]);
    }

    #[test]
    fn fragment_exact_multiple() {
        let frags = fragment(3000, 1000);
        assert_eq!(frags.len(), 3);
        assert!(frags[2].2);
        assert_eq!(frags[2], (2000, 1000, true));
    }

    #[test]
    fn pacer_enforces_rate() {
        let mut p = Pacer::new();
        // 1 byte/ns rate: 1000-byte packets are 1000 ns apart
        let t0 = p.reserve(0, 1000, 1.0);
        let t1 = p.reserve(0, 1000, 1.0);
        let t2 = p.reserve(0, 1000, 1.0);
        assert_eq!(t0, 0);
        assert_eq!(t1, 1000);
        assert_eq!(t2, 2000);
        // idle gap resets to `now`
        let t3 = p.reserve(10_000, 1000, 1.0);
        assert_eq!(t3, 10_000);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in TransportKind::ALL {
            let s = k.name().to_ascii_lowercase().replace(' ', "");
            // sanity: at least the canonical spellings parse
            let canon = match k {
                TransportKind::Roce => "roce",
                TransportKind::Irn => "irn",
                TransportKind::Srnic => "srnic",
                TransportKind::Falcon => "falcon",
                TransportKind::Uccl => "uccl",
                TransportKind::Optinic => "optinic",
                TransportKind::OptinicHw => "optinic-hw",
            };
            assert_eq!(TransportKind::parse(canon), Some(k), "spelling {s}");
        }
        assert_eq!(TransportKind::parse("bogus"), None);
    }
}
