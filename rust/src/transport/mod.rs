//! Transport layer: the six RDMA NIC designs compared in the paper
//! (Table 1), behind one trait.
//!
//! | Transport | Reliability | Reordering | CC | PFC |
//! |-----------|-------------|------------|----|-----|
//! | RoCE      | Go-Back-N (HW) | no/dropped | HW | required |
//! | IRN       | Selective Repeat (HW) | NIC buffer | HW | no |
//! | SRNIC     | Selective Repeat (SW) | SW reorder | HW | no |
//! | Falcon    | Selective Repeat (HW) | NIC buffer | HW (delay) + multipath | no |
//! | UCCL      | Selective Repeat (SW) | SW reorder | SW | no |
//! | OptiNIC   | **Best effort** | **offset-based placement** | HW | no |

pub mod falcon;
pub mod irn;
pub mod optinic;
pub mod reliable;
pub mod roce;
pub mod srnic;
pub mod uccl;

use crate::net::Packet;
use crate::sim::cluster::NicCtx;
use crate::sim::SimTime;
use crate::verbs::{Qp, Qpn, Wqe};

/// One NIC's transport engine. The DES engine drives it with packets and
/// timer fires; it reacts by DMA-placing data, transmitting packets, and
/// pushing wire CQEs (converted to typed `CqEvent`s at the CQ boundary).
///
/// `Send` supertrait: the partitioned engine moves each node's boxed
/// transport onto the worker thread that owns its partition.
pub trait Transport: Send {
    fn name(&self) -> &'static str;

    /// Install a connected QP endpoint.
    fn create_qp(&mut self, qp: Qp);

    /// Post to the send queue. Rings one doorbell per call — prefer
    /// [`Transport::post_send_batch`] from application code.
    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe);

    /// Post to the receive queue (two-sided verbs).
    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe);

    /// Post a batch of send WQEs with ONE doorbell per touched QP
    /// (verbs v2 doorbell batching). The default implementation falls back
    /// to per-WQE posting; engines that model host/doorbell overhead
    /// override it so the batching win is real, not cosmetic.
    fn post_send_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        for (qpn, wqe) in batch {
            self.post_send(ctx, qpn, wqe);
        }
    }

    /// Post a batch of receive WQEs in one engine crossing.
    fn post_recv_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        for (qpn, wqe) in batch {
            self.post_recv(ctx, qpn, wqe);
        }
    }

    /// A packet addressed to this NIC arrived.
    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet);

    /// A transport timer fired (ids are transport-managed).
    fn on_timer(&mut self, ctx: &mut NicCtx, timer_id: u64);

    /// Qualitative design-space position (paper Table 1).
    fn features(&self) -> FeatureMatrix;

    /// The CC algorithm this engine's [`crate::cc::CcDriver`] instantiates
    /// per QP. Engines never branch on this — it exists so experiments and
    /// regression tests can verify which algorithm a configuration
    /// resolved to (e.g. default-vs-forced CC).
    fn cc_kind(&self) -> crate::cc::CcKind;

    /// Per-QP NIC context in bytes (paper Table 4). Computed from the
    /// state the implementation actually keeps in "NIC SRAM".
    fn qp_state_bytes(&self) -> usize;

    /// Does this transport require lossless (PFC) operation?
    fn requires_pfc(&self) -> bool {
        false
    }

    /// Flip random bits in live NIC state (SEU fault injection, §2.4).
    /// Returns a human-readable description of what was corrupted, or None
    /// if the transport holds no corruptible NIC state for that roll.
    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String>;

    /// Number of QPs currently stalled (no forward progress possible
    /// without external recovery) — used by the fault experiments.
    fn stalled_qps(&self) -> usize {
        0
    }
}

/// Qualitative feature matrix (paper Tables 1 & 2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FeatureMatrix {
    pub reliability: &'static str,
    pub reordering: &'static str,
    pub congestion_control: &'static str,
    pub pfc_required: bool,
    pub target: &'static str,
    pub key_focus: &'static str,
}

/// Transport selector used by configs/CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransportKind {
    Roce,
    Irn,
    Srnic,
    Falcon,
    Uccl,
    Optinic,
    /// OptiNIC with software overheads removed — the paper's "OPTINIC (HW)"
    /// configuration in Fig 5 (same protocol, zero host-side per-fragment
    /// CPU cost).
    OptinicHw,
}

impl TransportKind {
    /// The six distinct NIC designs of the paper's Tables 1/4/5.
    /// `OptinicHw` is deliberately excluded: it is a datapath variant of
    /// `Optinic` (same protocol, same NIC state, zero host per-fragment
    /// cost), so it would duplicate every hardware-table column. Behavior
    /// sweeps that compare end-to-end performance should iterate
    /// [`TransportKind::ALL_WITH_VARIANTS`] instead.
    pub const ALL: [TransportKind; 6] = [
        TransportKind::Roce,
        TransportKind::Irn,
        TransportKind::Srnic,
        TransportKind::Falcon,
        TransportKind::Uccl,
        TransportKind::Optinic,
    ];

    /// Every parseable configuration, including datapath variants — the
    /// list the sweep benches iterate.
    pub const ALL_WITH_VARIANTS: [TransportKind; 7] = [
        TransportKind::Roce,
        TransportKind::Irn,
        TransportKind::Srnic,
        TransportKind::Falcon,
        TransportKind::Uccl,
        TransportKind::Optinic,
        TransportKind::OptinicHw,
    ];

    /// Canonical lower-case spelling, the inverse of [`TransportKind::parse`].
    pub fn canonical_name(&self) -> &'static str {
        match self {
            TransportKind::Roce => "roce",
            TransportKind::Irn => "irn",
            TransportKind::Srnic => "srnic",
            TransportKind::Falcon => "falcon",
            TransportKind::Uccl => "uccl",
            TransportKind::Optinic => "optinic",
            TransportKind::OptinicHw => "optinic-hw",
        }
    }

    pub fn parse(s: &str) -> Option<TransportKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "roce" | "rocev2" | "rc" => TransportKind::Roce,
            "irn" => TransportKind::Irn,
            "srnic" => TransportKind::Srnic,
            "falcon" => TransportKind::Falcon,
            "uccl" => TransportKind::Uccl,
            "optinic" | "xp" => TransportKind::Optinic,
            "optinic-hw" | "optinic_hw" | "xp-hw" => TransportKind::OptinicHw,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Roce => "RoCE",
            TransportKind::Irn => "IRN",
            TransportKind::Srnic => "SRNIC",
            TransportKind::Falcon => "Falcon",
            TransportKind::Uccl => "UCCL",
            TransportKind::Optinic => "OptiNIC",
            TransportKind::OptinicHw => "OptiNIC (HW)",
        }
    }

    /// Instantiate a transport engine for one NIC.
    pub fn build(
        &self,
        node: crate::verbs::NodeId,
        cfg: &TransportCfg,
    ) -> Box<dyn Transport> {
        match self {
            TransportKind::Roce => Box::new(roce::Roce::new(node, cfg.clone())),
            TransportKind::Irn => Box::new(irn::Irn::new(node, cfg.clone())),
            TransportKind::Srnic => Box::new(srnic::Srnic::new(node, cfg.clone())),
            TransportKind::Falcon => Box::new(falcon::Falcon::new(node, cfg.clone())),
            TransportKind::Uccl => Box::new(uccl::Uccl::new(node, cfg.clone())),
            TransportKind::Optinic => {
                // the paper's software prototype (§4): EQDS receiver-driven
                // CC and per-fragment WRITE_WITH_IMM host cost (§3.3 UC emu)
                let mut c = cfg.clone();
                if !c.cc_forced {
                    c.cc = crate::cc::CcKind::Eqds;
                }
                c.sw_overhead_ns = c.sw_overhead_ns.max(1_000);
                Box::new(optinic::Optinic::new(node, c, false))
            }
            TransportKind::OptinicHw => {
                // FPGA datapath: same protocol, no host-side per-fragment
                // cost; EQDS retained (any of §3.1.3's CCs compose)
                let mut c = cfg.clone();
                if !c.cc_forced {
                    c.cc = crate::cc::CcKind::Eqds;
                }
                Box::new(optinic::Optinic::new(node, c, true))
            }
        }
    }
}

/// Shared transport tuning knobs.
#[derive(Clone, Debug)]
pub struct TransportCfg {
    pub mtu: usize,
    /// Link rate, used for initial pacing rates (bytes/ns).
    pub link_bytes_per_ns: f64,
    /// Base RTT of the fabric, ns (pacing/timeout initialization).
    pub base_rtt_ns: u64,
    /// Congestion-control algorithm.
    pub cc: crate::cc::CcKind,
    /// When true, `cc` is an explicit experiment choice and transports must
    /// not substitute their paper-default algorithm (CC ablations).
    pub cc_forced: bool,
    /// Retransmission timeout for reliable transports, ns.
    pub rto_ns: u64,
    /// Max retransmission attempts before the QP errors out.
    pub max_retries: u32,
    /// Per-fragment software overhead for host-driven transports
    /// (segmentation, timers, pacing in software — §4's RoCE prototype).
    pub sw_overhead_ns: u64,
    /// Default OptiNIC message timeout when a WQE does not carry one, ns.
    pub default_msg_timeout_ns: u64,
    /// Host cost of ringing one doorbell (MMIO write + WQE fetch). Charged
    /// once per `post_send` call — so an N-WQE `post_send_batch` pays it
    /// once instead of N times, which is the doorbell-batching win the
    /// `perf_hotpath` bench measures.
    pub doorbell_ns: u64,
    /// True when the fabric offers genuine path diversity (leaf–spine):
    /// OptiNIC marks its fragments sprayable so the leaves fan them
    /// per-packet across spines — §3.1.1's OOO tolerance makes spraying
    /// free. Single-switch fabrics have no paths to spray over, so the
    /// flag stays off there and single-tier behavior is unchanged.
    pub multipath: bool,
    /// Links a one-way worst-case path traverses (2 for the ToR, 4 for
    /// leaf–spine, 6 for a cross-pod fat-tree) — the default
    /// `CcCtx::hops` when feedback carries no stamped hop count.
    pub path_hops: u32,
}

impl TransportCfg {
    pub fn from_fabric(f: &crate::net::FabricCfg) -> TransportCfg {
        TransportCfg {
            // payload per wire MTU, rounded down to a 4-byte boundary so
            // fragment edges never split an f32 — a lost fragment must zero
            // whole elements, not tear them (§3.2 placement semantics)
            mtu: (1500 - 58) & !3,
            link_bytes_per_ns: f.bytes_per_ns(),
            base_rtt_ns: f.base_rtt_ns(),
            cc: crate::cc::CcKind::Dcqcn,
            cc_forced: false,
            rto_ns: 12 * f.base_rtt_ns() + 50_000,
            max_retries: 7,
            sw_overhead_ns: 150,
            default_msg_timeout_ns: 5_000_000,
            doorbell_ns: 100,
            multipath: f.topo.is_multitier(),
            path_hops: f.path_links(),
        }
    }

    /// Force a CC algorithm as an explicit experiment choice: transports
    /// must not substitute their paper-default (`cc_forced`), and fluid
    /// cells route the same choice into their `RateAuthority`. The ONE
    /// place forced-CC intent is encoded — `ClusterCfg::with_cc` and the
    /// fluid engine's `enable_cc` both funnel through here.
    pub fn with_cc(mut self, cc: crate::cc::CcKind) -> TransportCfg {
        self.cc = cc;
        self.cc_forced = true;
        self
    }
}

/// Distinct QPNs touched by a posting batch, in first-appearance order —
/// shared by the engines' doorbell-batched posting (one doorbell ring and
/// one pump per touched QP). Linear scan: batches touch a handful of QPs.
pub(crate) fn batch_qpns(batch: &[(Qpn, Wqe)]) -> Vec<Qpn> {
    let mut touched: Vec<Qpn> = Vec::new();
    for &(qpn, _) in batch {
        if !touched.contains(&qpn) {
            touched.push(qpn);
        }
    }
    touched
}

/// Allocation-free fragmentation: yields `(msg_offset, len, last)` for
/// each MTU-sized piece of a message, exactly like [`fragment`] but
/// without building a `Vec` — the engines' send paths iterate this
/// directly (§Perf: admitting a multi-MB message used to allocate a
/// thousands-entry Vec per WQE). `ExactSizeIterator::len` gives the
/// fragment count up front for completion accounting.
#[derive(Clone, Copy, Debug)]
pub struct FragIter {
    off: usize,
    msg_len: usize,
    mtu: usize,
    /// A zero-length message still yields one empty terminal fragment.
    empty_pending: bool,
}

pub fn frag_iter(msg_len: usize, mtu: usize) -> FragIter {
    assert!(mtu > 0);
    FragIter {
        off: 0,
        msg_len,
        mtu,
        empty_pending: msg_len == 0,
    }
}

impl Iterator for FragIter {
    type Item = (usize, usize, bool);

    fn next(&mut self) -> Option<(usize, usize, bool)> {
        if self.empty_pending {
            self.empty_pending = false;
            return Some((0, 0, true));
        }
        if self.off >= self.msg_len {
            return None;
        }
        let len = self.mtu.min(self.msg_len - self.off);
        let last = self.off + len == self.msg_len;
        let item = (self.off, len, last);
        self.off += len;
        Some(item)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.len();
        (n, Some(n))
    }
}

impl ExactSizeIterator for FragIter {
    fn len(&self) -> usize {
        if self.empty_pending {
            1
        } else {
            (self.msg_len - self.off).div_ceil(self.mtu)
        }
    }
}

/// Fragment a message into MTU-sized pieces. Returns (msg_offset, len,
/// last). Vec-building convenience over [`frag_iter`], kept for tests and
/// cold paths.
pub fn fragment(msg_len: usize, mtu: usize) -> Vec<(usize, usize, bool)> {
    frag_iter(msg_len, mtu).collect()
}

// ---- transport timer id encoding -------------------------------------------
// Timers are engine-scheduled but transport-interpreted. The id packs the
// QP number, a kind tag, and a generation counter so stale timers (from
// cancelled/rearmed logical timers) can be recognized and ignored.

pub const TIMER_PACE: u8 = 1;
pub const TIMER_RTO: u8 = 2;
pub const TIMER_MSG_DEADLINE: u8 = 3;
pub const TIMER_CREDIT: u8 = 4;
pub const TIMER_SEND_DEADLINE: u8 = 5;

pub fn timer_id(qpn: Qpn, kind: u8, generation: u32) -> u64 {
    ((qpn as u64) << 32) | ((kind as u64) << 24) | (generation as u64 & 0xff_ffff)
}

pub fn timer_parts(id: u64) -> (Qpn, u8, u32) {
    (
        (id >> 32) as Qpn,
        ((id >> 24) & 0xff) as u8,
        (id & 0xff_ffff) as u32,
    )
}

/// Rate-based pacer shared by all transports: tracks the time the link/CC
/// next permits a transmission.
#[derive(Clone, Copy, Debug)]
pub struct Pacer {
    pub next_tx: SimTime,
}

impl Pacer {
    pub fn new() -> Pacer {
        Pacer { next_tx: 0 }
    }

    /// Earliest time a packet of `bytes` may start transmitting given
    /// `rate` (bytes/ns); advances internal state assuming it does.
    pub fn reserve(&mut self, now: SimTime, bytes: usize, rate_bytes_per_ns: f64) -> SimTime {
        let start = self.next_tx.max(now);
        let dur = (bytes as f64 / rate_bytes_per_ns).ceil() as SimTime;
        self.next_tx = start + dur.max(1);
        start
    }
}

impl Default for Pacer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fragment_covers_message_exactly() {
        let frags = fragment(10_000, 1442);
        let total: usize = frags.iter().map(|(_, l, _)| l).sum();
        assert_eq!(total, 10_000);
        assert!(frags.iter().rev().skip(1).all(|(_, _, last)| !last));
        assert!(frags.last().unwrap().2);
        // offsets contiguous
        let mut expect = 0;
        for (off, len, _) in &frags {
            assert_eq!(*off, expect);
            expect += len;
        }
    }

    #[test]
    fn fragment_empty_message() {
        let frags = fragment(0, 1000);
        assert_eq!(frags, vec![(0, 0, true)]);
    }

    #[test]
    fn fragment_exact_multiple() {
        let frags = fragment(3000, 1000);
        assert_eq!(frags.len(), 3);
        assert!(frags[2].2);
        assert_eq!(frags[2], (2000, 1000, true));
    }

    #[test]
    fn pacer_enforces_rate() {
        let mut p = Pacer::new();
        // 1 byte/ns rate: 1000-byte packets are 1000 ns apart
        let t0 = p.reserve(0, 1000, 1.0);
        let t1 = p.reserve(0, 1000, 1.0);
        let t2 = p.reserve(0, 1000, 1.0);
        assert_eq!(t0, 0);
        assert_eq!(t1, 1000);
        assert_eq!(t2, 2000);
        // idle gap resets to `now`
        let t3 = p.reserve(10_000, 1000, 1.0);
        assert_eq!(t3, 10_000);
    }

    /// Every variant — including the `OptinicHw` datapath variant that
    /// `ALL` intentionally omits — must round-trip through its canonical
    /// spelling, and the variant lists must be consistent.
    #[test]
    fn kind_parse_roundtrip_every_variant() {
        for k in TransportKind::ALL_WITH_VARIANTS {
            assert_eq!(
                TransportKind::parse(k.canonical_name()),
                Some(k),
                "canonical spelling '{}' must parse back",
                k.canonical_name()
            );
            assert!(!k.name().is_empty());
        }
        // ALL ⊂ ALL_WITH_VARIANTS, and the only extra is OptinicHw
        for k in TransportKind::ALL {
            assert!(TransportKind::ALL_WITH_VARIANTS.contains(&k));
        }
        assert!(TransportKind::ALL_WITH_VARIANTS.contains(&TransportKind::OptinicHw));
        assert!(!TransportKind::ALL.contains(&TransportKind::OptinicHw));
        assert_eq!(TransportKind::parse("bogus"), None);
        // alternate spellings still accepted
        assert_eq!(TransportKind::parse("xp-hw"), Some(TransportKind::OptinicHw));
        assert_eq!(TransportKind::parse("ROCEv2"), Some(TransportKind::Roce));
    }

    /// The engines are CC-agnostic: construction resolves the algorithm
    /// (paper defaults when the user expressed no preference, the forced
    /// choice otherwise) and `cc_kind` reports what was resolved.
    #[test]
    fn built_engines_report_resolved_cc() {
        use crate::cc::CcKind;
        let fab = crate::net::FabricCfg::cloudlab(2);
        let cfg = TransportCfg::from_fabric(&fab);
        for (kind, want) in [
            (TransportKind::Optinic, CcKind::Eqds),
            (TransportKind::OptinicHw, CcKind::Eqds),
            (TransportKind::Falcon, CcKind::Swift),
            (TransportKind::Roce, CcKind::Dcqcn),
            (TransportKind::Irn, CcKind::Dcqcn),
            (TransportKind::Srnic, CcKind::Dcqcn),
            (TransportKind::Uccl, CcKind::Dcqcn),
        ] {
            assert_eq!(kind.build(0, &cfg).cc_kind(), want, "{kind:?} default");
        }
        // an explicit experiment choice survives every constructor
        let mut forced = cfg.clone();
        forced.cc = CcKind::Hpcc;
        forced.cc_forced = true;
        for kind in TransportKind::ALL_WITH_VARIANTS {
            assert_eq!(
                kind.build(0, &forced).cc_kind(),
                CcKind::Hpcc,
                "{kind:?} must honor cc_forced"
            );
        }
    }

    // ---- fragment() properties (util::proptest_mini) -----------------------

    use crate::util::prng::Pcg64;
    use crate::util::proptest_mini::{check, Gen, PropConfig};

    /// Random (msg_len, mtu) cases biased toward the edges that matter:
    /// empty messages, exact-multiple lengths, mtu 1, len < mtu.
    struct FragCaseGen;

    impl Gen<(u64, u64)> for FragCaseGen {
        fn generate(&self, rng: &mut Pcg64) -> (u64, u64) {
            let mtu = match rng.below(4) {
                0 => 1,
                1 => 1 + rng.below(16),
                _ => 1 + rng.below(4096),
            };
            let len = match rng.below(5) {
                0 => 0,                        // empty message
                1 => mtu * (1 + rng.below(8)), // exact multiple of mtu
                2 => rng.below(mtu.max(2)),    // shorter than one fragment
                _ => rng.below(1 << 16),
            };
            (len, mtu)
        }
        fn shrink(&self, &(len, mtu): &(u64, u64)) -> Vec<(u64, u64)> {
            let mut out = Vec::new();
            if len > 0 {
                out.push((len / 2, mtu));
                out.push((0, mtu));
            }
            if mtu > 1 {
                out.push((len, mtu / 2));
                out.push((len, 1));
            }
            out
        }
    }

    fn frag_cfg() -> PropConfig {
        PropConfig {
            cases: 256,
            seed: 0xF7A6,
            max_shrink_steps: 64,
        }
    }

    #[test]
    fn fragment_prop_offsets_cover_exactly() {
        check("fragment-covers-msg", frag_cfg(), &FragCaseGen, |&(len, mtu)| {
            let (len, mtu) = (len as usize, mtu as usize);
            let frags = fragment(len, mtu);
            crate::prop_assert!(!frags.is_empty(), "at least one fragment always");
            let mut expect = 0usize;
            for &(off, l, _) in &frags {
                crate::prop_assert!(off == expect, "gap/overlap at offset {off}, expected {expect}");
                crate::prop_assert!(
                    l <= mtu && (l > 0 || len == 0),
                    "fragment len {l} out of (0, mtu={mtu}]"
                );
                expect += l;
            }
            crate::prop_assert!(expect == len, "covered {expect} of {len} bytes");
            Ok(())
        });
    }

    #[test]
    fn fragment_prop_last_flag_unique() {
        check("fragment-last-unique", frag_cfg(), &FragCaseGen, |&(len, mtu)| {
            let frags = fragment(len as usize, mtu as usize);
            let lasts = frags.iter().filter(|&&(_, _, last)| last).count();
            crate::prop_assert!(lasts == 1, "{lasts} fragments flagged last");
            crate::prop_assert!(frags.last().unwrap().2, "final fragment must carry the flag");
            Ok(())
        });
    }

    #[test]
    fn fragment_prop_count_matches_div_ceil() {
        check("fragment-count", frag_cfg(), &FragCaseGen, |&(len, mtu)| {
            let (len, mtu) = (len as usize, mtu as usize);
            let frags = fragment(len, mtu);
            let want = if len == 0 { 1 } else { len.div_ceil(mtu) };
            crate::prop_assert!(
                frags.len() == want,
                "{} fragments for len={len} mtu={mtu}, want {want}",
                frags.len()
            );
            Ok(())
        });
    }

    /// The allocation-free iterator must agree with the Vec builder on
    /// every case, including its exact-size accounting.
    #[test]
    fn frag_iter_prop_matches_fragment() {
        check("frag-iter-matches-vec", frag_cfg(), &FragCaseGen, |&(len, mtu)| {
            let (len, mtu) = (len as usize, mtu as usize);
            let it = frag_iter(len, mtu);
            crate::prop_assert!(
                it.len() == fragment(len, mtu).len(),
                "ExactSizeIterator len mismatch"
            );
            let collected: Vec<_> = it.collect();
            crate::prop_assert!(
                collected == fragment(len, mtu),
                "iterator items diverge from fragment()"
            );
            Ok(())
        });
    }

    #[test]
    fn frag_iter_len_tracks_consumption() {
        let mut it = frag_iter(2500, 1000);
        assert_eq!(it.len(), 3);
        assert_eq!(it.next(), Some((0, 1000, false)));
        assert_eq!(it.len(), 2);
        assert_eq!(it.next(), Some((1000, 1000, false)));
        assert_eq!(it.next(), Some((2000, 500, true)));
        assert_eq!(it.len(), 0);
        assert_eq!(it.next(), None);
        // empty message: exactly one empty terminal fragment
        let mut it = frag_iter(0, 64);
        assert_eq!(it.len(), 1);
        assert_eq!(it.next(), Some((0, 0, true)));
        assert_eq!(it.len(), 0);
        assert_eq!(it.next(), None);
    }

    #[test]
    fn fragment_explicit_edges() {
        // msg_len == 0: one empty terminal fragment
        assert_eq!(fragment(0, 1000), vec![(0, 0, true)]);
        // msg_len == mtu: exactly one full fragment
        assert_eq!(fragment(1000, 1000), vec![(0, 1000, true)]);
        // msg_len % mtu == 0: the last fragment is full-sized, no empty tail
        let frags = fragment(4000, 1000);
        assert_eq!(frags.len(), 4);
        assert_eq!(frags[3], (3000, 1000, true));
        // mtu of 1 byte
        let frags = fragment(3, 1);
        assert_eq!(frags, vec![(0, 1, false), (1, 1, false), (2, 1, true)]);
    }
}
