//! OPTINIC XP (eXpress Path): best-effort, out-of-order, timeout-bounded
//! RDMA transport (§3).
//!
//! What is *gone* relative to the reliable designs: retransmission queues,
//! reorder buffers, PSN windows, bitmaps, retry counters. What remains per
//! QP: an expected `wqe_seq`, one active-message descriptor (byte counter +
//! deadline), and CC metadata — 52 B total (Table 4).
//!
//! Mechanisms implemented here, with paper section references:
//! * self-describing packets — every fragment carries full placement info
//!   (RETH or explicit byte offset) and is DMA-placed on arrival (§3.1.1);
//! * single-active-message per QP keyed by `wqe_seq`; the three-way
//!   match / greater (preempt) / less (drop stale) rule (§3.1.1);
//! * bounded completion — per-WQE deadline timers and byte counters;
//!   partial-progress CQEs; sender completes on transmit (§3.1.2);
//! * early completion via preemption when a newer message arrives (§3.1.2);
//! * READ deadline piggybacking: the responder stops sending once the
//!   requester's deadline passes (§3.1.2);
//! * CC decoupled from reliability: ACKs are pure feedback, lost packets
//!   yield none (§3.1.3); EQDS pull-credits supported (§4);
//! * `hw=false` models the software prototype on commodity RoCE NICs
//!   (per-fragment host CPU cost, §3.3/§4); `hw=true` is the FPGA datapath
//!   ("OPTINIC (HW)" in Fig 5).

use std::collections::{BTreeMap, VecDeque};

use crate::cc::{Admit, CcDriver, CcKind};
use crate::net::{AckHdr, DataHdr, NetHints, Packet, PktKind, RethHdr};
use crate::sim::cluster::NicCtx;
use crate::sim::SimTime;
use crate::transport::{
    frag_iter, timer_id, timer_parts, FeatureMatrix, Transport, TransportCfg,
    TIMER_CREDIT, TIMER_MSG_DEADLINE, TIMER_PACE, TIMER_SEND_DEADLINE,
};
use crate::verbs::{CqStatus, Cqe, LossMap, NodeId, Qp, Qpn, Verb, Wqe};

/// ACK coalescing: one CC-feedback ACK per this many fragments (+ last).
const ACK_COALESCE: usize = 4;

/// One outgoing fragment (already self-describing).
#[derive(Clone, Copy, Debug)]
struct FragOut {
    wqe_seq: u32,
    msg_offset: usize,
    len: usize,
    last: bool,
}

/// Sender-side message in flight.
#[derive(Clone, Debug)]
struct SendMsg {
    wr_id: u64,
    verb: Verb,
    src_mr: crate::verbs::MrId,
    src_off: usize,
    msg_len: usize,
    remote: Option<crate::verbs::RemoteBuf>,
    imm: Option<u32>,
    stride: u16,
    frags_left: usize,
    sent_bytes: usize,
    /// absolute deadline for the send WQE, if any
    deadline: Option<SimTime>,
    deadline_gen: u32,
}

/// The receiver's single-active-message state: this plus `expected_wqe_seq`
/// is the *entire* per-QP receive context (§3.1.1 "single-active-message").
#[derive(Clone, Debug)]
struct ActiveMsg {
    wqe_seq: u32,
    bytes: usize,
    msg_len: usize,
    wr_id: Option<u64>,
    dst: Option<(crate::verbs::MrId, usize)>,
    imm: Option<u32>,
    deadline_gen: u32,
    is_recv_wqe: bool,
    /// Byte intervals actually placed — surfaced on the completion as the
    /// loss map apps/recovery consume directly (verbs v2).
    loss: LossMap,
}

struct QpState {
    qp: Qp,
    // ---- sender ----
    out: VecDeque<FragOut>,
    send_msgs: BTreeMap<u32, SendMsg>,
    next_wqe_seq: u32,
    // ---- receiver ----
    expected_wqe_seq: u32,
    active: Option<ActiveMsg>,
    recv_wqes: VecDeque<Wqe>,
    /// (timer generation, timeout duration, armed) parallel to `recv_wqes`.
    /// Per-WQE timers (§3.1.2) arm when the WQE becomes *active* — head of
    /// the queue (its turn in the sequential schedule) or first fragment —
    /// so each operation gets its own slice of the collective budget.
    recv_meta: VecDeque<(u32, SimTime, bool)>,
    /// wqe_seq the next pending recv WQE will be matched to.
    next_recv_seq: u32,
    deadline_gen: u32,
    acks_pending: usize,
    acked_bytes_pending: usize,
    /// Telemetry merged across the fragments one coalesced ACK covers.
    hints_pending: NetHints,
    last_tx_time_echo: SimTime,
}

/// The OptiNIC transport engine for one NIC.
pub struct Optinic {
    pub node: NodeId,
    pub cfg: TransportCfg,
    /// true = FPGA datapath (no per-fragment host cost) — "OPTINIC (HW)".
    pub hw: bool,
    qps: BTreeMap<Qpn, QpState>,
    /// The CC plane: per-QP algorithm instances, pacing, credit grants.
    /// The engine itself is CC-agnostic (§3.1.3 made structural).
    cc: CcDriver,
    /// Fault-injection bookkeeping: descriptions of injected faults (the
    /// design self-heals, so none of these stall a QP).
    faults_injected: u64,
}

impl Optinic {
    pub fn new(node: NodeId, cfg: TransportCfg, hw: bool) -> Optinic {
        let cc = CcDriver::new(&cfg);
        Optinic {
            node,
            cfg,
            hw,
            qps: BTreeMap::new(),
            cc,
            faults_injected: 0,
        }
    }

    fn sw_cost(&self) -> SimTime {
        if self.hw {
            0
        } else {
            self.cfg.sw_overhead_ns
        }
    }

    fn default_deadline(&self, now: SimTime, wqe: &Wqe) -> SimTime {
        match wqe.timeout {
            Some(t) => now + t,
            None => now + self.cfg.default_msg_timeout_ns,
        }
    }

    // ---- sender ---------------------------------------------------------------

    /// Charge the host-side doorbell cost (MMIO + WQE fetch) to the QP's
    /// pacing horizon. Called once per doorbell ring: batched posts pay it
    /// once for the whole batch (verbs v2 doorbell batching).
    fn ring_doorbell(&mut self, now: SimTime, qpn: Qpn) {
        self.cc.charge_doorbell(qpn, now, self.cfg.doorbell_ns);
    }

    fn admit_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        let now = ctx.time;
        let deadline = self.default_deadline(now, &wqe);
        let q = self.qps.get_mut(&qpn).expect("unknown QP");
        let seq = q.next_wqe_seq;
        q.next_wqe_seq += 1;
        let sge = wqe.sges[0];
        // allocation-free fragmentation (§Perf): the iterator's exact size
        // seeds the completion counter, then the send queue consumes it
        let frags = frag_iter(wqe.total_len(), q.qp.mtu);
        let gen = seq & 0xff_ffff;
        q.send_msgs.insert(
            seq,
            SendMsg {
                wr_id: wqe.wr_id,
                verb: wqe.verb,
                src_mr: sge.mr,
                src_off: sge.offset,
                msg_len: wqe.total_len(),
                remote: wqe.remote,
                imm: wqe.imm,
                stride: wqe.stride,
                frags_left: frags.len(),
                sent_bytes: 0,
                deadline: Some(deadline),
                deadline_gen: gen,
            },
        );
        for (off, len, last) in frags {
            q.out.push_back(FragOut {
                wqe_seq: seq,
                msg_offset: off,
                len,
                last,
            });
        }
        // Receiver-driven schemes announce demand so the peer's pull pacer
        // grants credits matched to data that actually wants to leave (the
        // speculative window covers the first BDP before grants arrive).
        // The CC plane decides; the engine never names an algorithm.
        if self.cc.announces_demand(qpn) {
            let pr = Packet::pull_req(
                self.node,
                q.qp.peer_node,
                q.qp.peer_qpn,
                wqe.total_len(),
            );
            ctx.tx(pr);
        }
        // send-WQE deadline (bounds CC starvation)
        ctx.set_timer(
            deadline - now,
            timer_id(qpn, TIMER_SEND_DEADLINE, gen as u32),
        );
    }

    fn pump(&mut self, ctx: &mut NicCtx, qpn: Qpn) {
        let sw_cost = self.sw_cost();
        let node = self.node;
        let spray = self.cfg.multipath;
        let Some(q) = self.qps.get_mut(&qpn) else { return };
        // resolve the CC admission gate once per pump (§Perf: no per-
        // fragment QP-map lookup on the send hot path)
        let Some(mut gate) = self.cc.gate(qpn) else { return };
        let mut pace: Option<(SimTime, bool)> = None;
        while let Some(frag) = q.out.front().copied() {
            // one CC-plane gate folds pacing, the software-datapath
            // throughput cap, and credit consumption
            match gate.admit(ctx.metrics, ctx.time, frag.len, sw_cost) {
                Admit::Go => {}
                Admit::Pace { at, arm } => {
                    pace = Some((at, arm));
                    break;
                }
                Admit::NoCredit => break, // credit grants re-pump
            }
            q.out.pop_front();
            let msg = q.send_msgs.get_mut(&frag.wqe_seq).expect("send msg");
            // EVERY fragment is self-describing: RETH (one-sided) or explicit
            // byte offset (two-sided) — §3.1.1.
            let reth = msg.remote.map(|r| RethHdr {
                mr: r.mr,
                offset: r.offset + frag.msg_offset,
                rkey: r.rkey,
            });
            let hdr = DataHdr {
                dst_qpn: q.qp.peer_qpn,
                src_qpn: q.qp.qpn,
                psn: 0, // no packet sequencing
                wqe_seq: frag.wqe_seq,
                msg_offset: frag.msg_offset,
                len: frag.len,
                last: frag.last,
                msg_len: msg.msg_len,
                src_mr: msg.src_mr,
                src_off: msg.src_off + frag.msg_offset,
                reth,
                stride: msg.stride,
                imm: if frag.last { msg.imm } else { None },
                deadline: None,
                tx_time: ctx.time,
                hints: NetHints::default(),
            };
            let mut pkt = Packet::data(node, q.qp.peer_node, hdr);
            // self-describing placement tolerates any reorder, so per-
            // packet spraying is free — fan fragments across every spine
            // whenever the fabric has real path diversity (§3.1.1)
            pkt.spray = spray;
            ctx.tx(pkt);
            msg.sent_bytes += frag.len;
            msg.frags_left -= 1;
            if msg.frags_left == 0 {
                // sender completes once all fragments are transmitted — no
                // acknowledgments required (§3.1.2); its deadline timer is
                // dead weight from here, so cancel it (lazy) instead of
                // letting the stale entry fire through the scheduler
                let m = q.send_msgs.remove(&frag.wqe_seq).unwrap();
                ctx.cancel_timer(timer_id(qpn, TIMER_SEND_DEADLINE, m.deadline_gen));
                ctx.push_cqe(Cqe {
                    wr_id: m.wr_id,
                    qpn,
                    status: CqStatus::Success,
                    bytes: m.msg_len,
                    expected_bytes: m.msg_len,
                    imm: None,
                    time: ctx.time + sw_cost,
                    is_recv: false,
                    loss: None,
                });
            }
        }
        if let Some((at, true)) = pace {
            ctx.set_timer(at - ctx.time, timer_id(qpn, TIMER_PACE, 0));
        }
    }

    // ---- receiver -------------------------------------------------------------

    fn on_data(&mut self, ctx: &mut NicCtx, from: NodeId, hdr: DataHdr) {
        let qpn = hdr.dst_qpn;
        let sw_cost = self.sw_cost();
        let default_timeout = self.cfg.default_msg_timeout_ns;
        let Some(q) = self.qps.get_mut(&qpn) else { return };

        // --- the three-way wqe_seq rule (§3.1.1) ---
        if hdr.wqe_seq < q.expected_wqe_seq {
            // late packet for a completed/timed-out message: drop, never
            // corrupt memory (§3.1.1 "Late Packet Handling")
            ctx.metrics.pkts_dropped_stale += 1;
            return;
        }
        if hdr.wqe_seq > q.expected_wqe_seq {
            // sender moved on: finalize the active message (preemption) and
            // any wholly-lost messages in between
            Self::finalize_through(ctx, q, hdr.wqe_seq, sw_cost, true);
        }
        debug_assert!(hdr.wqe_seq == q.expected_wqe_seq);

        // activate the message if this is its first fragment
        if q.active.is_none() {
            let needs_recv = hdr.reth.is_none() || hdr.imm.is_some();
            let (rwqe, gen) = if needs_recv {
                match q.recv_wqes.pop_front() {
                    Some(w) => {
                        let (gen, timeout, armed) =
                            q.recv_meta.pop_front().expect("meta");
                        q.next_recv_seq += 1;
                        if !armed {
                            ctx.set_timer(timeout, timer_id(qpn, TIMER_MSG_DEADLINE, gen));
                        }
                        (Some(w), gen)
                    }
                    None => match ctx.pop_srq() {
                        // SRQ fallback (verbs v2): any QP whose RQ ran dry
                        // consumes shared entries in FIFO order. The entry's
                        // deadline arms now — at activation — because an SRQ
                        // entry has no position in this QP's sequential
                        // message order until it is consumed.
                        Some(w) => {
                            q.deadline_gen += 1;
                            let gen = q.deadline_gen;
                            let timeout = w.timeout.unwrap_or(default_timeout);
                            ctx.set_timer(timeout, timer_id(qpn, TIMER_MSG_DEADLINE, gen));
                            ctx.metrics.bump("rx_srq_consumed");
                            (Some(w), gen)
                        }
                        None => {
                            // no posted receive anywhere: drop (best effort
                            // — no RNR storm)
                            ctx.metrics.bump("rx_no_recv_wqe");
                            return;
                        }
                    },
                }
            } else {
                // one-sided WRITE: bound it with the default timeout, armed
                // at activation (the sender owns the WQE timeout for WRITE)
                q.deadline_gen += 1;
                let gen = q.deadline_gen;
                ctx.set_timer(default_timeout, timer_id(qpn, TIMER_MSG_DEADLINE, gen));
                (None, gen)
            };
            let active = ActiveMsg {
                wqe_seq: hdr.wqe_seq,
                bytes: 0,
                msg_len: hdr.msg_len,
                wr_id: rwqe.as_ref().map(|w| w.wr_id),
                dst: rwqe.as_ref().map(|w| (w.sges[0].mr, w.sges[0].offset)),
                imm: None,
                deadline_gen: gen,
                is_recv_wqe: rwqe.is_some(),
                loss: LossMap::new(hdr.msg_len),
            };
            // zero the landing zone at activation: fragments that never
            // arrive must read as zeros (§3.2, "zeroed during placement")
            if let Some((mr, base)) = active.dst {
                ctx.mem.zero(mr, base, hdr.msg_len.min(ctx.mem.len(mr) - base));
            }
            q.active = Some(active);
        }

        let active = q.active.as_mut().unwrap();
        if hdr.imm.is_some() {
            active.imm = hdr.imm;
        }
        // in-place DMA using the self-describing header — no reordering,
        // no buffering (§3.1.1)
        let placed = if let Some(reth) = hdr.reth {
            ctx.mem
                .dma_copy(hdr.src_mr, hdr.src_off, reth.mr, reth.offset, hdr.len, None)
        } else if let Some((mr, base)) = active.dst {
            ctx.mem
                .dma_copy(hdr.src_mr, hdr.src_off, mr, base + hdr.msg_offset, hdr.len, None)
        } else {
            false
        };
        if placed {
            active.bytes += hdr.len;
            active.loss.record(hdr.msg_offset, hdr.len);
            ctx.metrics.data_bytes_delivered += hdr.len as u64;
        }

        let complete = hdr.last || active.bytes >= active.msg_len;

        // CC plane, receiver side: record the delivery (grant-rate AIMD
        // for receiver-driven schemes) and apply the notification-point
        // policy — the algorithm, not the engine, decides whether a CE
        // mark produces a CNP (§3.1.3: one code path for every scheme)
        if self.cc.on_delivery(qpn, ctx.time, hdr.len, &hdr.hints) {
            ctx.metrics.cnps_sent += 1;
            let cnp = Packet::cnp(ctx.node, from, hdr.src_qpn);
            ctx.tx(cnp);
        }
        // CC feedback: coalesced best-effort ACKs (pure feedback, §3.1.3)
        q.acks_pending += 1;
        q.acked_bytes_pending += hdr.len;
        q.hints_pending.merge(&hdr.hints);
        q.last_tx_time_echo = hdr.tx_time;
        if q.acks_pending >= ACK_COALESCE || complete {
            let ack = Packet::ack(
                ctx.node,
                from,
                AckHdr {
                    dst_qpn: hdr.src_qpn,
                    cumulative_psn: 0,
                    sack: None,
                    echo_tx_time: q.last_tx_time_echo,
                    hints: q.hints_pending,
                    acked_bytes: q.acked_bytes_pending,
                },
            );
            ctx.metrics.acks_sent += 1;
            ctx.tx(ack);
            q.acks_pending = 0;
            q.acked_bytes_pending = 0;
            q.hints_pending = NetHints::default();
        }

        // normal completion: the explicitly-marked final fragment arrived
        // (even if earlier ones were lost — §3.1.2)
        if complete {
            Self::finalize_through(ctx, q, hdr.wqe_seq + 1, sw_cost, false);
        }
    }

    /// Arm the head recv WQE's deadline if it is now "active" (its turn in
    /// the sequential message order) and not yet armed.
    fn arm_head_recv(ctx: &mut NicCtx, q: &mut QpState) {
        if q.active.is_some() {
            return;
        }
        if let Some((gen, timeout, armed)) = q.recv_meta.front_mut() {
            if !*armed {
                *armed = true;
                ctx.set_timer(*timeout, timer_id(q.qp.qpn, TIMER_MSG_DEADLINE, *gen));
            }
        }
    }

    /// Finalize the active message and any wholly-lost predecessors so that
    /// `expected_wqe_seq` becomes `upto`. `preempt` marks finalization
    /// triggered by a newer message's arrival.
    fn finalize_through(
        ctx: &mut NicCtx,
        q: &mut QpState,
        upto: u32,
        sw_cost: SimTime,
        preempt: bool,
    ) {
        while q.expected_wqe_seq < upto {
            let seq = q.expected_wqe_seq;
            q.expected_wqe_seq += 1;
            let finished = match q.active.take() {
                Some(a) if a.wqe_seq == seq => Some(a),
                other => {
                    q.active = other;
                    None
                }
            };
            match finished {
                Some(a) => {
                    // the message's deadline timer (armed at activation or
                    // head-of-queue) is obsolete once it finalizes
                    ctx.cancel_timer(timer_id(
                        q.qp.qpn,
                        TIMER_MSG_DEADLINE,
                        a.deadline_gen,
                    ));
                    let full = a.bytes >= a.msg_len;
                    if full {
                        ctx.metrics.full_completions += 1;
                    } else {
                        ctx.metrics.partial_completions += 1;
                    }
                    if preempt {
                        ctx.metrics.preemptions += 1;
                    }
                    if a.wr_id.is_some() || a.imm.is_some() {
                        ctx.push_cqe(Cqe {
                            wr_id: a.wr_id.unwrap_or(0),
                            qpn: q.qp.qpn,
                            status: if full {
                                CqStatus::Success
                            } else {
                                CqStatus::Partial
                            },
                            bytes: a.bytes,
                            expected_bytes: a.msg_len,
                            imm: a.imm,
                            time: ctx.time + sw_cost,
                            is_recv: true,
                            // the NIC's placement map rides the completion
                            loss: Some(a.loss),
                        });
                    }
                }
                None => {
                    // message wholly lost (no fragment ever arrived): consume
                    // its recv WQE with zero bytes if two-sided, and zero its
                    // landing zone (missing data reads as zeros)
                    if let Some(w) = q.recv_wqes.pop_front() {
                        if let Some((gen, _, armed)) = q.recv_meta.pop_front() {
                            if armed {
                                ctx.cancel_timer(timer_id(
                                    q.qp.qpn,
                                    TIMER_MSG_DEADLINE,
                                    gen,
                                ));
                            }
                        }
                        q.next_recv_seq += 1;
                        let s = w.sges[0];
                        ctx.mem.zero(s.mr, s.offset, s.len);
                        ctx.metrics.partial_completions += 1;
                        ctx.push_cqe(Cqe {
                            wr_id: w.wr_id,
                            qpn: q.qp.qpn,
                            status: CqStatus::Partial,
                            bytes: 0,
                            expected_bytes: w.total_len(),
                            imm: None,
                            time: ctx.time + sw_cost,
                            is_recv: true,
                            loss: Some(LossMap::new(w.total_len())),
                        });
                    }
                }
            }
        }
        // the next pending recv WQE is now active: start its slice
        Self::arm_head_recv(ctx, q);
    }

    fn on_msg_deadline(&mut self, ctx: &mut NicCtx, qpn: Qpn, gen: u32) {
        let sw_cost = self.sw_cost();
        let Some(q) = self.qps.get_mut(&qpn) else { return };
        // case 1: the active message's deadline expired before full
        // delivery — finalize with partial progress; the NIC reports the
        // byte counter (§3.1.2)
        if let Some(active) = &q.active {
            if active.deadline_gen == gen {
                let seq = active.wqe_seq;
                Self::finalize_through(ctx, q, seq + 1, sw_cost, false);
                return;
            }
        }
        // case 2: the head recv WQE's slice expired with no fragment ever
        // arriving — finalize it as wholly lost; the next WQE's slice
        // starts (armed inside finalize_through)
        if q.active.is_none() {
            if let Some((g, _, armed)) = q.recv_meta.front() {
                if *g == gen && *armed {
                    let upto = q.expected_wqe_seq + 1;
                    Self::finalize_through(ctx, q, upto, sw_cost, false);
                }
            }
        }
        // otherwise: stale timer for a completed message — ignore
    }

    fn on_send_deadline(&mut self, ctx: &mut NicCtx, qpn: Qpn, gen: u32) {
        let sw_cost = self.sw_cost();
        let Some(q) = self.qps.get_mut(&qpn) else { return };
        let seq = gen; // generation == wqe_seq & 0xffffff
        let Some(m) = q.send_msgs.get(&seq) else { return };
        if m.deadline_gen != gen {
            return;
        }
        // CC starvation / link dead: complete the send WQE with partial
        // progress and drop its unsent fragments
        let m = q.send_msgs.remove(&seq).unwrap();
        q.out.retain(|f| f.wqe_seq != seq);
        ctx.metrics.partial_completions += 1;
        ctx.push_cqe(Cqe {
            wr_id: m.wr_id,
            qpn,
            status: CqStatus::Partial,
            bytes: m.sent_bytes,
            expected_bytes: m.msg_len,
            imm: None,
            time: ctx.time + sw_cost,
            is_recv: false,
            loss: None,
        });
    }

    // ---- receiver-side credit grants (CC plane paces them) ---------------------

    fn on_credit_timer(&mut self, ctx: &mut NicCtx, qpn: Qpn) {
        let chunk = self.cfg.mtu * 4;
        let node = self.node;
        let Some((peer_node, peer_qpn)) = self
            .qps
            .get(&qpn)
            .map(|q| (q.qp.peer_node, q.qp.peer_qpn))
        else {
            return;
        };
        if let Some((bytes, next)) = self.cc.grant_fired(qpn, chunk) {
            ctx.tx(Packet::credit(node, peer_node, peer_qpn, bytes));
            if let Some(gap) = next {
                ctx.set_timer(gap, timer_id(qpn, TIMER_CREDIT, 0));
            }
        }
    }
}

impl Transport for Optinic {
    fn name(&self) -> &'static str {
        if self.hw {
            "OptiNIC (HW)"
        } else {
            "OptiNIC"
        }
    }

    fn create_qp(&mut self, qp: Qp) {
        self.cc.register_qp(qp.qpn);
        self.qps.insert(
            qp.qpn,
            QpState {
                qp,
                out: VecDeque::new(),
                send_msgs: BTreeMap::new(),
                next_wqe_seq: 0,
                expected_wqe_seq: 0,
                active: None,
                recv_wqes: VecDeque::new(),
                recv_meta: VecDeque::new(),
                next_recv_seq: 0,
                deadline_gen: 0,
                acks_pending: 0,
                acked_bytes_pending: 0,
                hints_pending: NetHints::default(),
                last_tx_time_echo: 0,
            },
        );
    }

    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.ring_doorbell(ctx.time, qpn);
        self.admit_send(ctx, qpn, wqe);
        self.pump(ctx, qpn);
    }

    /// Doorbell-batched posting: one doorbell charge and one pump per
    /// touched QP, however many WQEs ride the batch.
    fn post_send_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        let touched = crate::transport::batch_qpns(&batch);
        for &qpn in &touched {
            self.ring_doorbell(ctx.time, qpn);
        }
        for (qpn, wqe) in batch {
            self.admit_send(ctx, qpn, wqe);
        }
        for &qpn in &touched {
            self.pump(ctx, qpn);
        }
    }

    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        let timeout = wqe.timeout.unwrap_or(self.cfg.default_msg_timeout_ns);
        let q = self.qps.get_mut(&qpn).expect("unknown QP");
        // per-WQE deadline timer armed at post time (§3.1.2): bounds the
        // WQE even if not a single fragment ever arrives
        q.deadline_gen += 1;
        let gen = q.deadline_gen;
        q.recv_meta.push_back((gen, timeout, false));
        q.recv_wqes.push_back(wqe);
        // arm immediately only if this WQE is already "active" (head of
        // the sequential message order with nothing in flight before it)
        Self::arm_head_recv(ctx, q);
    }

    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet) {
        match pkt.kind {
            PktKind::Data(hdr) => self.on_data(ctx, pkt.src, hdr),
            PktKind::Ack(hdr) => {
                let qpn = hdr.dst_qpn;
                // decompose the feedback into the CC signal vocabulary
                let rtt = ctx.time.saturating_sub(hdr.echo_tx_time);
                self.cc.on_ack(
                    ctx.metrics,
                    qpn,
                    ctx.time,
                    Some(rtt),
                    hdr.acked_bytes,
                    &hdr.hints,
                );
                self.pump(ctx, qpn);
            }
            PktKind::Cnp { dst_qpn } => {
                self.cc.on_cnp(ctx.metrics, dst_qpn, ctx.time);
            }
            PktKind::Credit { dst_qpn, bytes } => {
                self.cc.on_credit(ctx.metrics, dst_qpn, ctx.time, bytes);
                self.pump(ctx, dst_qpn);
            }
            PktKind::PullReq { dst_qpn, bytes } => {
                // book the demand; first demand arms the grant timer
                // (fires immediately, then self-paces at the pull rate)
                if self.cc.on_pull_req(dst_qpn, bytes) {
                    ctx.set_timer(1, timer_id(dst_qpn, TIMER_CREDIT, 0));
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut NicCtx, id: u64) {
        let (qpn, kind, gen) = timer_parts(id);
        match kind {
            TIMER_PACE => {
                self.cc.pace_fired(qpn);
                self.pump(ctx, qpn);
            }
            TIMER_MSG_DEADLINE => self.on_msg_deadline(ctx, qpn, gen),
            TIMER_SEND_DEADLINE => self.on_send_deadline(ctx, qpn, gen),
            TIMER_CREDIT => self.on_credit_timer(ctx, qpn),
            _ => {}
        }
    }

    fn features(&self) -> FeatureMatrix {
        FeatureMatrix {
            reliability: "Best Effort",
            reordering: "Offset Based",
            congestion_control: "Hardware",
            pfc_required: false,
            target: "ML Collectives",
            key_focus: "+Tail optimality",
        }
    }

    fn cc_kind(&self) -> CcKind {
        self.cc.kind()
    }

    fn qp_state_bytes(&self) -> usize {
        crate::hw::qp_state::breakdown(crate::transport::TransportKind::Optinic).total()
    }

    /// OptiNIC's fault story (§2.4): the corruptible state is tiny and
    /// every field self-heals — a flipped `expected_wqe_seq` is resynced by
    /// the next message's preemption rule; a corrupted byte counter only
    /// mis-reports partial progress; a flipped deadline fires early (partial
    /// CQE) or late (bounded by the next preemption). No stalls.
    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String> {
        let keys: Vec<Qpn> = self.qps.keys().copied().collect();
        if keys.is_empty() {
            return None;
        }
        let qpn = *rng.choose(&keys);
        let q = self.qps.get_mut(&qpn).unwrap();
        self.faults_injected += 1;
        match rng.below(3) {
            0 => {
                q.expected_wqe_seq ^= 1 << rng.below(8);
                Some(format!(
                    "qp{qpn}: expected_wqe_seq bit-flip (self-heals via preemption)"
                ))
            }
            1 => {
                if let Some(a) = &mut q.active {
                    a.bytes ^= 1 << rng.below(10);
                    Some(format!("qp{qpn}: byte counter bit-flip (report-only)"))
                } else {
                    None
                }
            }
            _ => {
                // CC rate register corruption: recovers through normal CC
                // dynamics on subsequent feedback
                self.cc.corrupt_pacer(qpn);
                Some(format!("qp{qpn}: pacer register flip (CC re-converges)"))
            }
        }
    }

    fn stalled_qps(&self) -> usize {
        0 // best-effort forward progress: nothing waits forever
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_is_small() {
        let fab = crate::net::FabricCfg::cloudlab(2);
        let t = Optinic::new(0, TransportCfg::from_fabric(&fab), true);
        assert_eq!(t.qp_state_bytes(), 52);
    }

    #[test]
    fn names_distinguish_hw() {
        let fab = crate::net::FabricCfg::cloudlab(2);
        let cfg = TransportCfg::from_fabric(&fab);
        assert_eq!(Optinic::new(0, cfg.clone(), false).name(), "OptiNIC");
        assert_eq!(Optinic::new(0, cfg, true).name(), "OptiNIC (HW)");
    }
}
