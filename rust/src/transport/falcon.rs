//! Falcon (Singhvi et al., SIGCOMM'25): a reliable low-latency hardware
//! transport.
//!
//! Embraces NIC complexity: hardware selective repeat with fast
//! retransmission (aggressive dup threshold), delay-based congestion
//! control (Swift lineage), and hardware multipath — packets are sprayed
//! across paths and re-sequenced in the NIC. Strong under loss, but the
//! added state (350 B/QP) raises fault exposure (Table 5 MTBF).

use crate::net::Packet;
use crate::sim::cluster::NicCtx;
use crate::transport::reliable::{RelMode, Reliable, ReliableCfg};
use crate::transport::{FeatureMatrix, Transport, TransportCfg};
use crate::verbs::{NodeId, Qp, Qpn, Wqe};

pub struct Falcon {
    inner: Reliable,
}

impl Falcon {
    pub fn new(node: NodeId, mut cfg: TransportCfg) -> Falcon {
        // Falcon integrates delay-based CC in hardware. Its multipath
        // spraying adds per-packet path skew that the real NIC's per-path
        // RTT tracking filters out; our single CC instance instead widens
        // its delay target to cover the spray jitter so reordering skew is
        // not misread as congestion. Swift is the paper DEFAULT only: an
        // explicit experiment choice (`cc_forced`, CC ablations/sweeps)
        // must never be silently overwritten — and the Swift-specific
        // delay-budget widening below must not distort a forced
        // algorithm's parameters either, or Falcon grid cells stop being
        // comparable to the same CC on other transports.
        if !cfg.cc_forced {
            cfg.cc = crate::cc::CcKind::Swift;
            // provision the delay budget for multi-tenant fabrics: ambient
            // (non-Falcon) traffic sustains tens of µs of standing queue
            // that a datacenter-tuned target would misread as self-induced
            // congestion
            cfg.base_rtt_ns = cfg.base_rtt_ns * 2 + 64_000;
        }
        Falcon {
            inner: Reliable::new(
                node,
                cfg,
                ReliableCfg {
                    mode: RelMode::SelRepeat,
                    sw_datapath: false,
                    spray: true, // hardware multipath
                    // spray jitter reorders up to ~10 packets at 25 GbE —
                    // the resequencing window must exceed it or every
                    // reordering is misdeclared a loss
                    dup_threshold: 32,
                },
            ),
        }
    }
}

impl Transport for Falcon {
    fn name(&self) -> &'static str {
        "Falcon"
    }

    fn create_qp(&mut self, qp: Qp) {
        self.inner.create_qp_impl(qp);
    }

    fn post_send(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_send_impl(ctx, qpn, wqe);
    }

    fn post_send_batch(&mut self, ctx: &mut NicCtx, batch: Vec<(Qpn, Wqe)>) {
        self.inner.post_send_batch_impl(ctx, batch);
    }

    fn post_recv(&mut self, ctx: &mut NicCtx, qpn: Qpn, wqe: Wqe) {
        self.inner.post_recv_impl(ctx, qpn, wqe);
    }

    fn on_packet(&mut self, ctx: &mut NicCtx, pkt: Packet) {
        self.inner.on_packet_impl(ctx, pkt);
    }

    fn on_timer(&mut self, ctx: &mut NicCtx, timer_id: u64) {
        self.inner.on_timer_impl(ctx, timer_id);
    }

    fn features(&self) -> FeatureMatrix {
        FeatureMatrix {
            reliability: "Selective Repeat (HW)",
            reordering: "Buffered in NIC",
            congestion_control: "Hardware",
            pfc_required: false,
            target: "RDMA + ML + HPC",
            key_focus: "+Programmable CC",
        }
    }

    fn cc_kind(&self) -> crate::cc::CcKind {
        self.inner.cc_kind()
    }

    fn qp_state_bytes(&self) -> usize {
        crate::hw::qp_state::breakdown(crate::transport::TransportKind::Falcon).total()
    }

    fn inject_fault(&mut self, rng: &mut crate::util::prng::Pcg64) -> Option<String> {
        self.inner.inject_fault_impl(rng)
    }

    fn stalled_qps(&self) -> usize {
        self.inner.stalled_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::CcKind;
    use crate::transport::Transport;

    /// Regression for the silent CC overwrite: Falcon defaults to Swift,
    /// but an explicit user choice (`cc_forced`) must win.
    #[test]
    fn default_is_swift_but_forced_cc_wins() {
        let fab = crate::net::FabricCfg::cloudlab(2);
        let cfg = TransportCfg::from_fabric(&fab);
        // paper default applies when the user expressed no preference
        assert_eq!(Falcon::new(0, cfg.clone()).cc_kind(), CcKind::Swift);
        // an explicit ablation choice survives construction
        for forced in CcKind::ALL {
            let mut c = cfg.clone();
            c.cc = forced;
            c.cc_forced = true;
            assert_eq!(
                Falcon::new(0, c).cc_kind(),
                forced,
                "cc_forced={forced:?} must not be overwritten"
            );
        }
    }
}
