//! Registered-memory model. All nodes' memory regions live in one global
//! pool so the simulated DMA engine can copy sender-region → receiver-region
//! directly (zero-copy with respect to the packet objects, exactly like real
//! RDMA where the NIC DMAs between pinned buffers without staging).
//!
//! Memory windows: each region carries an `rkey` generation; bumping it
//! revokes remote access — this is the MW-based late-WRITE fence the RoCE/UC
//! software realization of OptiNIC uses (§3.3).

use crate::verbs::NodeId;

/// Memory-region handle (index into the global pool).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MrId(pub u32);

#[derive(Clone, Debug)]
struct Region {
    node: NodeId,
    bytes: Vec<u8>,
    rkey: u32,
}

/// Global registered-memory pool. `Clone` exists for the partitioned
/// engine: each partition runs against its own replica (registered
/// pre-run, inputs loaded), cross-partition data packets carry payload
/// refresh spans, and the post-run merge copies every region back from
/// its owning node's partition.
#[derive(Clone, Debug, Default)]
pub struct MemPool {
    regions: Vec<Region>,
}

impl MemPool {
    pub fn new() -> Self {
        MemPool::default()
    }

    /// Register a zeroed region of `len` bytes on `node`.
    pub fn register(&mut self, node: NodeId, len: usize) -> MrId {
        let id = MrId(self.regions.len() as u32);
        self.regions.push(Region {
            node,
            bytes: vec![0u8; len],
            rkey: 1,
        });
        id
    }

    /// Register a region initialized from `data`.
    pub fn register_with(&mut self, node: NodeId, data: Vec<u8>) -> MrId {
        let id = MrId(self.regions.len() as u32);
        self.regions.push(Region {
            node,
            bytes: data,
            rkey: 1,
        });
        id
    }

    pub fn len(&self, mr: MrId) -> usize {
        self.regions[mr.0 as usize].bytes.len()
    }

    pub fn node_of(&self, mr: MrId) -> NodeId {
        self.regions[mr.0 as usize].node
    }

    pub fn rkey(&self, mr: MrId) -> u32 {
        self.regions[mr.0 as usize].rkey
    }

    /// Revoke remote access by bumping the rkey (memory-window semantics).
    /// In-flight packets carrying the old rkey will fail placement.
    pub fn revoke(&mut self, mr: MrId) -> u32 {
        let r = &mut self.regions[mr.0 as usize];
        r.rkey = r.rkey.wrapping_add(1);
        r.rkey
    }

    pub fn read(&self, mr: MrId, offset: usize, len: usize) -> &[u8] {
        &self.regions[mr.0 as usize].bytes[offset..offset + len]
    }

    /// Overwrite region `mr` (bytes + rkey) from another pool's replica.
    /// Post-run merge of the partitioned engine: every region is adopted
    /// from the partition that owns its node, which executed all writes
    /// (local app writes and remote placements) against that replica.
    pub fn adopt_region(&mut self, other: &MemPool, mr: MrId) {
        let src = &other.regions[mr.0 as usize];
        let dst = &mut self.regions[mr.0 as usize];
        dst.bytes.clone_from(&src.bytes);
        dst.rkey = src.rkey;
    }

    pub fn write(&mut self, mr: MrId, offset: usize, data: &[u8]) {
        self.regions[mr.0 as usize].bytes[offset..offset + data.len()]
            .copy_from_slice(data);
    }

    pub fn fill(&mut self, mr: MrId, byte: u8) {
        self.regions[mr.0 as usize].bytes.fill(byte);
    }

    /// Zero a byte range (placement semantics: lost spans read as zeros).
    pub fn zero(&mut self, mr: MrId, offset: usize, len: usize) {
        self.regions[mr.0 as usize].bytes[offset..offset + len].fill(0);
    }

    /// DMA copy between two regions (`src` ≠ `dst`), the simulated
    /// placement operation. Checks the rkey if `rkey` is `Some` and returns
    /// false (no write) on mismatch — a revoked memory window.
    pub fn dma_copy(
        &mut self,
        src: MrId,
        src_off: usize,
        dst: MrId,
        dst_off: usize,
        len: usize,
        rkey: Option<u32>,
    ) -> bool {
        if src == dst {
            // same-region copies occur in loopback transports
            let r = &mut self.regions[src.0 as usize];
            if let Some(k) = rkey {
                if k != r.rkey {
                    return false;
                }
            }
            r.bytes.copy_within(src_off..src_off + len, dst_off);
            return true;
        }
        let (a, b) = two_mut(&mut self.regions, src.0 as usize, dst.0 as usize);
        if let Some(k) = rkey {
            if k != b.rkey {
                return false;
            }
        }
        b.bytes[dst_off..dst_off + len].copy_from_slice(&a.bytes[src_off..src_off + len]);
        true
    }

    /// View a region as f32 values (len must be 4-aligned).
    pub fn as_f32(&self, mr: MrId) -> Vec<f32> {
        let bytes = &self.regions[mr.0 as usize].bytes;
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Overwrite a region from f32 values.
    pub fn write_f32(&mut self, mr: MrId, offset_elems: usize, values: &[f32]) {
        let bytes = &mut self.regions[mr.0 as usize].bytes;
        for (i, v) in values.iter().enumerate() {
            let off = (offset_elems + i) * 4;
            bytes[off..off + 4].copy_from_slice(&v.to_le_bytes());
        }
    }

    /// Read a range as f32.
    pub fn read_f32(&self, mr: MrId, offset_elems: usize, count: usize) -> Vec<f32> {
        let bytes = &self.regions[mr.0 as usize].bytes;
        (0..count)
            .map(|i| {
                let off = (offset_elems + i) * 4;
                f32::from_le_bytes([
                    bytes[off],
                    bytes[off + 1],
                    bytes[off + 2],
                    bytes[off + 3],
                ])
            })
            .collect()
    }

    /// In-place f32 accumulate: dst[i] += src[i] (reduction primitive).
    pub fn accumulate_f32(&mut self, src: MrId, dst: MrId, elems: usize) {
        assert_ne!(src, dst);
        let (a, b) = two_mut(&mut self.regions, src.0 as usize, dst.0 as usize);
        for i in 0..elems {
            let off = i * 4;
            let x = f32::from_le_bytes([
                a.bytes[off],
                a.bytes[off + 1],
                a.bytes[off + 2],
                a.bytes[off + 3],
            ]);
            let y = f32::from_le_bytes([
                b.bytes[off],
                b.bytes[off + 1],
                b.bytes[off + 2],
                b.bytes[off + 3],
            ]);
            b.bytes[off..off + 4].copy_from_slice(&(x + y).to_le_bytes());
        }
    }

    pub fn region_count(&self) -> usize {
        self.regions.len()
    }
}

/// Disjoint mutable references to two different indices.
fn two_mut<T>(xs: &mut [T], i: usize, j: usize) -> (&mut T, &mut T) {
    assert_ne!(i, j);
    if i < j {
        let (lo, hi) = xs.split_at_mut(j);
        (&mut lo[i], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(i);
        (&mut hi[0], &mut lo[j])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_rw() {
        let mut pool = MemPool::new();
        let mr = pool.register(0, 16);
        assert_eq!(pool.len(mr), 16);
        assert_eq!(pool.node_of(mr), 0);
        pool.write(mr, 4, &[1, 2, 3]);
        assert_eq!(pool.read(mr, 4, 3), &[1, 2, 3]);
        assert_eq!(pool.read(mr, 0, 1), &[0]);
    }

    #[test]
    fn dma_copy_between_nodes() {
        let mut pool = MemPool::new();
        let a = pool.register_with(0, vec![9u8; 8]);
        let b = pool.register(1, 8);
        assert!(pool.dma_copy(a, 0, b, 4, 4, None));
        assert_eq!(pool.read(b, 0, 8), &[0, 0, 0, 0, 9, 9, 9, 9]);
    }

    #[test]
    fn rkey_revocation_blocks_late_writes() {
        let mut pool = MemPool::new();
        let a = pool.register_with(0, vec![7u8; 4]);
        let b = pool.register(1, 4);
        let old_key = pool.rkey(b);
        let new_key = pool.revoke(b);
        assert_ne!(old_key, new_key);
        // late WRITE with stale rkey is rejected
        assert!(!pool.dma_copy(a, 0, b, 0, 4, Some(old_key)));
        assert_eq!(pool.read(b, 0, 4), &[0, 0, 0, 0]);
        // fresh rkey succeeds
        assert!(pool.dma_copy(a, 0, b, 0, 4, Some(new_key)));
        assert_eq!(pool.read(b, 0, 4), &[7, 7, 7, 7]);
    }

    #[test]
    fn f32_views() {
        let mut pool = MemPool::new();
        let mr = pool.register(0, 12);
        pool.write_f32(mr, 0, &[1.5, -2.0, 3.25]);
        assert_eq!(pool.as_f32(mr), vec![1.5, -2.0, 3.25]);
        assert_eq!(pool.read_f32(mr, 1, 2), vec![-2.0, 3.25]);
    }

    #[test]
    fn accumulate() {
        let mut pool = MemPool::new();
        let a = pool.register(0, 8);
        let b = pool.register(1, 8);
        pool.write_f32(a, 0, &[1.0, 2.0]);
        pool.write_f32(b, 0, &[10.0, 20.0]);
        pool.accumulate_f32(a, b, 2);
        assert_eq!(pool.as_f32(b), vec![11.0, 22.0]);
        assert_eq!(pool.as_f32(a), vec![1.0, 2.0]);
    }

    #[test]
    fn two_mut_disjoint() {
        let mut v = vec![1, 2, 3];
        let (a, b) = two_mut(&mut v, 2, 0);
        *a += 10;
        *b += 100;
        assert_eq!(v, vec![101, 2, 13]);
    }
}
