//! Verbs v2 — the RDMA programming model shared by every transport.
//!
//! The app-facing surface is *loss-aware and batched*:
//! * applications receive typed [`CqEvent`]s (not raw CQEs): `SendDone`,
//!   `RecvDone { loss_map, .. }`, `TimeoutFired`, `QpError`. OptiNIC's
//!   partial-delivery semantics (§3.1.2 bounded completion) are first-class
//!   data — a [`LossMap`] of byte intervals that actually arrived;
//! * work is posted through typed [`QpHandle`]s with doorbell-batched
//!   `post_send_batch` / `post_recv_batch` (one doorbell per batch instead
//!   of one per WQE — the host-side overhead UCCL-style software
//!   transports show dominating at scale);
//! * a per-node shared receive queue ([`Srq`]) feeds any QP whose own
//!   receive queue is empty, so fan-in patterns need not provision one
//!   RQ WQE per peer;
//! * the engine drains completions through the non-allocating
//!   [`CompletionQueue::poll_into`] instead of a per-poll `Vec`.
//!
//! The old [`Cqe`] remains *only* as the internal wire struct transports
//! push; it is converted to a [`CqEvent`] at the completion queue boundary
//! and never reaches application code. See `docs/VERBS_V2.md` for the
//! migration table.

pub mod mem;

pub use mem::{MemPool, MrId};

use crate::sim::SimTime;

use std::collections::VecDeque;

/// Node (rank) identifier within a simulated cluster.
pub type NodeId = usize;

/// Queue-pair number, unique per node.
pub type Qpn = u32;

/// Work-request identifier chosen by the application.
pub type WrId = u64;

/// Typed handle to the local end of a connected queue pair. Returned by
/// `Cluster::connect`; the only way applications address QPs in verbs v2
/// (raw [`Qpn`]s stay internal to the transport engines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QpHandle {
    pub qpn: Qpn,
    /// The remote node this QP is connected to.
    pub peer: NodeId,
}

impl QpHandle {
    /// Placeholder handle (e.g. the diagonal of a full-mesh table).
    /// Posting on it is a logic error the transport will catch.
    pub fn null() -> QpHandle {
        QpHandle {
            qpn: 0,
            peer: NodeId::MAX,
        }
    }
}

/// RDMA verb kinds. Timeout ownership per §3.1.2: SEND/RECV both sides,
/// WRITE sender only, WRITE_WITH_IMM both sides, READ requester (deadline
/// piggybacked to the responder).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verb {
    Send,
    Recv,
    Write,
    WriteWithImm,
    Read,
}

/// A scatter–gather entry: a contiguous slice of a registered memory region.
/// OptiNIC's stride-interleaved packets are built from SGE lists (§3.2b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sge {
    pub mr: MrId,
    pub offset: usize,
    pub len: usize,
}

/// Remote buffer description for one-sided verbs (the RETH contents:
/// virtual address ≈ (mr, offset), rkey for protection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RemoteBuf {
    pub mr: MrId,
    pub offset: usize,
    pub rkey: u32,
}

/// A work request posted to a QP's send or receive queue (or the SRQ).
#[derive(Clone, Debug)]
pub struct Wqe {
    pub wr_id: WrId,
    pub verb: Verb,
    /// Local gather list (data source for sends/writes, sink for recvs).
    pub sges: Vec<Sge>,
    /// Remote buffer for one-sided verbs.
    pub remote: Option<RemoteBuf>,
    /// Immediate value (WRITE_WITH_IMM / SEND with imm).
    pub imm: Option<u32>,
    /// Bounded-completion deadline (OptiNIC §3.1.2). `None` = wait forever
    /// (classic reliable semantics).
    pub timeout: Option<SimTime>,
    /// Stride parameter for interleaved placement (§3.2b); 1 = contiguous.
    pub stride: u16,
}

impl Wqe {
    pub fn total_len(&self) -> usize {
        self.sges.iter().map(|s| s.len).sum()
    }

    /// Builder: plain send of one contiguous region.
    pub fn send(wr_id: WrId, mr: MrId, offset: usize, len: usize) -> Wqe {
        Wqe {
            wr_id,
            verb: Verb::Send,
            sges: vec![Sge { mr, offset, len }],
            remote: None,
            imm: None,
            timeout: None,
            stride: 1,
        }
    }

    /// Builder: receive into one contiguous region.
    pub fn recv(wr_id: WrId, mr: MrId, offset: usize, len: usize) -> Wqe {
        Wqe {
            wr_id,
            verb: Verb::Recv,
            sges: vec![Sge { mr, offset, len }],
            remote: None,
            imm: None,
            timeout: None,
            stride: 1,
        }
    }

    /// Builder: one-sided write.
    pub fn write(
        wr_id: WrId,
        mr: MrId,
        offset: usize,
        len: usize,
        remote: RemoteBuf,
    ) -> Wqe {
        Wqe {
            wr_id,
            verb: Verb::Write,
            sges: vec![Sge { mr, offset, len }],
            remote: Some(remote),
            imm: None,
            timeout: None,
            stride: 1,
        }
    }

    pub fn with_timeout(mut self, deadline: SimTime) -> Wqe {
        self.timeout = Some(deadline);
        self
    }

    pub fn with_stride(mut self, stride: u16) -> Wqe {
        self.stride = stride.max(1);
        self
    }

    pub fn with_imm(mut self, imm: u32) -> Wqe {
        self.imm = Some(imm);
        self
    }
}

// ---------------------------------------------------------------------------
// Loss map
// ---------------------------------------------------------------------------

/// Byte-interval map of what actually arrived for one message. The NIC
/// maintains this alongside its per-WQE byte counter (§3.1.2); apps and
/// `recovery::scrub_missing` consume it directly instead of re-deriving
/// loss from buffer contents.
///
/// Intervals are kept sorted and coalesced; in-order fragment arrival
/// degenerates to a single interval (O(1) amortized recording).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LossMap {
    expected: usize,
    /// Sorted, non-overlapping received intervals `(start, len)`.
    recvd: Vec<(usize, usize)>,
}

impl LossMap {
    /// Empty map for a message of `expected` bytes (nothing arrived yet).
    pub fn new(expected: usize) -> LossMap {
        LossMap {
            expected,
            recvd: Vec::new(),
        }
    }

    /// Map describing a fully-delivered message.
    pub fn complete(expected: usize) -> LossMap {
        LossMap {
            expected,
            recvd: if expected == 0 {
                Vec::new()
            } else {
                vec![(0, expected)]
            },
        }
    }

    /// Record the placement of `len` bytes at message offset `offset`.
    pub fn record(&mut self, offset: usize, len: usize) {
        if len == 0 {
            return;
        }
        let (start, end) = (offset, offset + len);
        // fast path: append/extend at the tail (in-order arrival)
        if let Some(last) = self.recvd.last_mut() {
            let last_end = last.0 + last.1;
            if start >= last.0 {
                if start > last_end {
                    self.recvd.push((start, len));
                    return;
                }
                if end > last_end {
                    last.1 = end - last.0;
                }
                return;
            }
        } else {
            self.recvd.push((start, len));
            return;
        }
        // general path: insert and re-coalesce (rare: true reordering)
        let pos = self
            .recvd
            .partition_point(|&(s, _)| s < start);
        self.recvd.insert(pos, (start, len));
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.recvd.len());
        for &(s, l) in &self.recvd {
            match merged.last_mut() {
                Some(prev) if s <= prev.0 + prev.1 => {
                    let e = (s + l).max(prev.0 + prev.1);
                    prev.1 = e - prev.0;
                }
                _ => merged.push((s, l)),
            }
        }
        self.recvd = merged;
    }

    /// Total bytes the message was expected to carry.
    pub fn expected_bytes(&self) -> usize {
        self.expected
    }

    /// Bytes that actually arrived (within `[0, expected)`).
    pub fn delivered_bytes(&self) -> usize {
        self.recvd
            .iter()
            .map(|&(s, l)| l.min(self.expected.saturating_sub(s)))
            .sum()
    }

    /// True when every expected byte arrived.
    pub fn is_complete(&self) -> bool {
        self.delivered_bytes() >= self.expected
    }

    /// Fraction of the message delivered, in [0, 1].
    pub fn delivered_fraction(&self) -> f64 {
        if self.expected == 0 {
            1.0
        } else {
            self.delivered_bytes() as f64 / self.expected as f64
        }
    }

    /// Visit each missing span `(offset, len)` in ascending order without
    /// allocating.
    pub fn for_each_missing(&self, mut f: impl FnMut(usize, usize)) {
        let mut cursor = 0usize;
        for &(s, l) in &self.recvd {
            let s = s.min(self.expected);
            if s > cursor {
                f(cursor, s - cursor);
            }
            cursor = cursor.max((s + l).min(self.expected));
        }
        if cursor < self.expected {
            f(cursor, self.expected - cursor);
        }
    }

    /// Missing spans as a vector (convenience; prefer
    /// [`LossMap::for_each_missing`] on hot paths).
    pub fn missing(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        self.for_each_missing(|s, l| out.push((s, l)));
        out
    }

    /// Number of received intervals (diagnostics).
    pub fn interval_count(&self) -> usize {
        self.recvd.len()
    }
}

// ---------------------------------------------------------------------------
// Wire-level CQE (transport-internal) and the app-facing CqEvent
// ---------------------------------------------------------------------------

/// Completion status on the wire struct. `Partial` is OptiNIC's bounded
/// completion: the WQE's deadline expired (or a newer message preempted it)
/// with only `bytes` of the message placed (§3.1.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CqStatus {
    Success,
    /// Bounded completion fired before full delivery.
    Partial,
    /// Transport-level fatal error (e.g. retry exhausted on reliable QPs).
    Error,
    /// Receive-side flush (QP torn down).
    Flushed,
}

/// INTERNAL wire struct: what transport engines push into the completion
/// queue. Application code never sees this — the CQ converts it into a
/// typed [`CqEvent`] at push time.
#[derive(Clone, Debug)]
pub struct Cqe {
    pub wr_id: WrId,
    pub qpn: Qpn,
    pub status: CqStatus,
    /// Bytes actually placed/transmitted (the per-WQE byte counter the NIC
    /// maintains, §3.1.2).
    pub bytes: usize,
    /// Message length expected.
    pub expected_bytes: usize,
    pub imm: Option<u32>,
    /// Completion timestamp (simulated).
    pub time: SimTime,
    /// True for receive-side completions.
    pub is_recv: bool,
    /// Byte intervals placed, when the transport tracks placement
    /// (OptiNIC's offset-based receive path). `None` ⇒ synthesized as a
    /// single prefix interval at conversion time.
    pub loss: Option<LossMap>,
}

impl Cqe {
    /// Fraction of the message that arrived, in [0, 1].
    pub fn delivered_fraction(&self) -> f64 {
        if self.expected_bytes == 0 {
            1.0
        } else {
            self.bytes as f64 / self.expected_bytes as f64
        }
    }
}

/// Typed, loss-aware completion event — the only completion type
/// applications see in verbs v2.
#[derive(Clone, Debug)]
pub enum CqEvent {
    /// A send/write WQE finished transmitting all of its fragments.
    SendDone {
        wr_id: WrId,
        qpn: Qpn,
        bytes: usize,
        time: SimTime,
    },
    /// A receive-side completion with data. For best-effort transports the
    /// [`LossMap`] may have holes (bounded completion / preemption); for
    /// reliable transports it is always complete.
    RecvDone {
        wr_id: WrId,
        qpn: Qpn,
        delivered_bytes: usize,
        expected_bytes: usize,
        imm: Option<u32>,
        /// What actually arrived, in message-relative byte offsets.
        loss_map: LossMap,
        time: SimTime,
    },
    /// A WQE deadline expired with nothing delivered (receive side: the
    /// message was wholly lost) or before transmission finished (send
    /// side: CC starvation / dead link — `delivered_bytes` were sent).
    TimeoutFired {
        wr_id: WrId,
        qpn: Qpn,
        is_recv: bool,
        delivered_bytes: usize,
        expected_bytes: usize,
        time: SimTime,
    },
    /// Fatal transport error (retry exhausted, QP flushed).
    QpError {
        wr_id: WrId,
        qpn: Qpn,
        is_recv: bool,
        expected_bytes: usize,
        time: SimTime,
    },
}

impl CqEvent {
    /// Convert the internal wire struct pushed by a transport engine.
    pub fn from_wire(cqe: Cqe) -> CqEvent {
        let Cqe {
            wr_id,
            qpn,
            status,
            bytes,
            expected_bytes,
            imm,
            time,
            is_recv,
            loss,
        } = cqe;
        match (status, is_recv) {
            (CqStatus::Success, false) => CqEvent::SendDone {
                wr_id,
                qpn,
                bytes,
                time,
            },
            (CqStatus::Success, true) => CqEvent::RecvDone {
                wr_id,
                qpn,
                delivered_bytes: bytes,
                expected_bytes,
                imm,
                loss_map: loss.unwrap_or_else(|| LossMap::complete(expected_bytes)),
                time,
            },
            (CqStatus::Partial, true) if bytes > 0 => CqEvent::RecvDone {
                wr_id,
                qpn,
                delivered_bytes: bytes,
                expected_bytes,
                imm,
                loss_map: loss.unwrap_or_else(|| {
                    // transport without placement tracking: approximate the
                    // arrived bytes as a prefix
                    let mut m = LossMap::new(expected_bytes);
                    m.record(0, bytes);
                    m
                }),
                time,
            },
            (CqStatus::Partial, _) => CqEvent::TimeoutFired {
                wr_id,
                qpn,
                is_recv,
                delivered_bytes: bytes,
                expected_bytes,
                time,
            },
            (CqStatus::Error, _) | (CqStatus::Flushed, _) => CqEvent::QpError {
                wr_id,
                qpn,
                is_recv,
                expected_bytes,
                time,
            },
        }
    }

    pub fn wr_id(&self) -> WrId {
        match self {
            CqEvent::SendDone { wr_id, .. }
            | CqEvent::RecvDone { wr_id, .. }
            | CqEvent::TimeoutFired { wr_id, .. }
            | CqEvent::QpError { wr_id, .. } => *wr_id,
        }
    }

    pub fn qpn(&self) -> Qpn {
        match self {
            CqEvent::SendDone { qpn, .. }
            | CqEvent::RecvDone { qpn, .. }
            | CqEvent::TimeoutFired { qpn, .. }
            | CqEvent::QpError { qpn, .. } => *qpn,
        }
    }

    pub fn time(&self) -> SimTime {
        match self {
            CqEvent::SendDone { time, .. }
            | CqEvent::RecvDone { time, .. }
            | CqEvent::TimeoutFired { time, .. }
            | CqEvent::QpError { time, .. } => *time,
        }
    }

    pub fn is_recv(&self) -> bool {
        match self {
            CqEvent::SendDone { .. } => false,
            CqEvent::RecvDone { .. } => true,
            CqEvent::TimeoutFired { is_recv, .. } | CqEvent::QpError { is_recv, .. } => {
                *is_recv
            }
        }
    }
}

/// QP transport service type (Table 2 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QpType {
    /// Reliable Connected: reliability + ordering + CC.
    Rc,
    /// Unreliable Connected: ordering enforced, no reliability.
    Uc,
    /// Unreliable Datagram.
    Ud,
    /// OptiNIC eXpress Path: no reliability, no ordering, keeps connection
    /// state + offloaded packetization + CC.
    Xp,
}

/// A queue pair endpoint. Connection state (the `peer` fields) is what
/// distinguishes connected QP types from UD.
#[derive(Clone, Debug)]
pub struct Qp {
    pub qpn: Qpn,
    pub qp_type: QpType,
    pub peer_node: NodeId,
    pub peer_qpn: Qpn,
    /// MTU governs fragmentation (payload bytes per packet).
    pub mtu: usize,
}

// ---------------------------------------------------------------------------
// Completion queue and shared receive queue
// ---------------------------------------------------------------------------

/// Per-node completion queue: transports push wire CQEs, the engine drains
/// typed events through [`CompletionQueue::poll_into`] — no allocation on
/// the DES hot loop (the caller's scratch vector is reused across polls).
#[derive(Clone, Debug, Default)]
pub struct CompletionQueue {
    events: Vec<CqEvent>,
}

impl CompletionQueue {
    /// Push an internal wire CQE (transport engines).
    pub fn push_wire(&mut self, cqe: Cqe) {
        self.events.push(CqEvent::from_wire(cqe));
    }

    /// Push an already-typed event.
    pub fn push_event(&mut self, ev: CqEvent) {
        self.events.push(ev);
    }

    /// Move all pending events into `out` (appending, preserving order) and
    /// return how many were moved. The queue's internal buffer keeps its
    /// capacity, and `out` only grows when a burst exceeds its capacity —
    /// the steady state allocates nothing.
    pub fn poll_into(&mut self, out: &mut Vec<CqEvent>) -> usize {
        let n = self.events.len();
        out.append(&mut self.events);
        n
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Per-node shared receive queue (SRQ). Transports consume entries in FIFO
/// order for any incoming two-sided message on a QP whose own receive
/// queue is empty — classic verbs SRQ semantics: callers accept
/// arrival-order buffer assignment.
///
/// Deadline discipline: an SRQ entry is not bound to any QP's sequential
/// message order until consumed, so its `Wqe::timeout` is armed twice over:
/// the engine arms a *queue-level* deadline at post time (an entry still
/// waiting when it fires completes as `TimeoutFired` — a wholly-lost
/// message can never strand an SRQ-only receiver), and the transport arms
/// the per-message deadline at activation (first fragment) as usual.
#[derive(Debug, Default)]
pub struct Srq {
    entries: VecDeque<(u64, Wqe)>,
    next_id: u64,
    /// Total entries ever consumed (diagnostics / tests).
    pub consumed: u64,
}

impl Srq {
    /// Post one receive WQE to the shared queue; returns its entry id
    /// (used by the engine's queue-level deadline).
    pub fn post(&mut self, wqe: Wqe) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.entries.push_back((id, wqe));
        id
    }

    /// Pop the next entry (transport engines; bumps `consumed`).
    pub fn pop(&mut self) -> Option<Wqe> {
        let w = self.entries.pop_front();
        if w.is_some() {
            self.consumed += 1;
        }
        w.map(|(_, wqe)| wqe)
    }

    /// Remove a still-queued entry by id (queue-level deadline expiry).
    /// `None` if the entry was already consumed by an arriving message.
    pub fn remove(&mut self, id: u64) -> Option<Wqe> {
        let pos = self.entries.iter().position(|(i, _)| *i == id)?;
        self.entries.remove(pos).map(|(_, wqe)| wqe)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wqe_builders() {
        let w = Wqe::send(1, MrId(0), 0, 4096).with_timeout(1_000).with_stride(8);
        assert_eq!(w.total_len(), 4096);
        assert_eq!(w.timeout, Some(1_000));
        assert_eq!(w.stride, 8);
        assert_eq!(w.verb, Verb::Send);

        let r = Wqe::write(
            2,
            MrId(1),
            128,
            256,
            RemoteBuf {
                mr: MrId(9),
                offset: 64,
                rkey: 0xdead,
            },
        );
        assert_eq!(r.remote.unwrap().rkey, 0xdead);
    }

    #[test]
    fn stride_clamped_to_one() {
        let w = Wqe::send(1, MrId(0), 0, 16).with_stride(0);
        assert_eq!(w.stride, 1);
    }

    #[test]
    fn loss_map_in_order_coalesces() {
        let mut m = LossMap::new(3000);
        m.record(0, 1000);
        m.record(1000, 1000);
        m.record(2000, 1000);
        assert_eq!(m.interval_count(), 1);
        assert!(m.is_complete());
        assert_eq!(m.delivered_bytes(), 3000);
        assert!(m.missing().is_empty());
    }

    #[test]
    fn loss_map_holes_reported() {
        let mut m = LossMap::new(5000);
        m.record(0, 1000);
        m.record(2000, 1000); // [1000,2000) lost
        m.record(4000, 1000); // [3000,4000) lost
        assert_eq!(m.delivered_bytes(), 3000);
        assert!(!m.is_complete());
        assert_eq!(m.missing(), vec![(1000, 1000), (3000, 1000)]);
        assert!((m.delivered_fraction() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn loss_map_out_of_order_and_overlap() {
        let mut m = LossMap::new(4000);
        m.record(3000, 1000);
        m.record(0, 1000);
        m.record(500, 1000); // overlaps the first interval
        assert_eq!(m.delivered_bytes(), 2500);
        assert_eq!(m.missing(), vec![(1500, 1500)]);
        m.record(1500, 1500);
        assert!(m.is_complete());
        assert_eq!(m.interval_count(), 1);
    }

    #[test]
    fn loss_map_empty_message() {
        let m = LossMap::new(0);
        assert!(m.is_complete());
        assert_eq!(m.delivered_fraction(), 1.0);
        assert!(LossMap::complete(0).is_complete());
    }

    #[test]
    fn loss_map_wholly_lost() {
        let m = LossMap::new(1234);
        assert_eq!(m.delivered_bytes(), 0);
        assert_eq!(m.missing(), vec![(0, 1234)]);
    }

    fn wire(status: CqStatus, bytes: usize, is_recv: bool) -> Cqe {
        Cqe {
            wr_id: 7,
            qpn: 3,
            status,
            bytes,
            expected_bytes: 1000,
            imm: None,
            time: 42,
            is_recv,
            loss: None,
        }
    }

    #[test]
    fn wire_to_event_mapping() {
        match CqEvent::from_wire(wire(CqStatus::Success, 1000, false)) {
            CqEvent::SendDone { wr_id: 7, bytes: 1000, .. } => {}
            other => panic!("want SendDone, got {other:?}"),
        }
        match CqEvent::from_wire(wire(CqStatus::Success, 1000, true)) {
            CqEvent::RecvDone { loss_map, .. } => assert!(loss_map.is_complete()),
            other => panic!("want RecvDone, got {other:?}"),
        }
        // partial recv WITH data → RecvDone carrying holes
        match CqEvent::from_wire(wire(CqStatus::Partial, 750, true)) {
            CqEvent::RecvDone {
                delivered_bytes: 750,
                loss_map,
                ..
            } => assert!(!loss_map.is_complete()),
            other => panic!("want RecvDone, got {other:?}"),
        }
        // partial recv with NO data → TimeoutFired
        match CqEvent::from_wire(wire(CqStatus::Partial, 0, true)) {
            CqEvent::TimeoutFired { is_recv: true, .. } => {}
            other => panic!("want TimeoutFired, got {other:?}"),
        }
        // partial send → TimeoutFired (CC starvation bound)
        match CqEvent::from_wire(wire(CqStatus::Partial, 400, false)) {
            CqEvent::TimeoutFired {
                is_recv: false,
                delivered_bytes: 400,
                ..
            } => {}
            other => panic!("want TimeoutFired, got {other:?}"),
        }
        match CqEvent::from_wire(wire(CqStatus::Error, 0, false)) {
            CqEvent::QpError { .. } => {}
            other => panic!("want QpError, got {other:?}"),
        }
    }

    #[test]
    fn cq_poll_into_reuses_scratch() {
        let mut cq = CompletionQueue::default();
        assert!(cq.is_empty());
        cq.push_wire(wire(CqStatus::Success, 1000, false));
        cq.push_wire(wire(CqStatus::Success, 1000, true));
        assert_eq!(cq.len(), 2);
        let mut scratch: Vec<CqEvent> = Vec::with_capacity(8);
        let cap_before = scratch.capacity();
        assert_eq!(cq.poll_into(&mut scratch), 2);
        assert!(cq.is_empty());
        assert_eq!(scratch.len(), 2);
        assert_eq!(scratch[0].wr_id(), 7);
        assert_eq!(scratch.capacity(), cap_before, "no realloc for small bursts");
        scratch.clear();
        assert_eq!(cq.poll_into(&mut scratch), 0);
        assert!(scratch.is_empty());
    }

    #[test]
    fn srq_fifo_and_consumed_count() {
        let mut srq = Srq::default();
        assert!(srq.is_empty());
        let id1 = srq.post(Wqe::recv(1, MrId(0), 0, 64));
        let id2 = srq.post(Wqe::recv(2, MrId(0), 64, 64));
        assert_ne!(id1, id2);
        assert_eq!(srq.len(), 2);
        assert_eq!(srq.pop().unwrap().wr_id, 1);
        assert_eq!(srq.pop().unwrap().wr_id, 2);
        assert!(srq.pop().is_none());
        assert_eq!(srq.consumed, 2);
        // both entries were consumed: their ids are no longer removable
        assert!(srq.remove(id1).is_none());
    }

    #[test]
    fn srq_remove_by_id_skips_consumed() {
        let mut srq = Srq::default();
        let a = srq.post(Wqe::recv(1, MrId(0), 0, 64));
        let b = srq.post(Wqe::recv(2, MrId(0), 64, 64));
        // deadline fires for the SECOND entry while the first still waits
        let w = srq.remove(b).expect("entry b still queued");
        assert_eq!(w.wr_id, 2);
        assert_eq!(srq.len(), 1);
        // consuming proceeds FIFO over what remains
        assert_eq!(srq.pop().unwrap().wr_id, 1);
        assert!(srq.remove(a).is_none());
    }

    #[test]
    fn delivered_fraction() {
        let cqe = wire(CqStatus::Partial, 750, true);
        assert!((cqe.delivered_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn qp_handle_null() {
        let h = QpHandle::null();
        assert_eq!(h.qpn, 0);
        assert_ne!(h, QpHandle { qpn: 1, peer: 0 });
    }
}
