//! The RDMA programming model shared by every transport: queue pairs, work
//! queue entries, completion queue entries, memory regions, and
//! scatter–gather entries. This mirrors the IB verbs abstractions the paper
//! builds on (§3.1 INFO box) — transports differ in *how* they move bytes,
//! not in this interface.

pub mod mem;

pub use mem::{MemPool, MrId};

use crate::sim::SimTime;

/// Node (rank) identifier within a simulated cluster.
pub type NodeId = usize;

/// Queue-pair number, unique per node.
pub type Qpn = u32;

/// Work-request identifier chosen by the application.
pub type WrId = u64;

/// RDMA verb kinds. Timeout ownership per §3.1.2: SEND/RECV both sides,
/// WRITE sender only, WRITE_WITH_IMM both sides, READ requester (deadline
/// piggybacked to the responder).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verb {
    Send,
    Recv,
    Write,
    WriteWithImm,
    Read,
}

/// A scatter–gather entry: a contiguous slice of a registered memory region.
/// OptiNIC's stride-interleaved packets are built from SGE lists (§3.2b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sge {
    pub mr: MrId,
    pub offset: usize,
    pub len: usize,
}

/// Remote buffer description for one-sided verbs (the RETH contents:
/// virtual address ≈ (mr, offset), rkey for protection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RemoteBuf {
    pub mr: MrId,
    pub offset: usize,
    pub rkey: u32,
}

/// A work request posted to a QP's send or receive queue.
#[derive(Clone, Debug)]
pub struct Wqe {
    pub wr_id: WrId,
    pub verb: Verb,
    /// Local gather list (data source for sends/writes, sink for recvs).
    pub sges: Vec<Sge>,
    /// Remote buffer for one-sided verbs.
    pub remote: Option<RemoteBuf>,
    /// Immediate value (WRITE_WITH_IMM / SEND with imm).
    pub imm: Option<u32>,
    /// Bounded-completion deadline (OptiNIC §3.1.2). `None` = wait forever
    /// (classic reliable semantics).
    pub timeout: Option<SimTime>,
    /// Stride parameter for interleaved placement (§3.2b); 1 = contiguous.
    pub stride: u16,
}

impl Wqe {
    pub fn total_len(&self) -> usize {
        self.sges.iter().map(|s| s.len).sum()
    }

    /// Builder: plain send of one contiguous region.
    pub fn send(wr_id: WrId, mr: MrId, offset: usize, len: usize) -> Wqe {
        Wqe {
            wr_id,
            verb: Verb::Send,
            sges: vec![Sge { mr, offset, len }],
            remote: None,
            imm: None,
            timeout: None,
            stride: 1,
        }
    }

    /// Builder: receive into one contiguous region.
    pub fn recv(wr_id: WrId, mr: MrId, offset: usize, len: usize) -> Wqe {
        Wqe {
            wr_id,
            verb: Verb::Recv,
            sges: vec![Sge { mr, offset, len }],
            remote: None,
            imm: None,
            timeout: None,
            stride: 1,
        }
    }

    /// Builder: one-sided write.
    pub fn write(
        wr_id: WrId,
        mr: MrId,
        offset: usize,
        len: usize,
        remote: RemoteBuf,
    ) -> Wqe {
        Wqe {
            wr_id,
            verb: Verb::Write,
            sges: vec![Sge { mr, offset, len }],
            remote: Some(remote),
            imm: None,
            timeout: None,
            stride: 1,
        }
    }

    pub fn with_timeout(mut self, deadline: SimTime) -> Wqe {
        self.timeout = Some(deadline);
        self
    }

    pub fn with_stride(mut self, stride: u16) -> Wqe {
        self.stride = stride.max(1);
        self
    }

    pub fn with_imm(mut self, imm: u32) -> Wqe {
        self.imm = Some(imm);
        self
    }
}

/// Completion status. OptiNIC adds `Partial` — the WQE's deadline expired
/// with only `bytes` of the message placed (bounded completion, §3.1.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CqStatus {
    Success,
    /// Bounded completion fired before full delivery.
    Partial,
    /// Transport-level fatal error (e.g. retry exhausted on reliable QPs).
    Error,
    /// Receive-side flush (QP torn down).
    Flushed,
}

/// Completion queue entry.
#[derive(Clone, Debug)]
pub struct Cqe {
    pub wr_id: WrId,
    pub qpn: Qpn,
    pub status: CqStatus,
    /// Bytes actually placed/transmitted. For OptiNIC partial completions
    /// this is the per-WQE byte counter the NIC maintains (§3.1.2).
    pub bytes: usize,
    /// Message length expected (so callers can compute the loss fraction).
    pub expected_bytes: usize,
    pub imm: Option<u32>,
    /// Completion timestamp (simulated).
    pub time: SimTime,
    /// True for receive-side completions.
    pub is_recv: bool,
}

impl Cqe {
    /// Fraction of the message that arrived, in [0, 1].
    pub fn delivered_fraction(&self) -> f64 {
        if self.expected_bytes == 0 {
            1.0
        } else {
            self.bytes as f64 / self.expected_bytes as f64
        }
    }
}

/// QP transport service type (Table 2 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QpType {
    /// Reliable Connected: reliability + ordering + CC.
    Rc,
    /// Unreliable Connected: ordering enforced, no reliability.
    Uc,
    /// Unreliable Datagram.
    Ud,
    /// OptiNIC eXpress Path: no reliability, no ordering, keeps connection
    /// state + offloaded packetization + CC.
    Xp,
}

/// A queue pair endpoint. Connection state (the `peer` fields) is what
/// distinguishes connected QP types from UD.
#[derive(Clone, Debug)]
pub struct Qp {
    pub qpn: Qpn,
    pub qp_type: QpType,
    pub peer_node: NodeId,
    pub peer_qpn: Qpn,
    /// MTU governs fragmentation (payload bytes per packet).
    pub mtu: usize,
}

/// Per-node completion queue: transports push, the application drains.
#[derive(Clone, Debug, Default)]
pub struct CompletionQueue {
    entries: Vec<Cqe>,
}

impl CompletionQueue {
    pub fn push(&mut self, cqe: Cqe) {
        self.entries.push(cqe);
    }

    pub fn drain(&mut self) -> Vec<Cqe> {
        std::mem::take(&mut self.entries)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wqe_builders() {
        let w = Wqe::send(1, MrId(0), 0, 4096).with_timeout(1_000).with_stride(8);
        assert_eq!(w.total_len(), 4096);
        assert_eq!(w.timeout, Some(1_000));
        assert_eq!(w.stride, 8);
        assert_eq!(w.verb, Verb::Send);

        let r = Wqe::write(
            2,
            MrId(1),
            128,
            256,
            RemoteBuf {
                mr: MrId(9),
                offset: 64,
                rkey: 0xdead,
            },
        );
        assert_eq!(r.remote.unwrap().rkey, 0xdead);
    }

    #[test]
    fn stride_clamped_to_one() {
        let w = Wqe::send(1, MrId(0), 0, 16).with_stride(0);
        assert_eq!(w.stride, 1);
    }

    #[test]
    fn delivered_fraction() {
        let cqe = Cqe {
            wr_id: 0,
            qpn: 0,
            status: CqStatus::Partial,
            bytes: 750,
            expected_bytes: 1000,
            imm: None,
            time: 0,
            is_recv: true,
        };
        assert!((cqe.delivered_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn cq_drain() {
        let mut cq = CompletionQueue::default();
        assert!(cq.is_empty());
        cq.push(Cqe {
            wr_id: 7,
            qpn: 1,
            status: CqStatus::Success,
            bytes: 10,
            expected_bytes: 10,
            imm: None,
            time: 5,
            is_recv: false,
        });
        assert_eq!(cq.len(), 1);
        let drained = cq.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].wr_id, 7);
        assert!(cq.is_empty());
    }
}
