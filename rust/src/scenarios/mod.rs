//! Adversarial scenario catalog — declarative, seed-deterministic burst &
//! fault choreography (ROADMAP item 4; docs/SCENARIOS.md).
//!
//! A [`ScenarioCell`] composes choreographed adversities against any
//! topology × transport × CC cell and runs a collective workload through
//! them, reporting a resilience scoreboard: completions, stalled QPs,
//! bytes lost, fault accounting (scheduled vs injected), and recovery
//! time after the last network fault. Choreographies reuse existing
//! engine vocabulary rather than inventing new event types:
//!
//! * **Phase-boundary incast** — synchronized microbursts aimed at the
//!   instants `CollectiveKind::phase_boundaries` predicts every rank
//!   turns its traffic around ([`crate::sim::cluster::Cluster::schedule_incast`]).
//! * **Stragglers** — per-rank compute-delay injection via
//!   `ClusterCfg::compute_delays`.
//! * **Rolling spine faults** — staggered spine blackholes built from the
//!   `NetFault` vocabulary through `hw::fault::schedule_spine_failure`;
//!   cells whose fabric has no spine tier record the plan as skipped
//!   instead of aborting the sweep (`FaultPlanError`).
//! * **SEU barrage** — MTBF-drawn upsets via `hw::fault::schedule_faults`.
//! * **Perfect storm** — all of the above at once.
//!
//! Every cell is pure over its own `Cluster` (no host state, no RNG
//! outside the seeded engine), so scenario grids run through the PR 4
//! sweep harness with byte-identical results for any `--jobs` — pinned in
//! `rust/tests/determinism.rs`.

use crate::cc::CcKind;
use crate::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use crate::hw::fault;
use crate::net::FabricCfg;
use crate::sim::cluster::{Cluster, ClusterCfg};
use crate::sim::{SchedKind, SimTime, MS};
use crate::transport::TransportKind;
use crate::util::json::Json;

/// The catalog. `Baseline` runs the identical workload with no adversary
/// so per-scenario tail deltas have a denominator.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScenarioKind {
    Baseline,
    /// Synchronized incast microbursts at collective phase boundaries.
    PhaseIncast,
    /// One rank starts each iteration late (compute straggler).
    Straggler,
    /// Staggered spine blackholes with an all-spines-dark overlap window.
    RollingSpineFaults,
    /// MTBF-accelerated SEU upsets into live NIC state.
    SeuBarrage,
    /// Everything at once.
    PerfectStorm,
}

impl ScenarioKind {
    pub const ALL: [ScenarioKind; 6] = [
        ScenarioKind::Baseline,
        ScenarioKind::PhaseIncast,
        ScenarioKind::Straggler,
        ScenarioKind::RollingSpineFaults,
        ScenarioKind::SeuBarrage,
        ScenarioKind::PerfectStorm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::Baseline => "baseline",
            ScenarioKind::PhaseIncast => "phase-incast",
            ScenarioKind::Straggler => "straggler",
            ScenarioKind::RollingSpineFaults => "rolling-spine-faults",
            ScenarioKind::SeuBarrage => "seu-barrage",
            ScenarioKind::PerfectStorm => "perfect-storm",
        }
    }

    pub fn parse(s: &str) -> Option<ScenarioKind> {
        let s = s.to_ascii_lowercase();
        ScenarioKind::ALL
            .into_iter()
            .find(|k| k.name() == s || k.name().replace('-', "_") == s)
    }

    fn wants_incast(&self) -> bool {
        matches!(self, ScenarioKind::PhaseIncast | ScenarioKind::PerfectStorm)
    }

    fn wants_straggler(&self) -> bool {
        matches!(self, ScenarioKind::Straggler | ScenarioKind::PerfectStorm)
    }

    fn wants_spine_faults(&self) -> bool {
        matches!(
            self,
            ScenarioKind::RollingSpineFaults | ScenarioKind::PerfectStorm
        )
    }

    fn wants_seu(&self) -> bool {
        matches!(self, ScenarioKind::SeuBarrage | ScenarioKind::PerfectStorm)
    }
}

/// One scenario × transport × CC × topology cell — declared as data, run
/// by [`run_scenario_cell`] (the sweep-harness cell body).
#[derive(Clone, Debug)]
pub struct ScenarioCell {
    pub scenario: ScenarioKind,
    pub transport: TransportKind,
    /// Forced CC algorithm; `None` keeps the transport's paper default.
    pub cc: Option<CcKind>,
    pub leaf_spine: bool,
    pub nodes: usize,
    pub collective: CollectiveKind,
    pub elems: usize,
    pub iters: usize,
    pub seed: u64,
    pub bg_load: f64,
    pub scheduler: SchedKind,
    /// Per-iteration sim-time cap: a stalled cell is recorded, not hung.
    pub iter_cap_ns: SimTime,
    // ---- choreography knobs (defaults match docs/SCENARIOS.md) ----
    /// Bytes converging on one edge port per phase-boundary burst.
    pub burst_bytes: usize,
    /// Straggler compute delay (ns) injected into one rank.
    pub straggler_ns: SimTime,
    /// Spine blackhole length (ns); spine `s` goes dark at
    /// `0.2 ms + s × (flap_ns / 2)`, so consecutive spines overlap.
    pub flap_ns: SimTime,
    /// SEU acceleration factor over the design's MTBF.
    pub seu_accel: f64,
}

impl ScenarioCell {
    pub fn new(scenario: ScenarioKind, transport: TransportKind, leaf_spine: bool) -> ScenarioCell {
        ScenarioCell {
            scenario,
            transport,
            cc: None,
            leaf_spine,
            nodes: 4,
            collective: CollectiveKind::AllReduceRing,
            elems: 16 * 1024,
            iters: 3,
            seed: 29,
            bg_load: 0.2,
            scheduler: SchedKind::Wheel,
            iter_cap_ns: 20 * MS,
            burst_bytes: 96 * 1024,
            straggler_ns: 2 * MS,
            flap_ns: 6 * MS,
            seu_accel: 2e8,
        }
    }

    pub fn topo_name(&self) -> &'static str {
        if self.leaf_spine {
            "leaf-spine"
        } else {
            "single"
        }
    }

    fn fabric(&self) -> FabricCfg {
        let mut fab = FabricCfg::cloudlab(self.nodes);
        if self.leaf_spine {
            fab = fab.with_leaf_spine(2, 2);
        }
        fab.corrupt_prob = 0.0; // adversity comes from the choreography
        fab
    }
}

/// Execute one scenario cell and return its resilience scoreboard as
/// Json (field definitions: docs/SCENARIOS.md §Scoreboard). Pure over
/// its own cluster — safe under the parallel sweep runner.
pub fn run_scenario_cell(cell: &ScenarioCell) -> Json {
    let mut cfg = ClusterCfg::new(cell.fabric(), cell.transport)
        .with_seed(cell.seed)
        .with_bg_load(cell.bg_load)
        .with_scheduler(cell.scheduler);
    if let Some(cc) = cell.cc {
        cfg = cfg.with_cc(cc);
    }
    if cell.scenario.wants_straggler() {
        let mut delays = vec![0; cell.nodes];
        delays[1] = cell.straggler_ns; // one late rank is enough to hurt
        cfg = cfg.with_compute_delays(delays);
    }
    let mut cluster = Cluster::new(cfg);

    // ---- one-shot choreography (absolute times) ----------------------------
    // Rolling spine faults: spine s dark over [0.2ms + s·flap/2, +flap) —
    // consecutive windows overlap, so there is an all-dark interval that
    // outlasts any reliable transport's retry budget.
    let mut spine_plan = "n/a";
    let mut last_down_at: Option<SimTime> = None;
    let mut last_up_at: Option<SimTime> = None;
    if cell.scenario.wants_spine_faults() {
        // derive the spine count from the constructed fabric so the
        // choreography tracks ScenarioCell::fabric() if its shape changes
        // (n_spines is the GLOBAL pod-spine count on a fat-tree, so the
        // rolling schedule walks every pod's spines there too)
        let spines = cluster.cfg.fabric.topology().n_spines();
        spine_plan = if spines == 0 { "skipped" } else { "applied" };
        for s in 0..spines {
            let down_at = 200_000 + s as SimTime * (cell.flap_ns / 2);
            let up_at = down_at + cell.flap_ns;
            match fault::schedule_spine_failure(&mut cluster, s, down_at, Some(up_at)) {
                Ok(_) => {
                    last_down_at = Some(down_at);
                    last_up_at = Some(up_at);
                }
                Err(_) => {
                    // residual plan errors (bad window, out-of-range spine)
                    // record the skip and keep the grid running rather
                    // than aborting the sweep (satellite contract)
                    spine_plan = "skipped";
                    break;
                }
            }
        }
    }
    // SEU barrage over the whole campaign horizon.
    let mut seu_scheduled = 0usize;
    if cell.scenario.wants_seu() {
        let horizon = cell.iters as SimTime * cell.iter_cap_ns;
        seu_scheduled = fault::schedule_faults(
            &mut cluster,
            cell.transport,
            horizon,
            cell.seu_accel,
            cell.seed,
        );
    }

    // ---- workload loop -----------------------------------------------------
    let ws = Workspace::new(&mut cluster, cell.elems, 1);
    let inputs: Vec<Vec<f32>> = (0..cell.nodes).map(|_| vec![1.0f32; cell.elems]).collect();
    let boundaries = cell.collective.phase_boundaries(
        cell.nodes,
        cell.elems,
        cluster.cfg.fabric.bytes_per_ns(),
        cluster.cfg.fabric.base_rtt_ns(),
    );
    let mut driver = Driver::new(1);
    let mut ccts: Vec<SimTime> = Vec::new();
    let mut finish_walls: Vec<SimTime> = Vec::new();
    let mut completions = 0usize;
    let mut lost_bytes = 0usize;
    let mut partial_steps = 0usize;
    let mut loss_sum = 0.0f64;
    let mut iters_run = 0usize;
    for _ in 0..cell.iters {
        iters_run += 1;
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(cell.collective, cell.elems);
        if matches!(
            cell.transport,
            TransportKind::Optinic | TransportKind::OptinicHw
        ) {
            spec.exchange_stats = true;
        } else {
            spec = spec.reliable();
        }
        // per-iteration choreography: bursts land on this run's predicted
        // phase boundaries, each aimed at a rotating victim edge port
        if cell.scenario.wants_incast() {
            for (i, b) in boundaries.iter().take(8).enumerate() {
                cluster.schedule_incast(
                    cluster.time + b,
                    i % cell.nodes,
                    cell.burst_bytes,
                    1500,
                );
            }
        }
        cluster.cfg.max_sim_time = cluster.time + cell.iter_cap_ns;
        let res = driver.run(&mut cluster, &ws, &spec);
        lost_bytes += res.lost_bytes();
        partial_steps += res.partial_steps();
        loss_sum += res.loss_fraction;
        if res.completed && !res.per_rank.iter().any(|r| r.failed) {
            completions += 1;
            ccts.push(res.cct_ns);
            finish_walls.push(cluster.time);
        } else {
            break; // a stalled reliable QP never recovers without re-setup
        }
    }

    // recovery time: first iteration finishing after the last fault window
    // opened, measured from that failure instant (0 = recovered instantly
    // or never faulted; null-equivalent -1 avoided: report presence flag)
    let recovery_ns = last_down_at
        .and_then(|down| finish_walls.iter().find(|&&t| t >= down).map(|&t| t - down))
        .unwrap_or(0);
    let recovered = match (last_down_at, last_up_at) {
        (Some(down), Some(_)) => finish_walls.iter().any(|&t| t >= down),
        _ => completions > 0,
    };

    let mean = if ccts.is_empty() {
        0.0
    } else {
        ccts.iter().sum::<SimTime>() as f64 / ccts.len() as f64
    };
    let p99 = ccts.iter().copied().max().unwrap_or(0);

    let mut o = Json::obj();
    o.set("scenario", cell.scenario.name())
        .set("transport", cell.transport.canonical_name())
        .set(
            "cc",
            cell.cc.map(|c| c.canonical_name()).unwrap_or("default"),
        )
        .set("topo", cell.topo_name())
        .set("collective", cell.collective.name())
        .set("iters", cell.iters as u64)
        .set("completions", completions as u64)
        .set("completed_all", completions == cell.iters)
        .set("mean_ns", mean)
        .set("p99_ns", p99)
        // TTA proxy: total communication time the training step sequence
        // pays across the campaign (docs/SCENARIOS.md §Scoreboard)
        .set("tta_proxy_ns", ccts.iter().sum::<SimTime>())
        .set("stalled_qps", cluster.total_stalled_qps() as u64)
        .set("bytes_lost", lost_bytes as u64)
        .set("partial_steps", partial_steps as u64)
        // mean loss fraction per iteration actually run (a stalled final
        // iteration counts toward both numerator and denominator)
        .set("loss_pct", 100.0 * loss_sum / iters_run.max(1) as f64)
        .set("spine_plan", spine_plan)
        .set("seu_scheduled", seu_scheduled as u64)
        .set(
            "faults_scheduled",
            cluster.metrics.counter("faults_scheduled"),
        )
        .set("faults_injected", cluster.metrics.counter("faults_injected"))
        .set("net_faults", cluster.metrics.counter("net_faults"))
        .set("recovery_ns", recovery_ns)
        .set("recovered", recovered)
        .set("t", cluster.time)
        .set("ev", cluster.events_processed)
        // the full metric surface rides along so determinism suites can
        // byte-compare entire scoreboards, not just summaries
        .set("metrics", cluster.metrics.to_json());
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_names_round_trip() {
        assert_eq!(ScenarioKind::ALL.len(), 6);
        for k in ScenarioKind::ALL {
            assert_eq!(ScenarioKind::parse(k.name()), Some(k));
            assert_eq!(
                ScenarioKind::parse(&k.name().replace('-', "_")),
                Some(k),
                "underscore spelling must parse"
            );
        }
        assert_eq!(ScenarioKind::parse("nope"), None);
    }

    /// Scenario cells must be replayable: same cell ⇒ byte-identical
    /// scoreboard including the full metrics block.
    #[test]
    fn scenario_cell_replays_byte_identical() {
        let mut cell =
            ScenarioCell::new(ScenarioKind::PhaseIncast, TransportKind::Optinic, false);
        cell.elems = 4 * 1024;
        cell.iters = 2;
        let a = run_scenario_cell(&cell).to_string_compact();
        let b = run_scenario_cell(&cell).to_string_compact();
        assert_eq!(a, b);
        assert!(a.contains("\"metrics\""));
    }

    /// The headline acceptance behavior: under rolling spine faults plus
    /// an SEU barrage (the perfect storm), OptiNIC completes every
    /// iteration while RoCE stalls — on the same choreography, seed, and
    /// fabric.
    #[test]
    fn perfect_storm_optinic_completes_roce_stalls() {
        let run = |transport| {
            let mut cell = ScenarioCell::new(ScenarioKind::PerfectStorm, transport, true);
            cell.iters = 2;
            run_scenario_cell(&cell)
        };
        let opt = run(TransportKind::Optinic);
        assert_eq!(
            opt.get("completed_all").and_then(Json::as_bool),
            Some(true),
            "OptiNIC must ride out the perfect storm: {opt:?}"
        );
        assert_eq!(opt.get("stalled_qps").and_then(Json::as_i64), Some(0));
        assert_eq!(opt.get("spine_plan").and_then(Json::as_str), Some("applied"));
        let roce = run(TransportKind::Roce);
        let stalled = roce
            .get("stalled_qps")
            .and_then(Json::as_i64)
            .unwrap_or(0);
        let all = roce
            .get("completed_all")
            .and_then(Json::as_bool)
            .unwrap_or(true);
        assert!(
            !all || stalled > 0,
            "RoCE must stall when the blackhole outlasts its retry budget"
        );
    }

    /// Single-switch cells skip the spine plan instead of aborting.
    #[test]
    fn spine_plan_skips_gracefully_on_single_switch() {
        let mut cell =
            ScenarioCell::new(ScenarioKind::RollingSpineFaults, TransportKind::Optinic, false);
        cell.elems = 4 * 1024;
        cell.iters = 1;
        let out = run_scenario_cell(&cell);
        assert_eq!(out.get("spine_plan").and_then(Json::as_str), Some("skipped"));
        assert_eq!(
            out.get("completed_all").and_then(Json::as_bool),
            Some(true),
            "the cell still runs its workload"
        );
    }

    /// The straggler choreography flows through ClusterCfg::compute_delays:
    /// the run takes at least the injected delay on a reliable transport.
    #[test]
    fn straggler_delays_reliable_completion() {
        let mut cell = ScenarioCell::new(ScenarioKind::Straggler, TransportKind::Irn, false);
        cell.elems = 2 * 1024;
        cell.iters = 1;
        let out = run_scenario_cell(&cell);
        assert_eq!(out.get("completed_all").and_then(Json::as_bool), Some(true));
        let p99 = out.get("p99_ns").and_then(Json::as_f64).unwrap_or(0.0);
        assert!(
            p99 >= cell.straggler_ns as f64,
            "reliable peers must absorb the {} ns straggler (p99={p99})",
            cell.straggler_ns
        );
        // baseline (no straggler) is well under the delay
        let mut base = cell.clone();
        base.scenario = ScenarioKind::Baseline;
        let b = run_scenario_cell(&base);
        let bp = b.get("p99_ns").and_then(Json::as_f64).unwrap_or(f64::MAX);
        assert!(bp < cell.straggler_ns as f64);
    }

    /// DBLP rides any engine as a forced CcKind — the scenario grid's
    /// proof that the CC v2 plane needed zero transport changes.
    #[test]
    fn dblp_runs_scenarios_on_both_engine_families() {
        for transport in [TransportKind::OptinicHw, TransportKind::Irn] {
            let mut cell = ScenarioCell::new(ScenarioKind::PhaseIncast, transport, false);
            cell.cc = Some(CcKind::Dblp);
            cell.elems = 4 * 1024;
            cell.iters = 2;
            let out = run_scenario_cell(&cell);
            assert_eq!(out.get("cc").and_then(Json::as_str), Some("dblp"));
            assert_eq!(
                out.get("completed_all").and_then(Json::as_bool),
                Some(true),
                "{transport:?} under DBLP must complete"
            );
        }
    }
}
