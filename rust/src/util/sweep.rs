//! Deterministic multicore sweep runner.
//!
//! Every figure/table in the paper reproduction comes out of a *grid* of
//! fully independent simulation cells — transport × CC × collective ×
//! size × environment. A cell is a pure function of its spec: it builds
//! its own `Cluster` (own seed, own RNG, own metrics), runs, and returns
//! a `Json` summary of *simulated* quantities. Cells therefore
//! parallelize embarrassingly, and — because nothing crosses cell
//! boundaries — the merged output is byte-identical no matter how many
//! workers ran them or in which order they finished.
//!
//! Design (see docs/PERF.md §"Parallel sweeps"):
//! * pool: `std::thread::scope` workers over a chunked work queue (an
//!   atomic cursor over the cell array; the dependency policy forbids
//!   rayon, and scoped threads let cells borrow grid-wide read-only
//!   state such as the hoisted input buffers);
//! * results ride an `mpsc` channel back keyed by **cell index** and are
//!   merged into fixed grid order — completion order never leaks;
//! * host wall-time is measured by the runner *outside* the cell result,
//!   so the merged `Json` stays deterministic while per-cell and
//!   aggregate wall/speedup numbers are still recorded (BENCH_PR4.json).
//!
//! Wall-clock microbenches (`tab3`, `perf_hotpath`'s timing sections)
//! still declare their grids here but mark them [`SweepGrid::serial`]:
//! running CPU-timing cells concurrently would corrupt the measurement.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::util::json::Json;

/// A positive-integer environment knob (anything else is ignored, not
/// an error).
fn env_uint(name: &str) -> Option<usize> {
    std::env::var(name)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

fn env_jobs() -> Option<usize> {
    env_uint("OPTINIC_JOBS")
}

/// The operator's explicit worker choice, if any: `--jobs N` /
/// `--jobs=N` in the raw process arguments, else `OPTINIC_JOBS`. This
/// is THE precedence rule — every resolution path below goes through
/// it, so the launcher, the plain benches, and the memory-bounded
/// benches can never diverge on how the knob reads.
pub fn explicit_jobs() -> Option<usize> {
    let args: Vec<String> = std::env::args().collect();
    jobs_from_arg_list(&args).or_else(env_jobs)
}

/// Worker count when the caller gives none: `OPTINIC_JOBS` if set,
/// else `std::thread::available_parallelism()`.
pub fn default_jobs() -> usize {
    env_jobs().unwrap_or_else(available_parallelism)
}

fn available_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Worker count for a bench binary: `--jobs`/`OPTINIC_JOBS`, else all
/// cores. (The launcher goes through `util::cli::Args` instead of raw
/// argv, but resolves the same way.)
pub fn jobs_from_args() -> usize {
    explicit_jobs().unwrap_or_else(available_parallelism)
}

/// Concurrent-cell buffer budget for [`jobs_bounded_by_cell_bytes`]:
/// large-message grids build multi-GB clusters per cell, and the derived
/// default must not multiply that by every core on the machine.
pub const CELL_MEM_BUDGET_BYTES: usize = 8 << 30;

/// Memory-aware default worker count for grids whose cells allocate
/// large buffers (fig5's 80 MB collectives register ~2 GB of cluster
/// memory per in-flight cell). An explicit `--jobs N` or `OPTINIC_JOBS`
/// always wins — the operator asked for it; otherwise the
/// `available_parallelism` default is clamped so concurrent cells stay
/// within [`CELL_MEM_BUDGET_BYTES`].
pub fn jobs_bounded_by_cell_bytes(bytes_per_cell: usize) -> usize {
    if let Some(n) = explicit_jobs() {
        return n;
    }
    let cap = (CELL_MEM_BUDGET_BYTES / bytes_per_cell.max(1)).max(1);
    available_parallelism().min(cap)
}

fn jobs_from_arg_list(args: &[String]) -> Option<usize> {
    uint_flag_from_arg_list(args, "--jobs")
}

/// Parse `--<flag> N` / `--<flag>=N` from a raw argument list (first
/// valid occurrence wins; non-numeric or zero values are skipped).
fn uint_flag_from_arg_list(args: &[String], flag: &str) -> Option<usize> {
    let eq = format!("{flag}=");
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let v = if a == flag {
            it.next().map(String::as_str)
        } else {
            a.strip_prefix(eq.as_str())
        };
        if let Some(v) = v {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return Some(n);
                }
            }
        }
    }
    None
}

// ---- engine cores (--cores): the partitioned-DES knob ----------------------
//
// `--jobs` parallelizes ACROSS grid cells; `--cores` parallelizes WITHIN
// one simulation (the partitioned conservative engine,
// `sim::Cluster::run_partitioned`). Both are pure wall-clock knobs —
// neither changes any merged result byte (docs/PERF.md §"Partitioned
// engine" for the precedence rules).

/// The operator's explicit per-run engine core choice, if any:
/// `--cores N` / `--cores=N`, else `OPTINIC_CORES`. `None` means "leave
/// the legacy single-threaded engine in place".
pub fn explicit_cores() -> Option<usize> {
    let args: Vec<String> = std::env::args().collect();
    uint_flag_from_arg_list(&args, "--cores").or_else(|| env_uint("OPTINIC_CORES"))
}

/// Sweep worker count when each cell itself runs a partitioned engine on
/// `cores` threads. An explicit `--jobs`/`OPTINIC_JOBS` always wins (the
/// operator asked for that many cell workers, whatever the product);
/// otherwise the machine is budgeted between the two layers:
/// `jobs × cores ≤ available_parallelism`, with at least one worker.
pub fn jobs_with_cores(cores: usize) -> usize {
    if let Some(n) = explicit_jobs() {
        return n;
    }
    (available_parallelism() / cores.max(1)).max(1)
}

/// Outcome of executing a grid: merged cell results in **fixed grid
/// order**, plus the wall-clock accounting the perf artifacts record.
#[derive(Debug)]
pub struct SweepReport<R> {
    /// One result per cell, index-aligned with the grid's cell array.
    pub results: Vec<R>,
    /// Host wall time each cell spent executing (ns). Nondeterministic
    /// by nature — kept OUT of `results` so merged output stays
    /// byte-identical across `--jobs`.
    pub cell_wall_ns: Vec<f64>,
    /// Wall time of the whole sweep (ns).
    pub wall_ns: f64,
    /// Worker threads actually used.
    pub jobs: usize,
}

impl<R> SweepReport<R> {
    /// Sum of per-cell wall times: what a serial run of the same cells
    /// would roughly have cost. `cells_wall_ns / wall_ns` is the pool's
    /// effective speedup.
    pub fn cells_wall_ns(&self) -> f64 {
        self.cell_wall_ns.iter().sum()
    }

    /// Effective parallel speedup (total cell work / sweep wall).
    pub fn pool_speedup(&self) -> f64 {
        let w = self.wall_ns.max(1.0);
        self.cells_wall_ns() / w
    }
}

impl SweepReport<Json> {
    /// Wall-clock accounting as JSON (per-cell walls, aggregate, jobs,
    /// effective speedup) — the shape `BENCH_PR4.json` records per grid.
    pub fn wall_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("jobs", self.jobs)
            .set("cells", self.results.len())
            .set("wall_ns", self.wall_ns)
            .set("cells_wall_ns", self.cells_wall_ns())
            .set("pool_speedup", self.pool_speedup())
            .set(
                "cell_wall_ns",
                Json::Arr(self.cell_wall_ns.iter().map(|&w| Json::Num(w)).collect()),
            );
        o
    }
}

/// A declared grid: the cell specs (data, not loops) plus execution
/// policy. All eleven benches and `optinic sweep` run through this.
#[derive(Clone, Debug)]
pub struct SweepGrid<T> {
    pub name: String,
    pub cells: Vec<T>,
    jobs: Option<usize>,
    serial: bool,
}

impl<T: Sync> SweepGrid<T> {
    pub fn new(name: &str, cells: Vec<T>) -> SweepGrid<T> {
        SweepGrid {
            name: name.to_string(),
            cells,
            jobs: None,
            serial: false,
        }
    }

    /// Override the worker count (e.g. from `--jobs`). Values are
    /// clamped to the cell count at run time.
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = Some(jobs.max(1));
        self
    }

    /// Force single-worker execution: for grids whose cells *measure
    /// host wall time* (tab3, perf_hotpath timing sections) —
    /// concurrent CPU-bound timing cells would contend for cores and
    /// memory bandwidth and corrupt each other's numbers.
    pub fn serial(mut self) -> Self {
        self.serial = true;
        self
    }

    /// Worker count this grid will run with.
    pub fn jobs(&self) -> usize {
        if self.serial {
            1
        } else {
            self.jobs.unwrap_or_else(default_jobs)
        }
    }

    /// Execute every cell and merge results in grid order.
    pub fn run<R, F>(&self, f: F) -> SweepReport<R>
    where
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        run_cells(&self.cells, self.jobs(), f)
    }

    /// Fallible cells: every cell still runs; the **first error in grid
    /// order** wins (deterministic regardless of completion order).
    pub fn try_run<R, E, F>(&self, f: F) -> Result<SweepReport<R>, E>
    where
        R: Send,
        E: Send,
        F: Fn(usize, &T) -> Result<R, E> + Sync,
    {
        let rep = self.run(f);
        let mut results = Vec::with_capacity(rep.results.len());
        for r in rep.results {
            results.push(r?);
        }
        Ok(SweepReport {
            results,
            cell_wall_ns: rep.cell_wall_ns,
            wall_ns: rep.wall_ns,
            jobs: rep.jobs,
        })
    }
}

/// How many cells a worker claims per queue visit: big grids amortize
/// the (cheap) atomic claim, small grids keep chunk = 1 for load
/// balance. Cells are coarse (whole simulations), so balance dominates.
fn chunk_size(cells: usize, jobs: usize) -> usize {
    (cells / (jobs * 8)).max(1)
}

/// The pool: scoped worker threads pull chunks of cell indices from an
/// atomic cursor and send `(index, result, cell_wall_ns)` back over a
/// channel; the caller's thread slots results by index. Determinism
/// argument: `f` sees only its own cell spec (plus `Sync` read-only
/// captures), results are keyed by index, and the merge order is the
/// grid order — so the returned vectors are independent of `jobs`,
/// scheduling, and completion order.
pub fn run_cells<T, R, F>(cells: &[T], jobs: usize, f: F) -> SweepReport<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = cells.len();
    let jobs = jobs.clamp(1, n.max(1));
    let t0 = Instant::now();
    let mut slots: Vec<Option<(R, f64)>> = (0..n).map(|_| None).collect();

    if jobs == 1 {
        // serial fast path — also the reference semantics the parallel
        // path must reproduce byte for byte (rust/tests/determinism.rs)
        for (i, cell) in cells.iter().enumerate() {
            let c0 = Instant::now();
            let r = f(i, cell);
            slots[i] = Some((r, c0.elapsed().as_nanos() as f64));
        }
    } else {
        let chunk = chunk_size(n, jobs);
        let cursor = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, R, f64)>();
        std::thread::scope(|s| {
            for _ in 0..jobs {
                let tx = tx.clone();
                let cursor = &cursor;
                let f = &f;
                s.spawn(move || loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    for i in start..(start + chunk).min(n) {
                        let c0 = Instant::now();
                        let r = f(i, &cells[i]);
                        if tx.send((i, r, c0.elapsed().as_nanos() as f64)).is_err() {
                            return;
                        }
                    }
                });
            }
            drop(tx);
            for (i, r, w) in rx {
                debug_assert!(slots[i].is_none(), "cell {i} ran twice");
                slots[i] = Some((r, w));
            }
        });
    }

    let mut results = Vec::with_capacity(n);
    let mut cell_wall_ns = Vec::with_capacity(n);
    for (i, slot) in slots.into_iter().enumerate() {
        let (r, w) = slot.unwrap_or_else(|| panic!("cell {i} produced no result"));
        results.push(r);
        cell_wall_ns.push(w);
    }
    SweepReport {
        results,
        cell_wall_ns,
        wall_ns: t0.elapsed().as_nanos() as f64,
        jobs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell_json(i: usize, x: &u64) -> Json {
        let mut o = Json::obj();
        o.set("index", i).set("value", x * x);
        o
    }

    #[test]
    fn merge_order_is_grid_order_for_any_jobs() {
        let cells: Vec<u64> = (0..37).collect();
        let grid = SweepGrid::new("t", cells);
        let serial = grid.clone().with_jobs(1).run(cell_json);
        for jobs in [2, 4, 9, 64] {
            let par = grid.clone().with_jobs(jobs).run(cell_json);
            assert_eq!(serial.results, par.results, "jobs={jobs} diverged");
            // merged output is byte-identical, not just structurally equal
            let a = Json::Arr(serial.results.clone()).to_string_pretty();
            let b = Json::Arr(par.results.clone()).to_string_pretty();
            assert_eq!(a, b, "jobs={jobs} bytes diverged");
        }
    }

    #[test]
    fn jobs_clamped_to_cells() {
        let rep = run_cells(&[1u64, 2], 16, |_, x| *x);
        assert_eq!(rep.jobs, 2);
        assert_eq!(rep.results, vec![1, 2]);
        assert_eq!(rep.cell_wall_ns.len(), 2);
        assert!(rep.wall_ns >= 0.0);
    }

    #[test]
    fn empty_grid_is_fine() {
        let rep = run_cells::<u64, u64, _>(&[], 8, |_, x| *x);
        assert!(rep.results.is_empty());
        assert_eq!(rep.jobs, 1);
    }

    #[test]
    fn serial_grid_forces_one_worker() {
        let grid = SweepGrid::new("timing", vec![1u64; 8]).with_jobs(8).serial();
        assert_eq!(grid.jobs(), 1);
    }

    #[test]
    fn try_run_returns_first_error_in_grid_order() {
        let grid = SweepGrid::new("t", (0..16u64).collect()).with_jobs(4);
        let err = grid
            .try_run(|i, _| if i >= 3 { Err(format!("cell {i}")) } else { Ok(i) })
            .unwrap_err();
        // cells 3..16 all fail; the merge must surface cell 3 no matter
        // which worker finished first
        assert_eq!(err, "cell 3");
        let ok = grid.try_run::<_, String, _>(|i, _| Ok(i)).unwrap();
        assert_eq!(ok.results, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn chunking_covers_all_cells() {
        // chunk > 1 path: 4 jobs over 256 cells → chunk 8
        assert_eq!(chunk_size(256, 4), 8);
        let cells: Vec<u64> = (0..256).collect();
        let rep = run_cells(&cells, 4, |_, x| x + 1);
        assert_eq!(rep.results, (1..=256).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_arg_parsing() {
        let a = |v: &[&str]| jobs_from_arg_list(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>());
        assert_eq!(a(&["bench", "--jobs", "3"]), Some(3));
        assert_eq!(a(&["bench", "--jobs=5", "--quick"]), Some(5));
        assert_eq!(a(&["bench", "--quick"]), None);
        assert_eq!(a(&["bench", "--jobs", "0"]), None);
        assert_eq!(a(&["bench", "--jobs", "nope"]), None);
    }

    #[test]
    fn cores_arg_parsing() {
        let a = |v: &[&str]| {
            uint_flag_from_arg_list(
                &v.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
                "--cores",
            )
        };
        assert_eq!(a(&["bench", "--cores", "4"]), Some(4));
        assert_eq!(a(&["bench", "--cores=2", "--quick"]), Some(2));
        assert_eq!(a(&["bench", "--jobs", "4"]), None);
        assert_eq!(a(&["bench", "--cores", "0"]), None);
        // `--jobs` parsing is untouched by the shared parser
        let args: Vec<String> = ["bench", "--jobs=3", "--cores=2"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(jobs_from_arg_list(&args), Some(3));
        assert_eq!(uint_flag_from_arg_list(&args, "--cores"), Some(2));
    }

    #[test]
    fn memory_cap_math() {
        // 2 GiB cells under the 8 GiB budget → at most 4 workers
        let cap = (CELL_MEM_BUDGET_BYTES / (2usize << 30)).max(1);
        assert_eq!(cap, 4);
        // cells bigger than the whole budget still get one worker
        assert_eq!((CELL_MEM_BUDGET_BYTES / (16usize << 30)).max(1), 1);
        // tiny cells are not clamped below the machine's parallelism
        let j = jobs_bounded_by_cell_bytes(1024);
        assert!(j >= 1);
    }

    #[test]
    fn wall_json_shape() {
        let grid = SweepGrid::new("t", vec![1u64, 2, 3]);
        let rep = grid.with_jobs(2).run(cell_json);
        let j = rep.wall_json();
        assert_eq!(j.get("cells").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("jobs").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("cell_wall_ns").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.get("pool_speedup").unwrap().as_f64().unwrap() >= 0.0);
    }
}
