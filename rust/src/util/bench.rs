//! Micro/macro benchmark harness (no `criterion` in the offline cache).
//!
//! Benches in `rust/benches/*.rs` use `harness = false` and drive this
//! module: warmup + timed iterations, wall-clock stats (mean/p50/p99/std),
//! paper-style table printing, and — since the multicore sweep harness —
//! the shared collective-grid cell ([`CollectiveCell`] /
//! [`run_collective_cell`]) that used to be copy-pasted as nested loops
//! across the figure benches. Results can also be dumped as JSON for
//! EXPERIMENTS.md tooling.

use std::time::Instant;

use crate::cc::CcKind;
use crate::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use crate::net::FabricCfg;
use crate::sim::cluster::{Cluster, ClusterCfg};
use crate::sim::SimTime;
use crate::transport::{Transport, TransportKind};
use crate::util::json::Json;
use crate::util::stats::Samples;

/// `--quick` / `PERF_QUICK=1` detection shared by the bench binaries
/// (CI smoke runs shrink their grids through this).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("PERF_QUICK").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Grid-wide collective input buffers. The figure sweeps feed every rank
/// the same fill value, so ONE buffer sized for the largest cell serves
/// the whole grid as read-only slices — one allocation per grid instead
/// of a `Vec<Vec<f32>>` rebuilt in every cell's setup, and safely
/// shareable across sweep workers (`&InputSet` is `Sync`).
pub struct InputSet {
    buf: Vec<f32>,
}

impl InputSet {
    /// A `1.0`-filled buffer covering cells up to `max_elems` elements.
    pub fn ones(max_elems: usize) -> InputSet {
        InputSet {
            buf: vec![1.0f32; max_elems],
        }
    }

    /// Per-rank input slices for a cell of `elems` elements.
    pub fn ranks(&self, nodes: usize, elems: usize) -> Vec<&[f32]> {
        assert!(
            elems <= self.buf.len(),
            "cell wants {elems} elems, InputSet holds {}",
            self.buf.len()
        );
        (0..nodes).map(|_| &self.buf[..elems]).collect()
    }
}

/// One collective-grid cell: pure data describing a full, independent
/// simulation (own cluster, own seed). The benches declare grids of
/// these and hand them to `util::sweep`; nothing carries over between
/// cells, which is what makes the sweep embarrassingly parallel AND
/// byte-deterministic regardless of `--jobs`.
#[derive(Clone, Debug)]
pub struct CollectiveCell {
    pub fabric: FabricCfg,
    pub transport: TransportKind,
    /// Force a CC algorithm (`ClusterCfg::with_cc`); `None` keeps the
    /// transport's paper-default scheme.
    pub cc: Option<CcKind>,
    pub kind: CollectiveKind,
    pub elems: usize,
    pub iters: usize,
    pub seed: u64,
    pub bg_load: f64,
    pub exchange_stats: bool,
    /// `CollectiveSpec::reliable()` (timeouts off) for this cell.
    pub reliable: bool,
    /// Cap each iteration at `now + cap` so a pathological pairing
    /// cannot hang the grid (0 = no cap; incomplete runs are recorded,
    /// not hidden).
    pub iter_cap_ns: SimTime,
    /// Worker threads for the partitioned conservative engine inside
    /// this cell's single simulation (`ClusterCfg::with_cores`). `None`
    /// keeps the legacy event loop. A pure wall-clock knob: the cell's
    /// result `Json` is byte-identical for any value, so it is NOT
    /// echoed into the output.
    pub cores: Option<usize>,
}

impl CollectiveCell {
    pub fn new(
        fabric: FabricCfg,
        transport: TransportKind,
        kind: CollectiveKind,
        elems: usize,
    ) -> CollectiveCell {
        CollectiveCell {
            fabric,
            transport,
            cc: None,
            kind,
            elems,
            iters: 1,
            seed: 11,
            bg_load: 0.0,
            exchange_stats: true,
            reliable: !matches!(
                transport,
                TransportKind::Optinic | TransportKind::OptinicHw
            ),
            iter_cap_ns: 0,
            cores: None,
        }
    }

    /// Run this cell's simulation on the partitioned engine with `cores`
    /// worker threads (`None` = legacy single-threaded loop).
    pub fn with_cores(mut self, cores: Option<usize>) -> Self {
        self.cores = cores;
        self
    }

    pub fn size_mb(&self) -> usize {
        self.elems * 4 / (1024 * 1024)
    }

    /// Rough resident footprint of this cell's cluster while running:
    /// `nodes × elems × 4 B` per registered buffer, three buffers per
    /// rank (`RankBuffers`) plus engine slack → 16 bytes per element
    /// per node, PLUS per-port fabric state — queues, horizons, per-link
    /// metrics — budgeted at 4 KiB per link. Single-switch and leaf–spine
    /// grids barely notice the port term, but a 1k-rank fat-tree carries
    /// O(10k) links and the sweep runner's memory-bounded worker clamp
    /// ([`crate::util::sweep::jobs_bounded_by_cell_bytes`], 8 GiB budget)
    /// must see that state or co-scheduled cells blow the budget. Keep
    /// this next to the cell definition so the estimate and the buffer
    /// model can't drift apart.
    pub fn est_cluster_bytes(&self) -> usize {
        let base = self.fabric.nodes * self.elems * 16
            + self.fabric.topology().n_links() * 4096;
        // Partitioned engine (`cores` set on a multi-tier topology): every
        // partition shard carries its OWN memory-pool replica and fabric
        // port array, plus a timing wheel (2048 recycled slot vectors +
        // staged entries) and the window envelope inbox/outbox buffers.
        // The co-scheduling clamp must budget per-shard replication or
        // `--jobs × --cores` cells blow the 8 GiB cap exactly when both
        // knobs are in play.
        let n_parts = match self.cores {
            Some(_) => {
                crate::net::PartitionMap::new(&self.fabric.topology()).n_parts
            }
            None => 1,
        };
        if n_parts <= 1 {
            return base;
        }
        const WHEEL_BYTES: usize = 256 * 1024;
        const CHANNEL_BYTES: usize = 64 * 1024;
        base * n_parts + n_parts * (WHEEL_BYTES + CHANNEL_BYTES)
    }
}

/// Execute one collective cell: build its cluster, run the iterations,
/// summarize. The returned `Json` carries only *simulated* quantities
/// (CCT stats, loss, completion, resolved CC) — host wall-time lives in
/// the sweep runner's report, NOT here, so merged grid output is
/// byte-identical for any `--jobs`.
pub fn run_collective_cell(cell: &CollectiveCell, inputs: &InputSet) -> Json {
    let mut ccfg = ClusterCfg::new(cell.fabric.clone(), cell.transport)
        .with_seed(cell.seed)
        .with_bg_load(cell.bg_load);
    if let Some(k) = cell.cc {
        ccfg = ccfg.with_cc(k);
    }
    if let Some(n) = cell.cores {
        ccfg = ccfg.with_cores(n);
    }
    let mut cluster = Cluster::new(ccfg);
    let ws = Workspace::new(&mut cluster, cell.elems, 1);
    let ranks = inputs.ranks(cluster.nodes(), cell.elems);
    let mut driver = Driver::new(1);
    let mut s = Samples::new();
    let mut loss = 0.0;
    let mut all_ok = true;
    for _ in 0..cell.iters {
        ws.load_input_slices(&mut cluster, &ranks);
        let mut spec = CollectiveSpec::new(cell.kind, cell.elems);
        spec.exchange_stats = cell.exchange_stats;
        if cell.reliable {
            spec = spec.reliable();
        }
        if cell.iter_cap_ns > 0 {
            cluster.cfg.max_sim_time = cluster.time + cell.iter_cap_ns;
        }
        let res = driver.run(&mut cluster, &ws, &spec);
        all_ok &= res.completed;
        s.push(res.cct_ns as f64);
        loss += res.loss_fraction;
    }
    let mut o = Json::obj();
    o.set("transport", cell.transport.name())
        .set("cc", cluster.transport(0).cc_kind().name())
        .set("topo", cell.fabric.topo.name())
        .set("collective", cell.kind.name())
        .set("mb", cell.size_mb())
        .set("mean_ns", s.mean())
        .set("std_ns", s.std())
        .set("p99_ns", s.p99())
        .set("loss_pct", loss / cell.iters.max(1) as f64 * 100.0)
        .set("completed", all_ok);
    o
}

/// Numeric field accessor for merged cell `Json` (table emission).
pub fn jf(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

/// String field accessor for merged cell `Json`.
pub fn js(j: &Json, key: &str) -> String {
    j.get(key)
        .and_then(Json::as_str)
        .unwrap_or_default()
        .to_string()
}

/// Result of one named measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_ns", self.mean_ns)
            .set("std_ns", self.std_ns)
            .set("p50_ns", self.p50_ns)
            .set("p99_ns", self.p99_ns)
            .set("min_ns", self.min_ns)
            .set("max_ns", self.max_ns);
        o
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_nanos() as f64);
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        std_ns: s.std(),
        p50_ns: s.p50(),
        p99_ns: s.p99(),
        min_ns: s.min(),
        max_ns: s.max(),
    }
}

/// Human-friendly duration rendering.
pub fn fmt_ns(ns: f64) -> String {
    if !ns.is_finite() {
        "n/a".to_string()
    } else if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Paper-style fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let hdr: Vec<String> = (0..ncols)
            .map(|i| format!("{:<w$}", self.headers[i], w = widths[i]))
            .collect();
        out.push_str(&hdr.join(" | "));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("-+-"),
        );
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = (0..ncols)
                .map(|i| format!("{:<w$}", row[i], w = widths[i]))
                .collect();
            out.push_str(&cells.join(" | "));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", self.title.as_str());
        o.set(
            "headers",
            Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        o.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        o
    }
}

/// Write a bench result JSON file under `bench_results/` (created on demand).
pub fn save_results(bench_name: &str, body: Json) {
    let dir = std::path::Path::new("bench_results");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(format!("{bench_name}.json"));
        let _ = std::fs::write(path, body.to_string_pretty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures() {
        let m = time_fn("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.iters, 10);
        assert!(m.mean_ns >= 0.0);
        assert!(m.p99_ns >= m.p50_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "bbbb"]);
        t.row_strs(&["xxxxx", "y"]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("xxxxx | y"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn input_set_slices() {
        let inputs = InputSet::ones(64);
        let ranks = inputs.ranks(4, 16);
        assert_eq!(ranks.len(), 4);
        assert!(ranks.iter().all(|r| r.len() == 16 && r[0] == 1.0));
    }

    #[test]
    #[should_panic]
    fn input_set_bounds_checked() {
        InputSet::ones(8).ranks(2, 16);
    }

    #[test]
    fn collective_cell_is_replay_deterministic() {
        // the cell is the unit the parallel sweep scatters: same spec ⇒
        // byte-identical Json, run to run
        let mut cell = CollectiveCell::new(
            FabricCfg::cloudlab(2),
            TransportKind::Optinic,
            CollectiveKind::AllReduceRing,
            256,
        );
        cell.iters = 2;
        cell.bg_load = 0.2;
        let inputs = InputSet::ones(256);
        let a = run_collective_cell(&cell, &inputs).to_string_pretty();
        let b = run_collective_cell(&cell, &inputs).to_string_pretty();
        assert_eq!(a, b);
        assert!(a.contains("\"mean_ns\""));
    }

    #[test]
    fn est_cluster_bytes_accounts_for_partition_replicas() {
        let single = CollectiveCell::new(
            FabricCfg::cloudlab(8).with_leaf_spine(4, 2),
            TransportKind::Optinic,
            CollectiveKind::AllReduceRing,
            1 << 20,
        );
        let parted = single.clone().with_cores(Some(4));
        // 4 leaf partitions replicate the pool + ports, plus per-shard
        // wheel/channel overhead: the estimate must grow at least 4×
        assert!(parted.est_cluster_bytes() >= 4 * single.est_cluster_bytes());
        // single-switch topologies never partition: same estimate
        let ss = CollectiveCell::new(
            FabricCfg::cloudlab(8),
            TransportKind::Optinic,
            CollectiveKind::AllReduceRing,
            1 << 20,
        );
        assert_eq!(
            ss.est_cluster_bytes(),
            ss.clone().with_cores(Some(4)).est_cluster_bytes()
        );
    }

    #[test]
    fn collective_cell_runs_partitioned_byte_identical() {
        let mk = |cores: Option<usize>| {
            let mut cell = CollectiveCell::new(
                FabricCfg::cloudlab(4).with_leaf_spine(2, 2),
                TransportKind::Optinic,
                CollectiveKind::AllReduceRing,
                256,
            )
            .with_cores(cores);
            cell.iters = 2;
            cell
        };
        let inputs = InputSet::ones(256);
        let one = run_collective_cell(&mk(Some(1)), &inputs).to_string_pretty();
        let four = run_collective_cell(&mk(Some(4)), &inputs).to_string_pretty();
        assert_eq!(one, four, "cell output must not depend on --cores");
    }

    #[test]
    fn collective_cell_defaults_follow_transport() {
        let mk = |t| {
            CollectiveCell::new(
                FabricCfg::cloudlab(2),
                t,
                CollectiveKind::AllReduceRing,
                64,
            )
        };
        assert!(!mk(TransportKind::Optinic).reliable);
        assert!(!mk(TransportKind::OptinicHw).reliable);
        assert!(mk(TransportKind::Roce).reliable);
        assert_eq!(mk(TransportKind::Roce).size_mb(), 0);
    }
}
