//! Micro/macro benchmark harness (no `criterion` in the offline cache).
//!
//! Benches in `rust/benches/*.rs` use `harness = false` and drive this
//! module: warmup + timed iterations, wall-clock stats (mean/p50/p99/std),
//! and paper-style table printing. Results can also be dumped as JSON for
//! EXPERIMENTS.md tooling.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Samples;

/// Result of one named measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_ns", self.mean_ns)
            .set("std_ns", self.std_ns)
            .set("p50_ns", self.p50_ns)
            .set("p99_ns", self.p99_ns)
            .set("min_ns", self.min_ns)
            .set("max_ns", self.max_ns);
        o
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_nanos() as f64);
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        std_ns: s.std(),
        p50_ns: s.p50(),
        p99_ns: s.p99(),
        min_ns: s.min(),
        max_ns: s.max(),
    }
}

/// Human-friendly duration rendering.
pub fn fmt_ns(ns: f64) -> String {
    if !ns.is_finite() {
        "n/a".to_string()
    } else if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Paper-style fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let hdr: Vec<String> = (0..ncols)
            .map(|i| format!("{:<w$}", self.headers[i], w = widths[i]))
            .collect();
        out.push_str(&hdr.join(" | "));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("-+-"),
        );
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = (0..ncols)
                .map(|i| format!("{:<w$}", row[i], w = widths[i]))
                .collect();
            out.push_str(&cells.join(" | "));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", self.title.as_str());
        o.set(
            "headers",
            Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        o.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        o
    }
}

/// Write a bench result JSON file under `bench_results/` (created on demand).
pub fn save_results(bench_name: &str, body: Json) {
    let dir = std::path::Path::new("bench_results");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(format!("{bench_name}.json"));
        let _ = std::fs::write(path, body.to_string_pretty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures() {
        let m = time_fn("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.iters, 10);
        assert!(m.mean_ns >= 0.0);
        assert!(m.p99_ns >= m.p50_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "bbbb"]);
        t.row_strs(&["xxxxx", "y"]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("xxxxx | y"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
