//! Minimal command-line argument parser (no `clap` in the offline cache).
//!
//! Supports: subcommands, `--flag`, `--key value`, `--key=value`, positional
//! arguments, and auto-generated help text.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (exclusive of argv[0]).
    /// `known_flags` lists boolean options that do not consume a value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        expect_subcommand: bool,
        known_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        if expect_subcommand {
            if let Some(first) = it.peek() {
                if !first.starts_with('-') {
                    out.subcommand = it.next();
                }
            }
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminator: rest is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some(eq) = body.find('=') {
                    out.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        out.options.insert(body.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(expect_subcommand: bool, known_flags: &[&str]) -> Result<Args, String> {
        Args::parse(std::env::args().skip(1), expect_subcommand, known_flags)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }
}

/// Help-text builder shared by the launcher and examples.
pub struct Help {
    name: &'static str,
    about: &'static str,
    lines: Vec<(String, String)>,
}

impl Help {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Help {
            name,
            about,
            lines: Vec::new(),
        }
    }

    pub fn item(mut self, left: &str, right: &str) -> Self {
        self.lines.push((left.to_string(), right.to_string()));
        self
    }

    pub fn render(&self) -> String {
        let width = self
            .lines
            .iter()
            .map(|(l, _)| l.len())
            .max()
            .unwrap_or(0)
            .max(10);
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.name, self.about);
        for (l, r) in &self.lines {
            s.push_str(&format!("  {l:<width$}  {r}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(
            argv(&["train", "--steps", "100", "--transport=optinic", "-x"]),
            true,
            &[],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("steps"), Some("100"));
        assert_eq!(a.opt("transport"), Some("optinic"));
        assert_eq!(a.positional, vec!["-x"]);
    }

    #[test]
    fn known_flags_do_not_consume() {
        let a = Args::parse(argv(&["--verbose", "pos1"]), false, &["verbose"]).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(argv(&["--dry-run"]), false, &[]).unwrap();
        assert!(a.has_flag("dry-run"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = Args::parse(argv(&["--a", "1", "--", "--not-an-opt"]), false, &[]).unwrap();
        assert_eq!(a.opt("a"), Some("1"));
        assert_eq!(a.positional, vec!["--not-an-opt"]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(argv(&["--n", "5", "--p", "0.25"]), false, &[]).unwrap();
        assert_eq!(a.opt_usize("n", 0), 5);
        assert_eq!(a.opt_f64("p", 0.0), 0.25);
        assert_eq!(a.opt_usize("missing", 9), 9);
    }

    #[test]
    fn help_renders() {
        let h = Help::new("optinic", "launcher")
            .item("--steps N", "training steps")
            .render();
        assert!(h.contains("--steps N"));
        assert!(h.contains("launcher"));
    }
}
