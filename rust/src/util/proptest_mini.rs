//! Miniature property-based testing framework (no `proptest` offline).
//!
//! Provides seeded value generators and a runner that executes a property
//! over many random cases, then *shrinks* failures (halving integers,
//! truncating vectors) to a small counterexample. Every failure report
//! includes the case seed so it can be replayed deterministically:
//!
//! ```text
//! property failed (seed=0x5eed, case=17, shrunk 9 steps): ...
//! ```
//!
//! Used by the transport/collective invariant tests (`rust/tests/`).

use crate::util::prng::Pcg64;

/// A generator of random values of type `T`.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg64) -> T;
    /// Produce smaller candidate values; empty = cannot shrink further.
    fn shrink(&self, value: &T) -> Vec<T>;
}

/// Uniform integer in [lo, hi].
pub struct IntRange {
    pub lo: u64,
    pub hi: u64,
}

impl Gen<u64> for IntRange {
    fn generate(&self, rng: &mut Pcg64) -> u64 {
        rng.range_inclusive(self.lo, self.hi)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (*v - self.lo) / 2;
            if mid != *v && mid != self.lo {
                out.push(mid);
            }
            if *v - 1 != mid && *v - 1 != self.lo {
                out.push(*v - 1);
            }
        }
        out
    }
}

/// Uniform f64 in [lo, hi).
pub struct FloatRange {
    pub lo: f64,
    pub hi: f64,
}

impl Gen<f64> for FloatRange {
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        self.lo + rng.f64() * (self.hi - self.lo)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if (*v - self.lo).abs() > 1e-12 {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2.0);
        }
        out
    }
}

/// Vector of values from an element generator, length in [min_len, max_len].
pub struct VecGen<G> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<T: Clone, G: Gen<T>> Gen<Vec<T>> for VecGen<G> {
    fn generate(&self, rng: &mut Pcg64) -> Vec<T> {
        let len = rng.range_inclusive(self.min_len as u64, self.max_len as u64) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<T>) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // drop back half
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            // drop last element
            out.push(v[..v.len() - 1].to_vec());
            // drop first element (keeps length-1 but different content)
            if v.len() - 1 >= self.min_len {
                out.push(v[1..].to_vec());
            }
        }
        // shrink one element
        for (i, val) in v.iter().enumerate().take(8) {
            for smaller in self.elem.shrink(val) {
                let mut c = v.clone();
                c[i] = smaller;
                out.push(c);
            }
        }
        out
    }
}

/// Outcome of a property check over one case.
pub type PropResult = Result<(), String>;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 128,
            seed: 0x0971_1c5e_ed00_0001,
            max_shrink_steps: 200,
        }
    }
}

/// Run `prop` over `cfg.cases` generated values; panic with a replayable
/// report on failure (after shrinking).
pub fn check<T: Clone + std::fmt::Debug, G: Gen<T>>(
    name: &str,
    cfg: PropConfig,
    gen: &G,
    prop: impl Fn(&T) -> PropResult,
) {
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed, case as u64);
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // shrink
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if steps >= cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={:#x}, case={case}, shrunk {steps} steps)\n  input: {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Convenience: check with default config.
pub fn quickcheck<T: Clone + std::fmt::Debug, G: Gen<T>>(
    name: &str,
    gen: &G,
    prop: impl Fn(&T) -> PropResult,
) {
    check(name, PropConfig::default(), gen, prop)
}

/// Assertion helpers usable inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quickcheck("sum-commutes", &VecGen {
            elem: IntRange { lo: 0, hi: 1000 },
            min_len: 0,
            max_len: 32,
        }, |v: &Vec<u64>| {
            let fwd: u64 = v.iter().sum();
            let rev: u64 = v.iter().rev().sum();
            prop_assert_eq!(fwd, rev);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        quickcheck("always-fails", &IntRange { lo: 0, hi: 10 }, |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // property: all values < 50. Counterexample should shrink toward 50.
        let gen = IntRange { lo: 0, hi: 1000 };
        let result = std::panic::catch_unwind(|| {
            check(
                "lt-50",
                PropConfig {
                    cases: 64,
                    seed: 0xabcd,
                    max_shrink_steps: 500,
                },
                &gen,
                |v: &u64| {
                    prop_assert!(*v < 50, "{v} >= 50");
                    Ok(())
                },
            )
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        // the shrunk input should be a small value close to the boundary
        let input: u64 = msg
            .lines()
            .find(|l| l.contains("input:"))
            .and_then(|l| l.split("input:").nth(1))
            .and_then(|s| s.trim().parse().ok())
            .unwrap();
        assert!(input < 200, "shrunk input {input} not small (msg: {msg})");
    }

    #[test]
    fn vec_shrink_respects_min_len() {
        let g = VecGen {
            elem: IntRange { lo: 0, hi: 5 },
            min_len: 2,
            max_len: 10,
        };
        let v = vec![1u64, 2, 3, 4];
        for cand in g.shrink(&v) {
            assert!(cand.len() >= 2);
        }
    }
}
