//! TOML-subset configuration parser.
//!
//! The launcher (`optinic` binary) and experiments are driven by config files
//! in a TOML subset: `[section]` / `[section.sub]` headers, `key = value`
//! pairs with string / integer / float / boolean / array values, `#`
//! comments. No multi-line strings, no inline tables, no dates — the subset
//! a systems config actually needs. (No `toml` crate in the offline cache.)

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Flat map of dotted keys (`section.key`) to values.
#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn empty() -> Self {
        Config::default()
    }

    pub fn from_file(path: impl AsRef<Path>) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            anyhow::anyhow!("reading config {}: {e}", path.as_ref().display())
        })?;
        Ok(Config::parse(&text)?)
    }

    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(ConfigError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ConfigError {
                        line: lineno,
                        msg: "empty section name".into(),
                    });
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or(ConfigError {
                line: lineno,
                msg: format!("expected 'key = value', got '{line}'"),
            })?;
            let key = line[..eq].trim();
            let val_text = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(ConfigError {
                    line: lineno,
                    msg: "empty key".into(),
                });
            }
            let value = parse_value(val_text).map_err(|msg| ConfigError {
                line: lineno,
                msg,
            })?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, value);
        }
        Ok(Config { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Set/override a value (used for `--set key=value` CLI overrides).
    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }

    /// Override from a raw `key=value` string, inferring the type.
    pub fn set_raw(&mut self, key: &str, raw: &str) -> Result<(), String> {
        let v = parse_value(raw).unwrap_or(Value::Str(raw.to_string()));
        self.entries.insert(key.to_string(), v);
        Ok(())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str().map(str::to_string))
            .unwrap_or_else(|| default.to_string())
    }

    /// Optional string: `None` when the key is absent (for settings whose
    /// absence means "use the subject's own default", e.g. the CC
    /// algorithm, where forcing any value would change experiment
    /// semantics).
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.get(key).and_then(|v| v.as_str().map(str::to_string))
    }

    pub fn i64(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.i64(key, default as i64) as usize
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn require_str(&self, key: &str) -> anyhow::Result<String> {
        self.get(key)
            .and_then(|v| v.as_str().map(str::to_string))
            .ok_or_else(|| anyhow::anyhow!("missing required config key '{key}'"))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// Keys under a section prefix (without the prefix).
    pub fn section(&self, prefix: &str) -> Vec<(&str, &Value)> {
        let p = format!("{prefix}.");
        self.entries
            .iter()
            .filter_map(|(k, v)| k.strip_prefix(&p).map(|rest| (rest, v)))
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        let mut s = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    other => return Err(format!("bad escape: \\{other:?}")),
                }
            } else {
                s.push(c);
            }
        }
        return Ok(Value::Str(s));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if t.starts_with('[') {
        let inner = t
            .strip_prefix('[')
            .unwrap()
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Arr(items));
    }
    // numbers: allow underscores, suffix-free ints and floats
    let clean: String = t.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare words are treated as strings (ergonomic for enum-ish values)
    if t.chars().all(|c| c.is_alphanumeric() || "-_.:/".contains(c)) {
        return Ok(Value::Str(t.to_string()));
    }
    Err(format!("cannot parse value '{t}'"))
}

/// Split on commas not nested in brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

impl fmt::Display for Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (k, v) in &self.entries {
            writeln!(f, "{k} = {v:?}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig5"           # inline comment
seed = 42

[net]
link_gbps = 25.0
nodes = 8
mtu = 1_500
ecn = true
rates = [10, 20.5, 30]

[net.switch]
buffer_kb = 512
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name", ""), "fig5");
        assert_eq!(c.i64("seed", 0), 42);
        assert_eq!(c.f64("net.link_gbps", 0.0), 25.0);
        assert_eq!(c.usize("net.nodes", 0), 8);
        assert_eq!(c.i64("net.mtu", 0), 1500);
        assert!(c.bool("net.ecn", false));
        assert_eq!(c.i64("net.switch.buffer_kb", 0), 512);
        let arr = c.get("net.rates").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64(), Some(20.5));
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.i64("missing", 7), 7);
        assert_eq!(c.str("missing", "x"), "x");
    }

    #[test]
    fn str_opt_distinguishes_absent() {
        let c = Config::parse("sweep.cc = dcqcn").unwrap();
        assert_eq!(c.str_opt("sweep.cc").as_deref(), Some("dcqcn"));
        assert_eq!(c.str_opt("sweep.missing"), None);
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str("k", ""), "a#b");
    }

    #[test]
    fn bare_words_are_strings() {
        let c = Config::parse("transport = optinic").unwrap();
        assert_eq!(c.str("transport", ""), "optinic");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Config::parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Config::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse("a = 1").unwrap();
        c.set_raw("a", "2").unwrap();
        c.set_raw("b.c", "hello").unwrap();
        assert_eq!(c.i64("a", 0), 2);
        assert_eq!(c.str("b.c", ""), "hello");
    }

    #[test]
    fn nested_arrays() {
        let c = Config::parse("m = [[1,2],[3,4]]").unwrap();
        let outer = c.get("m").unwrap().as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn string_escapes() {
        let c = Config::parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(c.str("s", ""), "a\nb\t\"c\"");
    }
}
