//! Minimal JSON value model, serializer, and parser.
//!
//! The offline toolchain has no `serde`/`serde_json`, but the repository
//! needs JSON in two places: reading `artifacts/manifest.json` written by
//! `python/compile/aot.py`, and writing machine-readable experiment results.
//! This is a complete, strict JSON implementation (RFC 8259 subset: no
//! surrogate-pair escapes in output, `\uXXXX` accepted on input).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value.into());
        } else {
            panic!("set on non-object Json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no NaN/Inf; emit null (documented lossy case)
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate; expect \uXXXX low surrogate
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // reconstruct UTF-8 multibyte sequences byte-by-byte
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().at(2).unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        // serialize then reparse
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let raw = Json::parse("\"héllo\"").unwrap();
        assert_eq!(raw.as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("name", "optinic").set("qps", 80_000u64).set("ok", true);
        let text = o.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("qps").unwrap().as_i64(), Some(80_000));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }
}
