//! Utility substrates.
//!
//! The offline toolchain ships only the `xla` crate's dependency closure —
//! no `rand`, `serde`, `clap`, `criterion`, or `proptest`. Everything a
//! production systems repo normally pulls from those crates is implemented
//! here, from scratch, with tests:
//!
//! - [`prng`]: PCG64 deterministic random numbers + distributions
//! - [`stats`]: online stats, percentiles, EWMA, latency histograms
//! - [`json`]: JSON parse/serialize (manifest + experiment outputs)
//! - [`config`]: TOML-subset experiment/config file parser
//! - [`cli`]: argument parsing for the launcher and examples
//! - [`bench`]: the bench harness used by `rust/benches/*`
//! - [`sweep`]: the deterministic multicore sweep runner every figure
//!   grid executes through (scoped-thread pool, fixed-order merge)
//! - [`proptest_mini`]: seeded property-based testing with shrinking

pub mod bench;
pub mod cli;
pub mod config;
pub mod json;
pub mod prng;
pub mod proptest_mini;
pub mod stats;
pub mod sweep;

/// Format a byte count using binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fmt_bytes_units() {
        assert_eq!(super::fmt_bytes(512), "512 B");
        assert_eq!(super::fmt_bytes(2048), "2.00 KiB");
        assert_eq!(super::fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }
}
