//! Deterministic pseudo-random number generation.
//!
//! The offline toolchain ships no `rand` crate, so the simulator carries its
//! own PRNG. We use PCG64 (O'Neill, "PCG: A Family of Simple Fast
//! Space-Efficient Statistically Good Algorithms for Random Number
//! Generation") in the XSL-RR variant: 128-bit LCG state, 64-bit output.
//! Determinism is load-bearing — every experiment in EXPERIMENTS.md is keyed
//! by a seed, and the DES replays bit-identically for a given seed.

/// PCG64 XSL-RR generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        // SplitMix64 expansion of the seed into 128-bit state material so
        // that nearby seeds do not produce correlated streams.
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next() as u128;
        let s1 = sm.next() as u128;
        let mut smi = SplitMix64::new(stream ^ 0x9e37_79b9_7f4a_7c15);
        let i0 = smi.next() as u128;
        let i1 = smi.next() as u128;
        let mut g = Pcg64 {
            state: 0,
            inc: (((i0 << 64) | i1) << 1) | 1, // must be odd
        };
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g.state = g.state.wrapping_add((s0 << 64) | s1);
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Fork an independent child generator (used to give each simulated
    /// component its own stream without sharing mutable state).
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        if p <= 0.0 {
            false
        } else if p >= 1.0 {
            true
        } else {
            self.f64() < p
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple and fine
    /// for non-hot-path use).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pareto (heavy-tail) with scale `xm` and shape `alpha`. Used for
    /// background-traffic flow sizes (datacenter flow-size distributions are
    /// heavy-tailed).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        xm / u.powf(1.0 / alpha)
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (rejection-free
    /// inverse-CDF over precomputed weights is done by `ZipfTable`; this is a
    /// slow direct version for small `n`).
    pub fn zipf_slow(&mut self, n: usize, s: f64) -> usize {
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Precomputed inverse-CDF table for Zipf sampling — O(log n) per sample.
#[derive(Clone, Debug)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

/// SplitMix64 — used only for seed expansion.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = g.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_bound() {
        let mut g = Pcg64::seeded(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[g.below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut g = Pcg64::seeded(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut g = Pcg64::seeded(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| g.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zipf_table_matches_slow() {
        let mut g = Pcg64::seeded(17);
        let table = ZipfTable::new(100, 1.1);
        // rank 0 must be the most frequent
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[table.sample(&mut g)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg64::seeded(19);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut g = Pcg64::seeded(23);
        for _ in 0..1000 {
            let x = g.range_inclusive(5, 7);
            assert!((5..=7).contains(&x));
        }
    }
}
