//! Statistics primitives used across the simulator and benches: online
//! mean/variance, percentile extraction, EWMA, and fixed-bucket histograms.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// A sample reservoir that keeps *all* samples; used for percentile queries
/// on bounded-size experiment outputs (collective completion times etc.).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples {
            xs: Vec::new(),
            sorted: true,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            f64::NAN
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    pub fn std(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile with linear interpolation; `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        self.ensure_sorted();
        percentile_of_sorted(&self.xs, q)
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// p99.9 — the SLO-attainment tail the serving layer reports. With
    /// fewer than ~1000 samples this interpolates toward the max, which
    /// is the honest small-sample reading of "99.9th percentile".
    pub fn p999(&mut self) -> f64 {
        self.percentile(99.9)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(f64::NAN)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    /// Append another reservoir's samples in their insertion order. The
    /// partitioned engine merges per-partition `Metrics` in fixed
    /// partition order, so concatenation keeps the merged reservoir
    /// byte-identical no matter how many worker threads produced it.
    pub fn merge(&mut self, other: &Samples) {
        if other.xs.is_empty() {
            return;
        }
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }
}

/// Percentile over an already-sorted slice (linear interpolation, same
/// convention as numpy's default).
pub fn percentile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median of a mutable slice (sorts in place).
pub fn median_inplace(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_of_sorted(xs, 50.0)
}

/// Exponentially weighted moving average, `alpha` = weight of the new value.
/// This is the exact smoother the paper uses for adaptive timeouts:
/// `T_new = alpha * T_median + (1 - alpha) * T_old` (§3.1.2, alpha = 0.2).
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(old) => self.alpha * x + (1.0 - self.alpha) * old,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn set(&mut self, x: f64) {
        self.value = Some(x);
    }
}

/// Log-scaled latency histogram (HdrHistogram-lite): buckets are
/// `[2^k, 2^(k+1))` ns subdivided linearly. Used on the DES hot path where we
/// cannot afford to retain every sample.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// sub-buckets per power of two
    sub: usize,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: u64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        let sub = 16;
        LatencyHistogram {
            sub,
            counts: vec![0; 64 * sub],
            total: 0,
            sum: 0.0,
            max: 0,
        }
    }

    fn bucket_of(&self, v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let k = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let base = 1u64 << k;
        let frac = ((v - base) as u128 * self.sub as u128 / base as u128) as usize;
        (k * self.sub + frac).min(self.counts.len() - 1)
    }

    fn bucket_low(&self, idx: usize) -> u64 {
        let k = idx / self.sub;
        let frac = idx % self.sub;
        let base = 1u64 << k;
        base + (base as u128 * frac as u128 / self.sub as u128) as u64
    }

    pub fn record(&mut self, v: u64) {
        let b = self.bucket_of(v);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v as f64;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate percentile (bucket lower bound).
    pub fn percentile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return self.bucket_low(i);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.sub, other.sub);
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_mean_var() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_stats_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 1e-9);
    }

    #[test]
    fn p999_on_known_distributions() {
        // uniform grid 1..=10_000: p99.9 rank = 0.999 * 9999 = 9989.001
        let mut u = Samples::new();
        for i in 1..=10_000 {
            u.push(i as f64);
        }
        assert!((u.p999() - 9990.001).abs() < 1e-6, "p999={}", u.p999());
        assert!(u.p999() > u.p99());
        assert!(u.p999() <= u.max());

        // exponential(λ=1): theoretical p99.9 = -ln(0.001) ≈ 6.908; with
        // 200k samples the empirical value lands within a few percent
        let mut e = Samples::new();
        let mut rng = crate::util::prng::Pcg64::seeded(77);
        for _ in 0..200_000 {
            e.push(rng.exponential(1.0));
        }
        let expect = -(0.001f64).ln();
        assert!(
            (e.p999() - expect).abs() / expect < 0.10,
            "p999={} expect={expect}",
            e.p999()
        );
        // and the tail ordering holds
        assert!(e.p50() < e.p99() && e.p99() < e.p999());
    }

    #[test]
    fn p999_small_sample_reads_toward_max() {
        let mut s = Samples::new();
        for i in 1..=10 {
            s.push(i as f64);
        }
        // 10 samples: p99.9 interpolates between 9 and 10, close to 10
        assert!(s.p999() > 9.9 && s.p999() <= 10.0);
    }

    #[test]
    fn median() {
        assert_eq!(median_inplace(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median_inplace(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median_inplace(&mut []).is_nan());
    }

    #[test]
    fn ewma_first_update_is_identity() {
        let mut e = Ewma::new(0.2);
        assert_eq!(e.update(10.0), 10.0);
        let v = e.update(20.0);
        assert!((v - (0.2 * 20.0 + 0.8 * 10.0)).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentile_close_to_exact() {
        let mut h = LatencyHistogram::new();
        let mut s = Samples::new();
        let mut rng = crate::util::prng::Pcg64::seeded(5);
        for _ in 0..50_000 {
            let v = (rng.exponential(1.0 / 50_000.0)) as u64 + 1;
            h.record(v);
            s.push(v as f64);
        }
        let hp = h.percentile(99.0) as f64;
        let sp = s.p99();
        // log-bucketing with 16 sub-buckets → ≤ ~7% relative error
        assert!((hp - sp).abs() / sp < 0.1, "hist={hp} exact={sp}");
    }

    #[test]
    fn histogram_zero_and_max() {
        let mut h = LatencyHistogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
    }
}
