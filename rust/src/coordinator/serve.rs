//! Inference-serving coordinator (Fig 4): batched decode with per-step
//! tensor-parallel collectives over the simulated fabric; reports
//! accuracy, throughput (tokens/s), and TTFT (mean + p99).
//!
//! This is the **closed-loop compatibility path**: one batch is in
//! service at a time and the clock advances with it, so it measures
//! service capacity and accuracy-under-loss, not SLO attainment. The
//! open-loop multi-tenant path (arrivals independent of service, pools
//! contending inside the DES) lives in [`crate::serving`]; arrivals here
//! are drawn from the same [`crate::serving::workload`] generator so the
//! two paths share one arrival-process definition.
//!
//! Request flow: Poisson arrivals → admission queue → batch formation
//! ([`batch_window`]) → prefill (compute + per-layer TP AllReduce) emits
//! the first token (TTFT) → `decode_tokens` further decode iterations,
//! each with a TP AllReduce of activation size. TTFT and queueing delay
//! are measured from each request's *own* arrival time, never from the
//! batch head's.
//!
//! Accuracy is *measured end-to-end*: the final TP AllReduce of each
//! evaluated decode carries the model's real logits, decomposed into
//! per-rank partial sums, through the lossy fabric; the recovered logits'
//! argmax is compared against the clean argmax path (Fig 4a).

use anyhow::Result;

use crate::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use crate::coordinator::env::EnvKind;
use crate::coordinator::gpu::GpuModel;
use crate::data::Corpus;
use crate::recovery::{self, Codec};
use crate::runtime::Engine;
use crate::serving::workload::{self, ArrivalKind, TenantCfg};
use crate::sim::cluster::{Cluster, ClusterCfg};
use crate::sim::SimTime;
use crate::transport::TransportKind;
use crate::util::prng::Pcg64;
use crate::util::stats::Samples;

#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub model: String,
    pub env: EnvKind,
    pub transport: TransportKind,
    pub codec: Codec,
    /// request arrival rate (requests/s of simulated time)
    pub arrival_rps: f64,
    pub num_requests: usize,
    /// decode iterations per request after the first token
    pub decode_tokens: usize,
    /// local SGD steps before serving so accuracy scores are meaningful
    pub pretrain_steps: usize,
    pub seed: u64,
    pub bg_load: f64,
    /// override the fabric's random-corruption probability (Fig 2 sweeps)
    pub corrupt_prob: Option<f64>,
}

impl ServeCfg {
    pub fn new(model: &str, env: EnvKind, transport: TransportKind) -> ServeCfg {
        ServeCfg {
            model: model.to_string(),
            env,
            transport,
            codec: Codec::HadamardBlockStride { p: 256, stride: 64 },
            arrival_rps: 300.0,
            num_requests: 64,
            decode_tokens: 4,
            pretrain_steps: 40,
            seed: 7,
            bg_load: 0.2,
            corrupt_prob: None,
        }
    }
}

#[derive(Debug, Default)]
pub struct ServeResult {
    pub ttft_ns: Samples,
    /// Per-request queueing delay: service start minus the request's OWN
    /// arrival time (the per-batch clock used to hide this — a request
    /// that arrived mid-window waits less than the batch head).
    pub queue_delay_ns: Samples,
    pub tokens_generated: usize,
    pub total_sim_ns: SimTime,
    /// end-to-end next-token accuracy through the lossy logits path
    pub lossy_accuracy: f64,
    /// accuracy of the clean (no-network) path on the same examples
    pub clean_accuracy: f64,
    pub data_loss_fraction: f64,
    /// Bounded completions across all TP collectives (verbs v2 loss-aware
    /// events): how often the serving path traded data for latency.
    pub partial_steps: usize,
}

impl ServeResult {
    pub fn throughput_tps(&self) -> f64 {
        if self.total_sim_ns == 0 {
            0.0
        } else {
            self.tokens_generated as f64 / (self.total_sim_ns as f64 / 1e9)
        }
    }
}

pub struct Server<'e> {
    pub cfg: ServeCfg,
    engine: &'e mut Engine,
    cluster: Cluster,
    ws: Workspace,
    driver: Driver,
    gpu: GpuModel,
    rng: Pcg64,
    params: Vec<f32>,
    wire_elems: usize,
    /// Reused activation payload for the timing-only per-layer/decode
    /// collectives (hoisted out of the loops — PR 4 `InputSet` precedent).
    act_buf: Vec<f32>,
}

impl<'e> Server<'e> {
    pub fn new(cfg: ServeCfg, engine: &'e mut Engine) -> Result<Server<'e>> {
        let info = engine.manifest.model(&cfg.model)?.clone();
        let mut params = engine.init_params(&cfg.model)?;
        // quick local pretraining so the served model predicts better than
        // chance and Fig 4a's accuracy comparison is meaningful
        if cfg.pretrain_steps > 0 {
            let corpus = crate::data::Corpus::new(info.vocab, cfg.seed ^ 0xDA7A);
            let mut mom = vec![0.0f32; params.len()];
            for s in 0..cfg.pretrain_steps {
                let toks = corpus.batch(info.batch, info.seq_len + 1, s as u64);
                let (_, grads) = engine.fwd_bwd(&cfg.model, &params, &toks)?;
                let (p2, m2) = engine.apply(&cfg.model, &params, &grads, &mom, 0.05)?;
                params = p2;
                mom = m2;
            }
        }
        // activation-sized collective payload: batch × vocab logits
        let logits_elems = info.batch * info.vocab;
        let wire_elems = recovery::encode(&vec![0.0; logits_elems], cfg.codec).len();
        let mut fab = cfg.env.fabric();
        fab.nodes = cfg.env.nodes();
        if let Some(p) = cfg.corrupt_prob {
            fab.corrupt_prob = p;
        }
        let mut cluster = Cluster::new(
            ClusterCfg::new(fab, cfg.transport)
                .with_seed(cfg.seed)
                .with_bg_load(cfg.bg_load),
        );
        let ws = Workspace::new(&mut cluster, wire_elems, 1);
        let gpu = cfg.env.gpu();
        let rng = Pcg64::new(cfg.seed, 0x5e1e);
        Ok(Server {
            cfg,
            engine,
            cluster,
            ws,
            driver: Driver::new(0x5e17e),
            gpu,
            rng,
            params,
            wire_elems,
            act_buf: vec![0.01f32; logits_elems],
        })
    }

    fn reliable(&self) -> bool {
        !matches!(
            self.cfg.transport,
            TransportKind::Optinic | TransportKind::OptinicHw
        )
    }

    /// One TP AllReduce carrying real per-rank partials of `payload`.
    /// Returns (recovered payload, cct, loss fraction, bounded completions).
    fn tp_allreduce(
        &mut self,
        payload: &[f32],
        delays: &[SimTime],
    ) -> (Vec<f32>, SimTime, f64, usize) {
        let n = self.cluster.nodes();
        // decompose into n partial sums (random convex weights per element
        // block would be overkill; a fixed 1/n split keeps reduction exact)
        let partial: Vec<f32> = payload.iter().map(|v| v / n as f32).collect();
        let enc = recovery::encode(&partial, self.cfg.codec);
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| enc.clone()).collect();
        self.ws.load_inputs(&mut self.cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, self.wire_elems);
        spec.stride = self.cfg.codec.wire_stride();
        spec.start_delays = delays.to_vec();
        spec.exchange_stats = !self.reliable();
        if self.reliable() {
            spec = spec.reliable();
        }
        let res = self.driver.run(&mut self.cluster, &self.ws, &spec);
        let wire = self.ws.read_output(&self.cluster, 0, CollectiveKind::AllReduceRing);
        let out = recovery::decode(&wire, self.cfg.codec, payload.len());
        (out, res.cct_ns, res.loss_fraction, res.partial_steps())
    }

    pub fn run(mut self) -> Result<ServeResult> {
        let info = self.engine.manifest.model(&self.cfg.model)?.clone();
        let corpus = Corpus::new(info.vocab, self.cfg.seed ^ 0x1f);
        // arrivals come from the shared open-loop generator (one Poisson
        // tenant = the historical Fig 4 workload)
        let tenants = vec![TenantCfg::new(
            "fig4",
            self.cfg.arrival_rps,
            ArrivalKind::Poisson,
        )];
        let arrivals: Vec<SimTime> =
            workload::generate(&tenants, self.cfg.num_requests, self.cfg.seed)
                .into_iter()
                .map(|r| r.arrival_ns)
                .collect();
        let act = std::mem::take(&mut self.act_buf);

        let mut result = ServeResult::default();
        let mut clock: SimTime = 0;
        let mut next_req = 0;
        let mut loss_acc = 0.0;
        let mut loss_n = 0usize;
        let mut correct_lossy = 0usize;
        let mut correct_clean = 0usize;
        let mut scored = 0usize;
        let n = self.cluster.nodes();

        while next_req < arrivals.len() {
            // admit everything that has arrived; serve one batch per loop
            let batch_start = next_req;
            let (batch, service_start) =
                batch_window(&arrivals, batch_start, info.batch, clock);
            clock = service_start;
            next_req = batch_start + batch;
            // queueing delay is per-request, from each one's own arrival —
            // a request that slid into the window mid-wait waits less
            for r in batch_start..batch_start + batch {
                result
                    .queue_delay_ns
                    .push(service_start.saturating_sub(arrivals[r]) as f64);
            }

            // ---- prefill: compute + per-layer TP collectives -------------
            let prefill_flops = GpuModel::train_step_flops(
                info.param_count,
                batch,
                info.seq_len,
            ) / 3.0; // forward only
            let (delays, base_compute) = self.gpu.step_delays(prefill_flops, n, &mut self.rng);
            clock += base_compute + *delays.iter().max().unwrap();
            // real logits for the batch (deterministic prompt per request)
            let toks = corpus.batch(info.batch, info.seq_len, batch_start as u64);
            let clean_logits = self.engine.infer(&self.cfg.model, &self.params, &toks)?;
            // intermediate per-layer collectives: timing only (small acts,
            // one reused buffer — no per-layer allocation)
            for _ in 0..info.n_layers.saturating_sub(1) {
                let (_, cct, lf, p) = self.tp_allreduce(&act, &[]);
                clock += cct;
                loss_acc += lf;
                loss_n += 1;
                result.partial_steps += p;
            }
            // final collective carries the real logits end-to-end
            let (lossy_logits, cct, lf, p) = self.tp_allreduce(&clean_logits, &[]);
            clock += cct;
            loss_acc += lf;
            loss_n += 1;
            result.partial_steps += p;

            // first token produced now → TTFT for every request in batch
            for r in batch_start..batch_start + batch {
                result
                    .ttft_ns
                    .push(clock.saturating_sub(arrivals[r]) as f64);
            }
            result.tokens_generated += batch;

            // accuracy scoring: argmax of lossy vs clean logits vs target
            let targets = corpus.batch(info.batch, info.seq_len + 1, batch_start as u64);
            for b in 0..info.batch.min(batch) {
                let clean = &clean_logits[b * info.vocab..(b + 1) * info.vocab];
                let lossy = &lossy_logits[b * info.vocab..(b + 1) * info.vocab];
                let target = targets[b * (info.seq_len + 1) + info.seq_len];
                if argmax(clean) == target as usize {
                    correct_clean += 1;
                }
                if argmax(lossy) == target as usize {
                    correct_lossy += 1;
                }
                scored += 1;
            }

            // ---- decode iterations (timing + loss accounting) ------------
            for _ in 0..self.cfg.decode_tokens {
                let decode_flops = GpuModel::decode_step_flops(info.param_count, batch);
                let (ddelays, dbase) = self.gpu.step_delays(decode_flops, n, &mut self.rng);
                clock += dbase + *ddelays.iter().max().unwrap();
                let (_, cct, lf, p) = self.tp_allreduce(&act, &ddelays);
                clock += cct;
                loss_acc += lf;
                loss_n += 1;
                result.partial_steps += p;
                result.tokens_generated += batch;
            }
        }

        result.total_sim_ns = clock;
        result.data_loss_fraction = loss_acc / loss_n.max(1) as f64;
        result.lossy_accuracy = correct_lossy as f64 / scored.max(1) as f64;
        result.clean_accuracy = correct_clean as f64 / scored.max(1) as f64;
        Ok(result)
    }
}

/// Form one service batch from the admission queue.
///
/// Service can start once the head request has arrived (`service_start =
/// max(clock, arrivals[batch_start])`); every request already arrived by
/// that instant joins, up to `capacity`. Returns `(batch_len,
/// service_start)`. Arrivals must be sorted ascending (the workload
/// generator guarantees this). Pure so the queueing-delay semantics are
/// testable without the pjrt engine.
pub(crate) fn batch_window(
    arrivals: &[SimTime],
    batch_start: usize,
    capacity: usize,
    clock: SimTime,
) -> (usize, SimTime) {
    let service_start = clock.max(arrivals[batch_start]);
    let cap = capacity.max(1).min(arrivals.len() - batch_start);
    let mut batch = 1;
    while batch < cap && arrivals[batch_start + batch] <= service_start {
        batch += 1;
    }
    (batch, service_start)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}

// Batch-formation / queueing-delay semantics: pure, no engine needed.
// These pin the per-request accounting — under the old per-batch clock,
// every request in a window was charged the head's wait, so the second
// case below (distinct delays within one window) fails on that behavior.
#[cfg(test)]
mod batch_tests {
    use super::batch_window;

    #[test]
    fn window_admits_only_arrived_requests() {
        // head arrived at 0 and 5; next at 100 hasn't when service starts
        let arrivals = [0, 5, 100];
        let (batch, service_start) = batch_window(&arrivals, 0, 4, 10);
        assert_eq!(service_start, 10);
        assert_eq!(batch, 2, "request arriving at t=100 must not be admitted");
    }

    #[test]
    fn queue_delay_is_per_request_not_per_batch() {
        let arrivals = [0, 5, 100];
        let (batch, service_start) = batch_window(&arrivals, 0, 4, 10);
        let delays: Vec<u64> = (0..batch)
            .map(|r| service_start.saturating_sub(arrivals[r]))
            .collect();
        // the head waited 10ns, the mid-window arrival only 5ns — the old
        // per-batch accounting reported 10 for both
        assert_eq!(delays, vec![10, 5]);
    }

    #[test]
    fn service_waits_for_head_arrival() {
        let arrivals = [50, 60];
        let (batch, service_start) = batch_window(&arrivals, 0, 8, 0);
        assert_eq!(service_start, 50, "service cannot start before arrival");
        assert_eq!(batch, 1);
        // head's queueing delay is zero: it is served the instant it arrives
        assert_eq!(service_start - arrivals[0], 0);
    }

    #[test]
    fn capacity_is_honored_and_batch_never_empty() {
        let arrivals = [0, 1, 2, 3, 4, 5];
        let (batch, _) = batch_window(&arrivals, 0, 4, 1_000);
        assert_eq!(batch, 4, "batch capped at capacity");
        let (batch, _) = batch_window(&arrivals, 5, 0, 1_000);
        assert_eq!(batch, 1, "degenerate capacity still serves the head");
    }

    #[test]
    fn mid_queue_start_offsets_correctly() {
        let arrivals = [0, 10, 20, 30];
        let (batch, service_start) = batch_window(&arrivals, 2, 4, 25);
        assert_eq!(service_start, 25);
        assert_eq!(batch, 1, "only index 2 has arrived by t=25");
        let (batch, service_start) = batch_window(&arrivals, 2, 4, 35);
        assert_eq!((batch, service_start), (2, 35));
    }
}

// Quarantined behind `pjrt`: serving scores accuracy through real model
// inference (XLA CPU client + `make artifacts`), which is
// environment-dependent. The TP-collective path underneath is covered by
// the tier-1 collectives tests.
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn serving_produces_tokens_and_ttft() {
        let mut engine = Engine::load_default().expect("make artifacts");
        let mut cfg = ServeCfg::new("tiny", EnvKind::Hyperstack4, TransportKind::Optinic);
        cfg.num_requests = 8;
        cfg.decode_tokens = 2;
        cfg.bg_load = 0.0;
        let mut res = Server::new(cfg, &mut engine).unwrap().run().unwrap();
        assert_eq!(res.ttft_ns.len(), 8);
        assert!(res.tokens_generated >= 8);
        assert!(res.throughput_tps() > 0.0);
        assert!(res.ttft_ns.p99() >= res.ttft_ns.p50());
        // with a lossless fabric, lossy accuracy == clean accuracy
        assert!((res.lossy_accuracy - res.clean_accuracy).abs() < 1e-9);
    }

    #[test]
    fn accuracy_survives_loss() {
        let mut engine = Engine::load_default().expect("make artifacts");
        let mut cfg = ServeCfg::new("tiny", EnvKind::CloudLab8, TransportKind::Optinic);
        cfg.num_requests = 8;
        cfg.decode_tokens = 1;
        cfg.bg_load = 0.0;
        let mut engine2 = Engine::load_default().unwrap();
        let _ = &mut engine2;
        let res = Server::new(cfg, &mut engine).unwrap().run().unwrap();
        // Fig 4a: accuracy difference under loss stays small
        assert!(
            (res.lossy_accuracy - res.clean_accuracy).abs() <= 0.25,
            "lossy {} vs clean {}",
            res.lossy_accuracy,
            res.clean_accuracy
        );
    }
}
