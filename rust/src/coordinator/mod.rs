//! L3 coordinator: environments, GPU compute-time model, the training
//! driver (Fig 2/3), and the inference-serving driver (Fig 4). The
//! launcher binary (`rust/src/main.rs`) is a thin CLI over these.

pub mod env;
pub mod gpu;
pub mod serve;
pub mod train;

pub use env::EnvKind;
pub use gpu::{GpuKind, GpuModel};
pub use serve::{ServeCfg, ServeResult, Server};
pub use train::{CommPattern, StepRecord, TrainCfg, TrainResult, Trainer};
