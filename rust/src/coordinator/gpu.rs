//! GPU compute-time model: per-step durations for the training/serving
//! simulations, with the jitter that creates real stragglers.
//!
//! The paper's environments (§5.1.1): CloudLab V100S (32 GB) and
//! Hyperstack H100 (80 GB). Effective training throughput (achieved, not
//! peak) is what the TTA accounting needs; values follow the commonly
//! reported ~40–50% MFU for mid-size transformer fine-tuning.

use crate::sim::SimTime;
use crate::util::prng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GpuKind {
    V100,
    H100,
}

impl GpuKind {
    /// Achieved training FLOP/s (mixed precision, incl. utilization).
    pub fn train_flops(&self) -> f64 {
        match self {
            GpuKind::V100 => 45e12,  // ~125 TF tensor-core peak × ~0.36 MFU
            GpuKind::H100 => 420e12, // ~990 TF bf16 peak × ~0.42 MFU
        }
    }

    /// Fixed per-step launch/framework overhead, ns.
    pub fn step_overhead_ns(&self) -> u64 {
        match self {
            GpuKind::V100 => 800_000,
            GpuKind::H100 => 400_000,
        }
    }
}

/// Jittered compute-time source. Every rank draws an independent duration
/// per step: multiplicative lognormal-ish jitter plus an occasional
/// heavy-tail straggler event (GC, preemption, clock throttling) — the
/// §2.1 "slowest GPU in each synchronization round" effect.
#[derive(Clone, Debug)]
pub struct GpuModel {
    pub kind: GpuKind,
    /// fractional jitter sigma (multiplicative)
    pub jitter_sigma: f64,
    /// probability of a straggler event per step
    pub straggler_prob: f64,
    /// straggler extra delay as a fraction of the base step (mean of exp)
    pub straggler_scale: f64,
}

impl GpuModel {
    pub fn new(kind: GpuKind) -> GpuModel {
        GpuModel {
            kind,
            jitter_sigma: 0.04,
            straggler_prob: 0.03,
            straggler_scale: 0.6,
        }
    }

    /// Training-step FLOPs: the standard 6·params·tokens estimate.
    pub fn train_step_flops(params: usize, batch: usize, seq: usize) -> f64 {
        6.0 * params as f64 * (batch * seq) as f64
    }

    /// Decode-step FLOPs (one token per sequence): 2·params·batch.
    pub fn decode_step_flops(params: usize, batch: usize) -> f64 {
        2.0 * params as f64 * batch as f64
    }

    /// Deterministic base duration for a compute chunk of `flops`.
    pub fn base_ns(&self, flops: f64) -> SimTime {
        (flops / self.kind.train_flops() * 1e9) as SimTime + self.kind.step_overhead_ns()
    }

    /// Jittered duration for one rank's step.
    pub fn sample_ns(&self, flops: f64, rng: &mut Pcg64) -> SimTime {
        let base = self.base_ns(flops) as f64;
        let mult = (1.0 + self.jitter_sigma * rng.normal()).max(0.5);
        let mut t = base * mult;
        if rng.chance(self.straggler_prob) {
            t += rng.exponential(1.0 / (self.straggler_scale * base));
        }
        t as SimTime
    }

    /// Per-rank start delays for a collective following a compute phase:
    /// each rank's jittered duration, normalized so the fastest is 0 —
    /// the straggler *skew* that the transport sees.
    pub fn step_delays(&self, flops: f64, ranks: usize, rng: &mut Pcg64) -> (Vec<SimTime>, SimTime) {
        let times: Vec<SimTime> = (0..ranks).map(|_| self.sample_ns(flops, rng)).collect();
        let min = *times.iter().min().unwrap();
        let delays = times.iter().map(|t| t - min).collect();
        (delays, min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_faster_than_v100() {
        let f = GpuModel::train_step_flops(1_000_000, 8, 64);
        let v = GpuModel::new(GpuKind::V100).base_ns(f);
        let h = GpuModel::new(GpuKind::H100).base_ns(f);
        assert!(h < v);
    }

    #[test]
    fn jitter_produces_spread_and_tail() {
        let m = GpuModel::new(GpuKind::V100);
        let mut rng = Pcg64::seeded(1);
        let f = GpuModel::train_step_flops(5_000_000, 8, 64);
        let base = m.base_ns(f);
        let xs: Vec<SimTime> = (0..2000).map(|_| m.sample_ns(f, &mut rng)).collect();
        let max = *xs.iter().max().unwrap();
        let min = *xs.iter().min().unwrap();
        assert!(min < base);
        // heavy tail: worst case well above base
        assert!(max as f64 > 1.3 * base as f64, "max={max} base={base}");
    }

    #[test]
    fn delays_normalized_to_fastest() {
        let m = GpuModel::new(GpuKind::H100);
        let mut rng = Pcg64::seeded(2);
        let (delays, min) = m.step_delays(1e12, 8, &mut rng);
        assert_eq!(delays.len(), 8);
        assert_eq!(*delays.iter().min().unwrap(), 0);
        assert!(min > 0);
    }

    #[test]
    fn flops_formulas() {
        assert_eq!(GpuModel::train_step_flops(10, 2, 3), 6.0 * 10.0 * 6.0);
        assert_eq!(GpuModel::decode_step_flops(10, 4), 80.0);
    }
}
