//! Evaluation environments (§5.1.1): the paper's CloudLab and Hyperstack
//! clusters, as fabric + GPU model pairings.

use crate::coordinator::gpu::{GpuKind, GpuModel};
use crate::net::FabricCfg;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EnvKind {
    /// 8× r7525: V100S, dual-port CX-5, 25 GbE ToR.
    CloudLab8,
    /// 4× H100-80G-PCIe, 100 G.
    Hyperstack4,
    /// 8× H100-80G-PCIe, 100 G.
    Hyperstack8,
}

impl EnvKind {
    pub const ALL: [EnvKind; 3] = [
        EnvKind::CloudLab8,
        EnvKind::Hyperstack4,
        EnvKind::Hyperstack8,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            EnvKind::CloudLab8 => "CloudLab (8 nodes)",
            EnvKind::Hyperstack4 => "Hyperstack (4 nodes)",
            EnvKind::Hyperstack8 => "Hyperstack (8 nodes)",
        }
    }

    pub fn parse(s: &str) -> Option<EnvKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "cloudlab" | "cloudlab8" | "cloudlab-8" => EnvKind::CloudLab8,
            "hyperstack4" | "hyperstack-4" => EnvKind::Hyperstack4,
            "hyperstack" | "hyperstack8" | "hyperstack-8" => EnvKind::Hyperstack8,
            _ => return None,
        })
    }

    pub fn nodes(&self) -> usize {
        match self {
            EnvKind::CloudLab8 | EnvKind::Hyperstack8 => 8,
            EnvKind::Hyperstack4 => 4,
        }
    }

    pub fn fabric(&self) -> FabricCfg {
        match self {
            EnvKind::CloudLab8 => FabricCfg::cloudlab(8),
            EnvKind::Hyperstack4 => FabricCfg::hyperstack(4),
            EnvKind::Hyperstack8 => FabricCfg::hyperstack(8),
        }
    }

    pub fn gpu(&self) -> GpuModel {
        match self {
            EnvKind::CloudLab8 => GpuModel::new(GpuKind::V100),
            _ => GpuModel::new(GpuKind::H100),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_shape() {
        assert_eq!(EnvKind::parse("cloudlab"), Some(EnvKind::CloudLab8));
        assert_eq!(EnvKind::parse("hyperstack-4"), Some(EnvKind::Hyperstack4));
        assert_eq!(EnvKind::CloudLab8.nodes(), 8);
        assert_eq!(EnvKind::Hyperstack4.nodes(), 4);
        assert!(EnvKind::Hyperstack8.fabric().link_gbps > EnvKind::CloudLab8.fabric().link_gbps);
    }
}
