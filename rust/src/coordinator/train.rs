//! Distributed-training coordinator (Fig 2, Fig 3): data-parallel and
//! ZeRO-3-style communication patterns over the simulated fabric, with
//! real gradients flowing through the lossy collectives.
//!
//! Per step:
//! 1. every worker runs `fwd_bwd` via PJRT on its own data shard;
//! 2. per-rank compute durations are drawn from the GPU model (jitter +
//!    stragglers) and become collective start delays;
//! 3. gradients are codec-encoded (§3.2), pushed through the *simulated*
//!    network under the configured transport — packets genuinely drop —
//!    reduced in encoded space (the transform is linear), decoded;
//! 4. the averaged (possibly lossy) gradient updates the shared params.
//!
//! Loss curves under loss are therefore measured, not modeled. Simulated
//! wall-clock = Σ max-rank(compute) + collective completion times, which
//! is what time-to-accuracy (TTA) plots against.

use anyhow::Result;

use crate::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use crate::coordinator::env::EnvKind;
use crate::coordinator::gpu::GpuModel;
use crate::data::Corpus;
use crate::recovery::{self, Codec};
use crate::runtime::Engine;
use crate::sim::cluster::{Cluster, ClusterCfg};
use crate::sim::SimTime;
use crate::transport::TransportKind;
use crate::util::prng::Pcg64;

/// Communication pattern per training step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// Classic data parallelism: one AllReduce over gradients.
    DataParallel,
    /// ZeRO-3/FSDP-style: ReduceScatter(grads) + AllGather(params) for the
    /// next forward + a prefetch AllGather overlapping backward (§2.1,
    /// Fig 1). Parameters also traverse the lossy fabric (codec-protected).
    Zero3,
}

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub model: String,
    pub env: EnvKind,
    pub transport: TransportKind,
    pub pattern: CommPattern,
    pub codec: Codec,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub bg_load: f64,
    /// override the fabric's random-corruption probability (Fig 2 sweeps)
    pub corrupt_prob: Option<f64>,
    pub eval_every: usize,
    /// evaluate on this many held-out batches
    pub eval_batches: usize,
}

impl TrainCfg {
    pub fn new(model: &str, env: EnvKind, transport: TransportKind) -> TrainCfg {
        TrainCfg {
            model: model.to_string(),
            env,
            transport,
            pattern: CommPattern::Zero3,
            codec: Codec::HadamardBlockStride { p: 256, stride: 64 },
            steps: 50,
            lr: 0.05,
            seed: 42,
            bg_load: 0.2,
            corrupt_prob: None,
            eval_every: 10,
            eval_batches: 4,
        }
    }
}

/// One step's record.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub train_loss: f32,
    pub sim_time_ns: SimTime,
    pub compute_ns: SimTime,
    pub comm_ns: SimTime,
    pub loss_fraction: f64,
    /// Bounded completions observed this step (verbs v2 loss-aware events,
    /// summed across ranks and collectives).
    pub partial_steps: usize,
    pub eval_accuracy: Option<f32>,
}

#[derive(Debug, Default)]
pub struct TrainResult {
    pub records: Vec<StepRecord>,
    pub final_accuracy: f32,
    pub total_sim_ns: SimTime,
    pub total_loss_fraction: f64,
}

impl TrainResult {
    /// Time-to-accuracy: first simulated time where eval accuracy ≥ target.
    pub fn tta_ns(&self, target: f32) -> Option<SimTime> {
        self.records
            .iter()
            .find(|r| r.eval_accuracy.map(|a| a >= target).unwrap_or(false))
            .map(|r| r.sim_time_ns)
    }
}

pub struct Trainer<'e> {
    pub cfg: TrainCfg,
    engine: &'e mut Engine,
    cluster: Cluster,
    ws: Workspace,
    driver: Driver,
    corpus: Corpus,
    gpu: GpuModel,
    rng: Pcg64,
    /// flat model state (identical across ranks — synchronous SGD)
    params: Vec<f32>,
    momentum: Vec<f32>,
    /// element count of the encoded gradient (codec wire length)
    wire_elems: usize,
    clock: SimTime,
}

impl<'e> Trainer<'e> {
    pub fn new(cfg: TrainCfg, engine: &'e mut Engine) -> Result<Trainer<'e>> {
        let info = engine.manifest.model(&cfg.model)?.clone();
        let params = engine.init_params(&cfg.model)?;
        let momentum = vec![0.0f32; params.len()];
        let wire_elems = recovery::encode(&params, cfg.codec).len();
        let mut fab = cfg.env.fabric();
        fab.nodes = cfg.env.nodes();
        if let Some(p) = cfg.corrupt_prob {
            fab.corrupt_prob = p;
        }
        let cluster_cfg = ClusterCfg::new(fab, cfg.transport)
            .with_seed(cfg.seed)
            .with_bg_load(cfg.bg_load);
        let mut cluster = Cluster::new(cluster_cfg);
        let ws = Workspace::new(&mut cluster, wire_elems, 1);
        let corpus = Corpus::new(info.vocab, cfg.seed ^ 0xDA7A);
        let gpu = cfg.env.gpu();
        let rng = Pcg64::new(cfg.seed, 0x7121);
        Ok(Trainer {
            cfg,
            engine,
            cluster,
            ws,
            driver: Driver::new(0xF16_3),
            corpus,
            gpu,
            rng,
            params,
            momentum,
            wire_elems,
            clock: 0,
        })
    }

    fn reliable(&self) -> bool {
        !matches!(
            self.cfg.transport,
            TransportKind::Optinic | TransportKind::OptinicHw
        )
    }

    /// Run one lossy collective of `kind` where every rank contributes
    /// `inputs[r]`; returns rank-0's output and the comm statistics
    /// (completion time, loss fraction, bounded-completion count).
    fn run_collective(
        &mut self,
        kind: CollectiveKind,
        inputs: &[Vec<f32>],
        delays: &[SimTime],
    ) -> (Vec<f32>, SimTime, f64, usize) {
        self.ws.load_inputs(&mut self.cluster, inputs);
        let mut spec = CollectiveSpec::new(kind, self.wire_elems);
        spec.stride = self.cfg.codec.wire_stride();
        spec.start_delays = delays.to_vec();
        spec.exchange_stats = !self.reliable();
        if self.reliable() {
            spec = spec.reliable();
        }
        let res = self.driver.run(&mut self.cluster, &self.ws, &spec);
        let out = self.ws.read_output(&self.cluster, 0, kind);
        (out, res.cct_ns, res.loss_fraction, res.partial_steps())
    }

    /// Execute one training step; returns its record.
    pub fn step(&mut self, step: usize) -> Result<StepRecord> {
        let info = self.engine.manifest.model(&self.cfg.model)?.clone();
        let n = self.cfg.env.nodes();
        // 1. per-worker compute (PJRT) on disjoint shards
        let mut losses = Vec::with_capacity(n);
        let mut enc_grads: Vec<Vec<f32>> = Vec::with_capacity(n);
        for w in 0..n {
            let toks =
                self.corpus
                    .batch_for_worker(info.batch, info.seq_len + 1, step as u64, w as u64);
            let (loss, grads) = self.engine.fwd_bwd(&self.cfg.model, &self.params, &toks)?;
            losses.push(loss);
            // scale by 1/n before encoding (linear transform commutes)
            let scaled: Vec<f32> = grads.iter().map(|g| g / n as f32).collect();
            enc_grads.push(recovery::encode(&scaled, self.cfg.codec));
        }
        // 2. compute-time jitter → straggler skew
        let flops = GpuModel::train_step_flops(info.param_count, info.batch, info.seq_len);
        let (delays, base_compute) = self.gpu.step_delays(flops, n, &mut self.rng);
        let max_skew = *delays.iter().max().unwrap();

        // 3. communication per the parallelism pattern
        let mut comm_ns = 0;
        let mut loss_acc = 0.0;
        let mut loss_events = 0;
        let mut partial_steps = 0;
        let (reduced_wire, cct, lf, partials) = match self.cfg.pattern {
            CommPattern::DataParallel => {
                self.run_collective(CollectiveKind::AllReduceRing, &enc_grads, &delays)
            }
            CommPattern::Zero3 => {
                // grads: RS then AG over the encoded vector ≈ ring AllReduce;
                // plus a parameter AllGather (FSDP prefetch) — same wire
                // volume of params, codec-protected.
                let (out, t1, l1, p1) =
                    self.run_collective(CollectiveKind::AllReduceRing, &enc_grads, &delays);
                let enc_params = recovery::encode(&self.params, self.cfg.codec);
                let params_in: Vec<Vec<f32>> = (0..n).map(|_| enc_params.clone()).collect();
                let (_pout, t2, l2, p2) =
                    self.run_collective(CollectiveKind::AllGather, &params_in, &[]);
                comm_ns += t2;
                loss_acc += l2;
                loss_events += 1;
                partial_steps += p2;
                (out, t1, l1, p1)
            }
        };
        comm_ns += cct;
        loss_acc += lf;
        loss_events += 1;
        partial_steps += partials;

        // 4. decode + apply
        let avg_grads = recovery::decode(&reduced_wire, self.cfg.codec, self.params.len());
        let (p2, m2) = self.engine.apply(
            &self.cfg.model,
            &self.params,
            &avg_grads,
            &self.momentum,
            self.cfg.lr,
        )?;
        self.params = p2;
        self.momentum = m2;

        let step_ns = base_compute + max_skew + comm_ns;
        self.clock += step_ns;
        let eval_accuracy = if (step + 1) % self.cfg.eval_every == 0 {
            Some(self.evaluate()?)
        } else {
            None
        };
        Ok(StepRecord {
            step,
            train_loss: losses.iter().sum::<f32>() / n as f32,
            sim_time_ns: self.clock,
            compute_ns: base_compute + max_skew,
            comm_ns,
            loss_fraction: loss_acc / loss_events as f64,
            partial_steps,
            eval_accuracy,
        })
    }

    /// Held-out next-token accuracy.
    pub fn evaluate(&mut self) -> Result<f32> {
        let info = self.engine.manifest.model(&self.cfg.model)?.clone();
        let mut acc = 0.0;
        for i in 0..self.cfg.eval_batches {
            let toks = self
                .corpus
                .eval_batch(info.batch, info.seq_len + 1, i as u64);
            acc += self.engine.accuracy(&self.cfg.model, &self.params, &toks)?;
        }
        Ok(acc / self.cfg.eval_batches as f32)
    }

    pub fn run(mut self) -> Result<TrainResult> {
        let mut records = Vec::with_capacity(self.cfg.steps);
        let mut loss_acc = 0.0;
        for s in 0..self.cfg.steps {
            let rec = self.step(s)?;
            loss_acc += rec.loss_fraction;
            log::info!(
                "step {s}: loss={:.4} t={} comm={} dataloss={:.3}%",
                rec.train_loss,
                crate::sim::fmt_time(rec.sim_time_ns),
                crate::sim::fmt_time(rec.comm_ns),
                rec.loss_fraction * 100.0
            );
            records.push(rec);
        }
        let final_accuracy = self.evaluate()?;
        Ok(TrainResult {
            total_sim_ns: self.clock,
            total_loss_fraction: loss_acc / self.cfg.steps.max(1) as f64,
            final_accuracy,
            records,
        })
    }
}

// Quarantined behind `pjrt`: end-to-end training drives real model
// compute through the XLA CPU client and needs `make artifacts` — both
// environment-dependent. The simulation/network layers under the trainer
// are covered by the tier-1 collectives and transport tests.
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn quick_cfg(transport: TransportKind) -> TrainCfg {
        let mut cfg = TrainCfg::new("tiny", EnvKind::Hyperstack4, transport);
        cfg.steps = 6;
        cfg.eval_every = 3;
        cfg.pattern = CommPattern::DataParallel;
        cfg.bg_load = 0.0;
        cfg.codec = Codec::HadamardBlockStride { p: 256, stride: 64 };
        cfg
    }

    #[test]
    fn training_loss_decreases_over_optinic() {
        let mut engine = Engine::load_default().expect("make artifacts");
        let cfg = quick_cfg(TransportKind::Optinic);
        let result = Trainer::new(cfg, &mut engine).unwrap().run().unwrap();
        assert_eq!(result.records.len(), 6);
        let first = result.records.first().unwrap().train_loss;
        let last = result.records.last().unwrap().train_loss;
        assert!(last < first, "loss {first} → {last}");
        assert!(result.total_sim_ns > 0);
    }

    #[test]
    fn training_matches_roce_numerics_when_lossless() {
        // with no corruption and no bg traffic, OptiNIC and RoCE training
        // should produce near-identical loss curves (all data arrives)
        let mut engine = Engine::load_default().expect("make artifacts");
        let run = |t| {
            let cfg = quick_cfg(t);
            Trainer::new(cfg, &mut Engine::load_default().unwrap())
                .unwrap()
                .run()
                .unwrap()
        };
        let _ = &mut engine;
        let a = run(TransportKind::Optinic);
        let b = run(TransportKind::Roce);
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert!(
                (ra.train_loss - rb.train_loss).abs() < 0.05,
                "step {}: {} vs {}",
                ra.step,
                ra.train_loss,
                rb.train_loss
            );
        }
    }
}
